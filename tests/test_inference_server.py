"""InferenceServer beyond the smoke test: wave coalescing under sustained
concurrent submits, short-wave padding correctness, and clean stop while
actors are parked inside ``act()``."""

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from _apex_helpers import init_actor, tiny_preset

from repro.runtime import InferenceServer, ParamStore, phases


def _setup(num_actors: int, coalesce_s: float = 0.002, mode: str = "wave"):
    preset = tiny_preset()
    cfg = dataclasses.replace(preset.apex, num_shards=num_actors)
    env, agent = preset.env, preset.agent
    slices = [init_actor(cfg, env, jax.random.key(t))[0]
              for t in range(num_actors)]
    params = agent.init(jax.random.key(7), slices[0].obs[:1])
    store = ParamStore(params)
    server = InferenceServer(cfg, env, agent, store, max_batch=num_actors,
                             coalesce_s=coalesce_s, mode=mode)
    return cfg, env, agent, slices, params, store, server


def _raw(leaf):
    """Bitwise-comparable view of a leaf (typed PRNG keys included)."""
    if jnp.issubdtype(getattr(leaf, "dtype", None), jax.dtypes.prng_key):
        leaf = jax.random.key_data(leaf)
    return np.asarray(leaf)


def _collect_full_wave(server, slices, num):
    """Submit ``num`` requests from threads and return their results in
    actor order, starting the server only once every request is parked —
    both modes then admit the identical stacked wave."""
    results = {}
    threads = [threading.Thread(target=lambda t=t: results.__setitem__(
        t, server.act(slices[t], t)), daemon=True) for t in range(num)]
    for th in threads:
        th.start()
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        with server._cond:
            if len(server._pending) == num:
                break
        time.sleep(0.005)
    with server._cond:
        assert len(server._pending) == num
    server.start()
    for th in threads:
        th.join(timeout=120.0)
        assert not th.is_alive()
    return results


def test_wave_coalescing_under_concurrent_resubmits():
    """K actors resubmitting in lockstep for R rounds must coalesce: far
    fewer dispatches than requests, with full waves the steady state."""
    K, R = 3, 8
    cfg, env, agent, slices, params, store, server = _setup(K)
    server.warm(slices[0])   # compile before the clock matters
    server.start()
    results = [[] for _ in range(K)]
    barrier = threading.Barrier(K)
    try:
        def worker(t):
            sl = slices[t]
            for _ in range(R):
                barrier.wait(timeout=60.0)  # resubmit together: full waves
                out = server.act(sl, t)
                assert out is not None
                sl, block, _ = out
                results[t].append(block)

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in range(K)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120.0)
            assert not th.is_alive()
    finally:
        server.stop()
    assert server.error is None
    stats = server.snapshot()
    assert stats.requests == K * R
    # lockstep resubmission coalesces every round into one dispatch
    assert stats.dispatches == R
    assert stats.full_waves == R
    # coalescing must not cross-wire actors: each actor's stream equals its
    # own direct act_phase rollout chain
    for t in range(K):
        sl = slices[t]
        for r in range(R):
            sl, ref_block, _ = phases.act_phase(cfg, env, agent, params, sl, t)
            np.testing.assert_allclose(
                np.asarray(results[t][r].priorities),
                np.asarray(ref_block.priorities), rtol=1e-5, atol=1e-6)


def test_short_wave_padding_matches_direct_act():
    """A lone request in a max_batch=3 server rides a padded wave; the
    padding lanes' duplicate rollouts must be dropped, not returned."""
    K = 3
    cfg, env, agent, slices, params, store, server = _setup(K)
    server.warm(slices[0])
    server.start()
    try:
        out = server.act(slices[1], 1)   # single submit: wave of 1, pad 2
        assert out is not None
        new_slice, block, metrics = out
        stats = server.snapshot()
        assert stats.dispatches >= 1
        assert stats.full_waves == 0     # it was a short wave
        ref_slice, ref_block, _ = phases.act_phase(cfg, env, agent, params,
                                                   slices[1], 1)
        np.testing.assert_allclose(np.asarray(block.priorities),
                                   np.asarray(ref_block.priorities),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(block.items["obs"]),
                                      np.asarray(ref_block.items["obs"]))
        np.testing.assert_array_equal(np.asarray(new_slice.obs),
                                      np.asarray(ref_slice.obs))
        # the result is the actor's own lane, not a padding replica: a
        # different actor through the same short-wave path also matches
        # *its own* direct rollout (distinct rng/eps lane)
        other = server.act(slices[0], 0)
        assert other is not None
        _, other_ref, _ = phases.act_phase(cfg, env, agent, params,
                                           slices[0], 0)
        np.testing.assert_allclose(np.asarray(other[1].priorities),
                                   np.asarray(other_ref.priorities),
                                   rtol=1e-5, atol=1e-6)
    finally:
        server.stop()
    assert server.error is None


def test_slots_mode_matches_direct_act():
    """Slot scheduling admits without a coalesce window; per-actor numerics
    must still equal the actor's own direct act_phase rollout chain."""
    K, R = 3, 6
    cfg, env, agent, slices, params, store, server = _setup(K, mode="slots")
    server.warm(slices[0])
    server.start()
    results = [[] for _ in range(K)]
    try:
        def worker(t):
            sl = slices[t]
            for _ in range(R):
                out = server.act(sl, t)
                assert out is not None
                sl, block, _ = out
                results[t].append(block)

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in range(K)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120.0)
            assert not th.is_alive()
    finally:
        server.stop()
    assert server.error is None
    stats = server.snapshot()
    assert stats.requests == K * R
    for t in range(K):
        sl = slices[t]
        for r in range(R):
            sl, ref_block, _ = phases.act_phase(cfg, env, agent, params, sl, t)
            np.testing.assert_allclose(
                np.asarray(results[t][r].priorities),
                np.asarray(ref_block.priorities), rtol=1e-5, atol=1e-6)


def test_wave_and_slots_bit_identical_on_full_wave():
    """A full wave carries the exact same stacked content through the same
    compiled function in either mode — per-actor results are bit-identical,
    not merely close (the acceptance property for switching the runner's
    default scheduler)."""
    K = 3
    _, _, _, slices_w, _, _, wave_srv = _setup(K, coalesce_s=30.0)
    _, _, _, slices_s, _, _, slot_srv = _setup(K, mode="slots")
    try:
        wave_out = _collect_full_wave(wave_srv, slices_w, K)
        slot_out = _collect_full_wave(slot_srv, slices_s, K)
    finally:
        wave_srv.stop()
        slot_srv.stop()
    assert wave_srv.error is None and slot_srv.error is None
    assert wave_srv.snapshot().full_waves == 1
    for t in range(K):
        assert wave_out[t] is not None and slot_out[t] is not None
        w_slice, w_block, _ = wave_out[t]
        s_slice, s_block, _ = slot_out[t]
        for wl, sl in zip(jax.tree.leaves(w_slice), jax.tree.leaves(s_slice)):
            np.testing.assert_array_equal(_raw(wl), _raw(sl))
        np.testing.assert_array_equal(np.asarray(w_block.priorities),
                                      np.asarray(s_block.priorities))
        for wl, sl in zip(jax.tree.leaves(w_block.items),
                          jax.tree.leaves(s_block.items)):
            np.testing.assert_array_equal(_raw(wl), _raw(sl))


def test_hot_swap_under_version_churn_zero_drops():
    """Slot mode under param churn: every request completes (none dropped,
    none None), swaps land only at dispatch boundaries, and the engine ends
    on the latest published version."""
    K, R = 2, 10
    cfg, env, agent, slices, params, store, server = _setup(K, mode="slots")
    server.warm(slices[0])
    server.start()
    served = [0] * K
    stop_churn = threading.Event()

    def churner():
        rng = jax.random.key(99)
        while not stop_churn.is_set():
            rng, sub = jax.random.split(rng)
            store.publish(agent.init(sub, slices[0].obs[:1]))
            time.sleep(0.002)

    churn = threading.Thread(target=churner, daemon=True)
    churn.start()
    try:
        def worker(t):
            sl = slices[t]
            for _ in range(R):
                out = server.act(sl, t)
                assert out is not None, "request dropped during hot swap"
                sl, _, _ = out
                served[t] += 1

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in range(K)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120.0)
            assert not th.is_alive()
    finally:
        stop_churn.set()
        churn.join(timeout=10.0)
        server.stop()
    assert server.error is None
    assert served == [R] * K
    stats = server.snapshot()
    assert stats.requests == K * R
    assert stats.hot_swaps >= 1          # churn was actually observed
    assert stats.hot_swaps <= stats.dispatches  # only at dispatch boundaries
    # the engine's snapshot converged onto a published version
    assert server._snap.version <= store.version


def test_stop_wakes_parked_client_immediately():
    """act() parks on its event, not a poll loop: stop() must wake a parked
    client well inside any poll quantum."""
    K = 2
    _, _, _, slices, _, _, server = _setup(K, coalesce_s=30.0)
    server.warm(slices[0])
    server.start()
    woke = {}

    def worker():
        woke["result"] = server.act(slices[0], 0)
        woke["at"] = time.monotonic()

    th = threading.Thread(target=worker, daemon=True)
    th.start()
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        with server._cond:
            if server._pending:
                break
        time.sleep(0.005)
    t0 = time.monotonic()
    server.stop()
    th.join(timeout=5.0)
    assert not th.is_alive()
    assert woke["result"] is None
    assert woke["at"] - t0 < 2.0  # event-direct, not a timeout poll expiring


def test_clean_stop_while_actors_blocked():
    """Actors parked inside act() when the server stops must wake up with
    None (the runner's stop signal), not hang or crash."""
    K = 3
    cfg, env, agent, slices, params, store, server = _setup(
        K, coalesce_s=30.0)  # a wave never fills: requests park server-side
    server.warm(slices[0])
    server.start()
    results = {}

    def worker(t):
        results[t] = server.act(slices[t], t)

    # Only K-1 actors submit, so the wave waits for a straggler that never
    # comes and the coalescing window (30s) far outlives the test.
    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(K - 1)]
    for th in threads:
        th.start()
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        with server._cond:
            if len(server._pending) == K - 1:
                break
        time.sleep(0.005)
    with server._cond:
        assert len(server._pending) == K - 1  # genuinely parked
    server.stop()
    for th in threads:
        th.join(timeout=30.0)
        assert not th.is_alive()
    assert server.error is None
    assert all(results[t] is None for t in range(K - 1))
    # a submit after stop() returns None immediately as well
    assert server.act(slices[K - 1], K - 1) is None
