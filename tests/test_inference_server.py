"""InferenceServer beyond the smoke test: wave coalescing under sustained
concurrent submits, short-wave padding correctness, and clean stop while
actors are parked inside ``act()``."""

import dataclasses
import threading
import time

import jax
import numpy as np
from _apex_helpers import init_actor, tiny_preset

from repro.runtime import InferenceServer, ParamStore, phases


def _setup(num_actors: int, coalesce_s: float = 0.002):
    preset = tiny_preset()
    cfg = dataclasses.replace(preset.apex, num_shards=num_actors)
    env, agent = preset.env, preset.agent
    slices = [init_actor(cfg, env, jax.random.key(t))[0]
              for t in range(num_actors)]
    params = agent.init(jax.random.key(7), slices[0].obs[:1])
    store = ParamStore(params)
    server = InferenceServer(cfg, env, agent, store, max_batch=num_actors,
                             coalesce_s=coalesce_s)
    return cfg, env, agent, slices, params, store, server


def test_wave_coalescing_under_concurrent_resubmits():
    """K actors resubmitting in lockstep for R rounds must coalesce: far
    fewer dispatches than requests, with full waves the steady state."""
    K, R = 3, 8
    cfg, env, agent, slices, params, store, server = _setup(K)
    server.warm(slices[0])   # compile before the clock matters
    server.start()
    results = [[] for _ in range(K)]
    barrier = threading.Barrier(K)
    try:
        def worker(t):
            sl = slices[t]
            for _ in range(R):
                barrier.wait(timeout=60.0)  # resubmit together: full waves
                out = server.act(sl, t)
                assert out is not None
                sl, block, _ = out
                results[t].append(block)

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in range(K)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120.0)
            assert not th.is_alive()
    finally:
        server.stop()
    assert server.error is None
    stats = server.snapshot()
    assert stats.requests == K * R
    # lockstep resubmission coalesces every round into one dispatch
    assert stats.dispatches == R
    assert stats.full_waves == R
    # coalescing must not cross-wire actors: each actor's stream equals its
    # own direct act_phase rollout chain
    for t in range(K):
        sl = slices[t]
        for r in range(R):
            sl, ref_block, _ = phases.act_phase(cfg, env, agent, params, sl, t)
            np.testing.assert_allclose(
                np.asarray(results[t][r].priorities),
                np.asarray(ref_block.priorities), rtol=1e-5, atol=1e-6)


def test_short_wave_padding_matches_direct_act():
    """A lone request in a max_batch=3 server rides a padded wave; the
    padding lanes' duplicate rollouts must be dropped, not returned."""
    K = 3
    cfg, env, agent, slices, params, store, server = _setup(K)
    server.warm(slices[0])
    server.start()
    try:
        out = server.act(slices[1], 1)   # single submit: wave of 1, pad 2
        assert out is not None
        new_slice, block, metrics = out
        stats = server.snapshot()
        assert stats.dispatches >= 1
        assert stats.full_waves == 0     # it was a short wave
        ref_slice, ref_block, _ = phases.act_phase(cfg, env, agent, params,
                                                   slices[1], 1)
        np.testing.assert_allclose(np.asarray(block.priorities),
                                   np.asarray(ref_block.priorities),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(block.items["obs"]),
                                      np.asarray(ref_block.items["obs"]))
        np.testing.assert_array_equal(np.asarray(new_slice.obs),
                                      np.asarray(ref_slice.obs))
        # the result is the actor's own lane, not a padding replica: a
        # different actor through the same short-wave path also matches
        # *its own* direct rollout (distinct rng/eps lane)
        other = server.act(slices[0], 0)
        assert other is not None
        _, other_ref, _ = phases.act_phase(cfg, env, agent, params,
                                           slices[0], 0)
        np.testing.assert_allclose(np.asarray(other[1].priorities),
                                   np.asarray(other_ref.priorities),
                                   rtol=1e-5, atol=1e-6)
    finally:
        server.stop()
    assert server.error is None


def test_clean_stop_while_actors_blocked():
    """Actors parked inside act() when the server stops must wake up with
    None (the runner's stop signal), not hang or crash."""
    K = 3
    cfg, env, agent, slices, params, store, server = _setup(
        K, coalesce_s=30.0)  # a wave never fills: requests park server-side
    server.warm(slices[0])
    server.start()
    results = {}

    def worker(t):
        results[t] = server.act(slices[t], t)

    # Only K-1 actors submit, so the wave waits for a straggler that never
    # comes and the coalescing window (30s) far outlives the test.
    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(K - 1)]
    for th in threads:
        th.start()
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        with server._cond:
            if len(server._pending) == K - 1:
                break
        time.sleep(0.005)
    with server._cond:
        assert len(server._pending) == K - 1  # genuinely parked
    server.stop()
    for th in threads:
        th.join(timeout=30.0)
        assert not th.is_alive()
    assert server.error is None
    assert all(results[t] is None for t in range(K - 1))
    # a submit after stop() returns None immediately as well
    assert server.act(slices[K - 1], K - 1) is None
