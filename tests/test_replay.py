"""Prioritized replay behaviour: adds, sampling, priority updates, both
eviction strategies, IS weights (paper §3/§4.1/Appendix D/F)."""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import priority as prio, replay, sumtree

CFG = replay.ReplayConfig(capacity=64, soft_capacity=48, min_fill=4)


def make_items(n, base=0):
    return {"x": jnp.arange(base, base + n, dtype=jnp.float32),
            "y": jnp.ones((n, 3), jnp.int32)}


def test_add_and_sample_roundtrip():
    state = replay.init(CFG, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    state = replay.add_fifo(CFG, state, make_items(10), jnp.ones(10))
    assert int(state.size) == 10
    batch = replay.sample(CFG, state, jax.random.key(0), 8)
    assert batch.items["x"].shape == (8,)
    assert np.all(np.asarray(batch.indices) < 10)
    assert np.all(np.asarray(batch.is_weights) > 0)
    assert np.all(np.asarray(batch.is_weights) <= 1.0 + 1e-6)


def test_add_respects_valid_mask():
    state = replay.init(CFG, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    valid = jnp.array([True, False, True, False])
    state = replay.add_fifo(CFG, state, make_items(4), jnp.ones(4), valid)
    assert int(state.size) == 2
    assert int(state.total_added) == 2


def test_set_priorities_changes_distribution():
    state = replay.init(CFG, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    state = replay.add_fifo(CFG, state, make_items(16), jnp.full(16, 0.01))
    state = replay.set_priorities(CFG, state, jnp.array([5]), jnp.array([100.0]))
    idx = np.asarray(replay.sample(CFG, state, jax.random.key(1), 64).indices)
    assert (idx == 5).mean() > 0.5  # slot 5 dominates the mass


def test_fifo_eviction_removes_oldest():
    state = replay.init(CFG, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    state = replay.add_fifo(CFG, state, make_items(60), jnp.ones(60))
    assert int(state.size) == 60
    state = replay.evict_fifo(CFG, state)
    assert int(state.size) == CFG.soft_cap
    # oldest 12 slots zeroed
    leaves = np.asarray(sumtree.leaves(state.tree))
    assert (leaves[:12] == 0).all()
    assert (leaves[12:60] > 0).all()


def test_prioritized_eviction_prefers_low_priority():
    cfg = replay.ReplayConfig(capacity=64, soft_capacity=32, min_fill=4)
    state = replay.init(cfg, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    # half low priority, half high
    prios = jnp.concatenate([jnp.full(24, 0.01), jnp.full(24, 10.0)])
    state = replay.add_alloc(cfg, state, make_items(48), prios)
    before = np.asarray(sumtree.leaves(state.tree)) > 0
    state = replay.evict_prioritized(cfg, state, jax.random.key(0), 16)
    after = np.asarray(sumtree.leaves(state.tree)) > 0
    evicted = before & ~after
    # alpha_evict < 0 => low-priority slots evicted far more often
    assert evicted[:24].sum() > evicted[24:48].sum()


def test_alloc_reuses_freed_slots():
    cfg = replay.ReplayConfig(capacity=16, soft_capacity=12, min_fill=1)
    state = replay.init(cfg, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    state = replay.add_alloc(cfg, state, make_items(16), jnp.ones(16))
    assert int(state.size) == 16
    state = replay.evict_prioritized(cfg, state, jax.random.key(0), 8)
    freed = 16 - int(state.size)
    assert freed > 0
    state2 = replay.add_alloc(cfg, state, make_items(freed, base=100), jnp.ones(freed))
    assert int(state2.size) == 16


def test_alloc_overflow_drops_instead_of_clobbering_live_slots():
    """A block larger than the free-slot count must not overwrite live
    experience: overflow lanes are dropped (only eviction frees live slots)."""
    cfg = replay.ReplayConfig(capacity=16, soft_capacity=12, min_fill=1)
    state = replay.init(cfg, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    state = replay.add_alloc(cfg, state, make_items(12), jnp.full(12, 2.0))
    before_x = np.asarray(state.storage["x"]).copy()
    before_leaves = np.asarray(sumtree.leaves(state.tree)).copy()
    # 4 free slots, 10-lane block: 4 applied, 6 overflow lanes dropped.
    state = replay.add_alloc(cfg, state, make_items(10, base=100),
                             jnp.full(10, 9.0))
    assert int(state.size) == 16
    assert int(state.total_added) == 12 + 4
    x = np.asarray(state.storage["x"])
    leaves = np.asarray(sumtree.leaves(state.tree))
    # the 12 live slots kept their items and priorities
    np.testing.assert_array_equal(x[:12], before_x[:12])
    np.testing.assert_allclose(leaves[:12], before_leaves[:12])
    # the 4 free slots got the first 4 lanes of the new block
    np.testing.assert_array_equal(x[12:16], np.arange(100, 104, dtype=np.float32))
    # a completely full buffer drops the whole block
    state2 = replay.add_alloc(cfg, state, make_items(8, base=500),
                              jnp.full(8, 1.0))
    assert int(state2.size) == 16
    np.testing.assert_array_equal(np.asarray(state2.storage["x"]), x)


def test_is_weights_uniform_priorities_are_one():
    state = replay.init(CFG, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    state = replay.add_fifo(CFG, state, make_items(32), jnp.ones(32))
    w = replay.sample(CFG, state, jax.random.key(2), 16).is_weights
    np.testing.assert_allclose(np.asarray(w), 1.0, rtol=1e-5)


def test_min_fill_gate():
    state = replay.init(CFG, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    assert not bool(replay.can_sample(CFG, state))
    state = replay.add_fifo(CFG, state, make_items(4), jnp.ones(4))
    assert bool(replay.can_sample(CFG, state))


# --- fused ingest: kernel path bit-identical to the three-dispatch path -----

@contextlib.contextmanager
def pinned_backend(name):
    """Pin the sum-tree hot-op backend, restoring whatever override was in
    effect before (the CI matrix legs seed one via REPRO_SUMTREE_BACKEND)."""
    saved = sumtree._backend
    sumtree.set_backend(name)
    try:
        yield
    finally:
        sumtree.set_backend(saved)


def assert_replay_states_identical(got, want):
    np.testing.assert_array_equal(np.asarray(got.tree), np.asarray(want.tree))
    for k in want.storage:
        np.testing.assert_array_equal(np.asarray(got.storage[k]),
                                      np.asarray(want.storage[k]), err_msg=k)
    for field in ("write_pos", "size", "total_added"):
        assert int(getattr(got, field)) == int(getattr(want, field)), field


@settings(max_examples=15, deadline=None)
@given(
    mode=st.sampled_from(["fifo", "alloc"]),
    batch=st.integers(1, 24),
    prefill=st.integers(0, 32),
    seed=st.integers(0, 10**6),
)
def test_fused_ingest_bit_identical(mode, batch, prefill, seed):
    """add_fifo/add_alloc through the fused Pallas ingest kernel (interpret
    on CPU) must be bit-identical to the unfused XLA three-dispatch path —
    across wrap-around, duplicate slots, overflow lanes and valid masks."""
    cfg = replay.ReplayConfig(capacity=32, soft_capacity=24, min_fill=1)
    state = replay.init(cfg, {"x": jnp.zeros(()),
                              "y": jnp.zeros((3,), jnp.int32)})
    rng = np.random.RandomState(seed)
    add = replay.add_fifo if mode == "fifo" else replay.add_alloc
    with pinned_backend("xla"):
        if prefill:  # moves write_pos / consumes free slots before the probe
            state = add(cfg, state, make_items(prefill),
                        jnp.asarray(rng.uniform(0.1, 5.0, prefill),
                                    jnp.float32))
    pr = jnp.asarray(rng.uniform(0.0, 5.0, batch), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=batch) > 0.3)
    items = make_items(batch, base=1000)
    with pinned_backend("xla"):
        want = add(cfg, state, items, pr, valid)
    with pinned_backend("interpret"):
        got = add(cfg, state, items, pr, valid)
    assert_replay_states_identical(got, want)


@pytest.mark.parametrize("mode", ["fifo", "alloc"])
def test_fused_ingest_full_capacity_add(mode):
    """A block exactly the size of the buffer, onto an empty state and onto
    a full one: fifo wraps/overwrites everything, alloc drops every overflow
    lane — both bit-identical to the unfused path."""
    cap = 32
    cfg = replay.ReplayConfig(capacity=cap, soft_capacity=24, min_fill=1)
    empty = replay.init(cfg, {"x": jnp.zeros(()),
                              "y": jnp.zeros((3,), jnp.int32)})
    add = replay.add_fifo if mode == "fifo" else replay.add_alloc
    pr = jnp.linspace(0.1, 5.0, cap, dtype=jnp.float32)
    with pinned_backend("xla"):
        full_w = add(cfg, empty, make_items(cap), pr)
        again_w = add(cfg, full_w, make_items(cap, base=500), pr)
    with pinned_backend("interpret"):
        full_g = add(cfg, empty, make_items(cap), pr)
        again_g = add(cfg, full_g, make_items(cap, base=500), pr)
    assert_replay_states_identical(full_g, full_w)
    assert_replay_states_identical(again_g, again_w)
    if mode == "alloc":  # every lane of the second block dropped
        assert int(again_g.total_added) == cap


def test_fused_alloc_overflow_drops_on_kernel_path():
    """The overflow sentinel (idx == C) must drop inside the kernel too —
    live slots (slot 0 in particular) keep their rows and leaves."""
    cfg = replay.ReplayConfig(capacity=16, soft_capacity=12, min_fill=1)
    state = replay.init(cfg, {"x": jnp.zeros(()),
                              "y": jnp.zeros((3,), jnp.int32)})
    with pinned_backend("interpret"):
        state = replay.add_alloc(cfg, state, make_items(12),
                                 jnp.full(12, 2.0))
        before_x = np.asarray(state.storage["x"]).copy()
        # 4 free slots, 10-lane block: 4 applied, 6 overflow lanes dropped.
        state = replay.add_alloc(cfg, state, make_items(10, base=100),
                                 jnp.full(10, 9.0))
    assert int(state.size) == 16
    x = np.asarray(state.storage["x"])
    np.testing.assert_array_equal(x[:12], before_x[:12])
    np.testing.assert_array_equal(x[12:16],
                                  np.arange(100, 104, dtype=np.float32))


@settings(max_examples=20, deadline=None)
@given(
    n_adds=st.integers(1, 5),
    batch=st.integers(1, 16),
    seed=st.integers(0, 1000),
)
def test_size_and_mass_invariants(n_adds, batch, seed):
    """size == live leaves; total == sum of leaves; sampled idx always live."""
    cfg = replay.ReplayConfig(capacity=128, soft_capacity=96, min_fill=1)
    state = replay.init(cfg, {"x": jnp.zeros(()), "y": jnp.zeros((3,), jnp.int32)})
    rng = np.random.RandomState(seed)
    for i in range(n_adds):
        pr = jnp.asarray(rng.uniform(0.1, 5.0, batch), jnp.float32)
        state = replay.add_fifo(cfg, state, make_items(batch, base=i * 100), pr)
        state = replay.evict_fifo(cfg, state)
    leaves = np.asarray(sumtree.leaves(state.tree))
    assert int(state.size) == int((leaves > 0).sum())
    assert float(sumtree.total(state.tree)) == pytest.approx(leaves.sum(), rel=1e-4)
    if replay.can_sample(cfg, state):
        idx = np.asarray(replay.sample(cfg, state, jax.random.key(seed), 8).indices)
        assert (leaves[idx] > 0).all()
