"""Shared scaffolding for runtime/fabric tests: a CPU-tiny Ape-X DQN preset
and builders for actor slices / transition blocks / replay item examples.

(The shard-scaling benchmark keeps its own jitted block builder — bench code
must not depend on test scaffolding and needs ``block_until_ready`` timing.)
"""

import jax
import jax.numpy as jnp

from repro.configs import apex_dqn
from repro.core import apex, replay as replay_lib
from repro.core.agents import DQNAgent
from repro.envs.synthetic import ChainWorld, batch_reset
from repro.models.qnetworks import DuelingDQN
from repro.runtime import phases


def tiny_preset(min_fill=32, batch_size=16, capacity=512, evict_interval=10):
    env = ChainWorld(length=6, max_steps=16)
    agent = DQNAgent(net=DuelingDQN(num_actions=env.num_actions,
                                    mlp_hidden=(16,), head_hidden=16),
                     grad_clip=40.0)
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=capacity, min_fill=min_fill),
        lanes_per_shard=4, num_shards=1, rollout_len=8, n_step=3,
        batch_size=batch_size, learner_steps_per_iter=1, param_sync_period=2,
        target_update_period=10, evict_interval=evict_interval,
        eps_base=0.4, eps_alpha=7.0)
    return apex_dqn.ApexDQNPreset(apex=cfg, env=env, agent=agent,
                                  learning_rate=1e-3)


def init_actor(cfg, env, rng):
    env_state, obs = batch_reset(env, rng, cfg.lanes_per_shard)
    return phases.ActorSlice(
        env_state=env_state, obs=obs,
        ep_return=jnp.zeros((cfg.lanes_per_shard,), jnp.float32),
        rng=jax.random.fold_in(rng, 1), frames=jnp.zeros((), jnp.int32)), obs


def make_block(cfg, env, agent, seed=0):
    aslice, obs = init_actor(cfg, env, jax.random.key(seed))
    params = agent.init(jax.random.key(seed + 1), obs[:1])
    _, block, _ = phases.act_phase(cfg, env, agent, params, aslice, 0)
    return block


def item_example(env):
    _, obs = batch_reset(env, jax.random.key(9), 1)
    return phases.item_example(env, obs)
