"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward and one train step on CPU, asserting output
shapes and finiteness. The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import steps as steps_lib
from repro.models import registry, transformer
from repro.optim import optimizers as optim

ARCHS = list(registry.ARCH_IDS)


def _batch_for(cfg, B=2, S=32):
    rng = jax.random.key(1)
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeddings"] = jax.random.normal(rng, (B, S, cfg.d_model),
                                                jnp.float32)
    elif cfg.input_mode == "mixed":
        p = 8
        batch["prefix_embeddings"] = jax.random.normal(
            rng, (B, p, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(rng, (B, S - p), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch["labels"] = labels.at[:, -1].set(-1)
    batch["is_weights"] = jnp.ones((B,), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = registry.get_config(arch).reduced()
    params = transformer.init(cfg, jax.random.key(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    logits = transformer.apply(
        params, batch.get("tokens"), cfg=cfg,
        embeddings=batch.get("embeddings"),
        prefix_embeddings=batch.get("prefix_embeddings"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_reduces_loss(arch):
    """One prioritized train step: loss finite, params change, priorities out."""
    cfg = registry.get_config(arch).reduced()
    params = transformer.init(cfg, jax.random.key(0))
    optimizer = optim.adamw(1e-3)
    opt_state = optimizer.init(params)
    step = jax.jit(steps_lib.make_train_step(cfg, optimizer))
    batch = _batch_for(cfg)
    p1, o1, prios, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert prios.shape == (2,)
    assert bool(jnp.all(prios > 0))
    # params actually moved
    diff = sum(float(jnp.abs(a - b).sum())
               for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert diff > 0
    # a second step on the same batch shrinks the loss (sanity, not rigor)
    _, _, _, m2 = step(p1, o1, batch)
    assert float(m2["loss"]) < float(metrics["loss"])


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not registry.get_config(a).encoder_only])
def test_serve_step_shapes(arch):
    cfg = registry.get_config(arch).reduced()
    params = transformer.init(cfg, jax.random.key(0))
    B, S_max = 2, 16
    cache = transformer.init_cache(cfg, B, S_max)
    serve = jax.jit(steps_lib.make_serve_step(cfg))
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(3):
        tok, cache = serve(params, cache, tok, jnp.asarray(pos))
        assert tok.shape == (B, 1)
        assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))


def test_registry_combos_cover_assignment():
    combos = list(registry.combos(include_skipped=True))
    assert len(combos) == 40  # 10 archs x 4 shapes
    skipped = [(a, s, w) for a, s, ok, w in combos if not ok]
    # exactly the documented skips: hubert decode shapes + long_500k for pure
    # full-attention archs
    skipped_names = {(a, s) for a, s, _ in skipped}
    assert ("hubert-xlarge", "decode_32k") in skipped_names
    assert ("hubert-xlarge", "long_500k") in skipped_names
    for dense_full in ("stablelm-1.6b", "granite-3-8b", "llama3.2-1b",
                       "internvl2-2b", "phi3.5-moe-42b-a6.6b",
                       "deepseek-v2-236b"):
        assert (dense_full, "long_500k") in skipped_names
    for runs_long in ("h2o-danube-1.8b", "zamba2-2.7b", "rwkv6-1.6b"):
        assert (runs_long, "long_500k") not in skipped_names
    assert len(skipped) == 8


def test_moe_grouped_dispatch_matches_global():
    """§Perf iteration 5: shard-local dispatch must be numerically identical
    to global dispatch when capacity is ample."""
    import dataclasses
    import numpy as np
    from repro.models.layers import moe_apply, moe_init

    cfg = registry.get_config("phi3.5-moe-42b-a6.6b").reduced()
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y1, a1 = moe_apply(p, cfg, x)
    cfg4 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=4))
    y4, a4 = moe_apply(p, cfg4, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=2e-4, atol=2e-4)
    assert abs(float(a1 - a4)) < 1e-6
