"""Async runtime: shared phases, snapshot store, replay-service queue
behaviour (backpressure + starvation), ingest staging, and an end-to-end
decoupled run."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from _apex_helpers import init_actor, item_example, make_block, tiny_preset

from repro.core import apex, replay as replay_lib
from repro.runtime import (AsyncConfig, ParamStore, ReplayService, phases,
                           run_async)
from repro.runtime.sources import BlockStager

# CI matrix leg: REPRO_TEST_INGEST_STAGING=1 re-runs the end-to-end test
# with the pipelined ingest stager attached (pass-through puts on CPU, so
# it exercises the stage-ahead ordering, not the DMA).
INGEST_STAGING = bool(os.environ.get("REPRO_TEST_INGEST_STAGING"))
# CI matrix leg: REPRO_TEST_METRICS_DIR=<dir> re-runs the end-to-end test
# with the telemetry plane enabled (JSONL sink + full-rate tracing), and
# CI uploads the resulting metrics/spans JSONL as a workflow artifact.
METRICS_DIR = os.environ.get("REPRO_TEST_METRICS_DIR") or None
# CI matrix leg: REPRO_TEST_INFERENCE_MODE=slots re-runs the end-to-end
# test with the shared inference engine in slot-scheduled continuous-
# batching mode (wave also accepted; empty = per-thread dispatch).
INFERENCE_MODE = os.environ.get("REPRO_TEST_INFERENCE_MODE") or None


# --- shared phases ----------------------------------------------------------

def test_act_phase_block_shape_and_frames():
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    aslice, obs = init_actor(cfg, env, jax.random.key(0))
    params = agent.init(jax.random.key(1), obs[:1])
    new_slice, block, metrics = phases.act_phase(cfg, env, agent, params,
                                                 aslice, 0)
    n_transitions = cfg.lanes_per_shard * cfg.window
    assert block.priorities.shape == (n_transitions,)
    assert block.items["obs"].shape[0] == n_transitions
    assert int(new_slice.frames) == cfg.lanes_per_shard * cfg.rollout_len
    assert bool(jnp.all(block.priorities >= 0))
    assert "mean_ep_return" in metrics


def test_sync_driver_composes_shared_phases():
    """apex.actor_phase == act_phase + replay_add on identical state, so the
    lockstep driver and the async runtime can never drift apart."""
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    opt = preset.make_optimizer()
    state = apex.init_state(cfg, env, agent, opt, jax.random.key(0))

    via_driver, _ = apex.actor_phase(cfg, env, agent, state, 0)

    aslice = phases.ActorSlice(env_state=state.env_state, obs=state.obs,
                               ep_return=state.ep_return, rng=state.rng,
                               frames=state.frames)
    aslice2, block, _ = phases.act_phase(cfg, env, agent, state.actor_params,
                                         aslice, 0)
    replay2 = phases.replay_add(cfg, state.replay, block)

    np.testing.assert_allclose(np.asarray(via_driver.replay.tree),
                               np.asarray(replay2.tree), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(via_driver.obs),
                                  np.asarray(aslice2.obs))
    assert int(via_driver.frames) == int(aslice2.frames)


def test_learn_phase_steps_and_priorities():
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    opt = preset.make_optimizer()
    aslice, obs = init_actor(cfg, env, jax.random.key(0))
    params = agent.init(jax.random.key(1), obs[:1])
    lslice = phases.LearnerSlice(
        params=params, target_params=jax.tree.map(jnp.copy, params),
        opt_state=opt.init(params), learner_step=jnp.zeros((), jnp.int32))
    _, block, _ = phases.act_phase(cfg, env, agent, params, aslice, 0)
    items = jax.tree.map(lambda x: x[:cfg.batch_size], block.items)
    w = jnp.ones((cfg.batch_size,), jnp.float32)
    new_lslice, prios, metrics = phases.learn_phase(cfg, agent, opt, lslice,
                                                    items, w)
    assert int(new_lslice.learner_step) == 1
    assert prios.shape == (cfg.batch_size,)
    assert bool(jnp.all(jnp.isfinite(prios)))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    diff = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree.map(lambda a, b: a - b, new_lslice.params, lslice.params), 0.0)
    assert diff > 0


# --- param store ------------------------------------------------------------

def test_param_store_versioning():
    store = ParamStore({"w": jnp.zeros((2,))})
    assert store.version == 0
    v1 = store.publish({"w": jnp.ones((2,))})
    assert v1 == 1 and store.version == 1
    snap = store.get()
    assert snap.version == 1
    assert float(snap.params["w"][0]) == 1.0


def test_param_store_concurrent_reads_never_torn():
    """Readers must always see a snapshot whose version matches its payload."""
    store = ParamStore(jnp.zeros((4,)) + 0.0)
    stop = threading.Event()
    errors = []

    def reader():
        while not stop.is_set():
            snap = store.get()
            if float(snap.params[0]) != float(snap.version):
                errors.append((snap.version, float(snap.params[0])))

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for v in range(1, 200):
        store.publish(jnp.zeros((4,)) + float(v))
    stop.set()
    for t in threads:
        t.join()
    assert not errors


# --- replay service queue paths ---------------------------------------------

def empty_replay(cfg, env):
    return replay_lib.init(cfg.replay, item_example(env))


def test_actor_backpressure_when_service_stalled():
    """With the owner thread not running, the bounded add queue fills and
    further adds report backpressure instead of growing memory."""
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    service = ReplayService(cfg, empty_replay(cfg, env),
                            add_queue_depth=2)  # never started
    block = make_block(cfg, env, agent)
    assert service.add(block, timeout=0.01)
    assert service.add(block, timeout=0.01)
    t0 = time.monotonic()
    assert not service.add(block, timeout=0.05)   # actor would block here
    assert time.monotonic() - t0 >= 0.04          # it genuinely waited


def test_learner_starved_until_min_fill():
    """Before min-fill the sample queue stays empty (learner-starved path);
    after enough adds the service starts serving batches."""
    preset = tiny_preset(min_fill=64)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    service = ReplayService(cfg, empty_replay(cfg, env)).start()
    try:
        assert service.get_batch(timeout=0.05) is None   # starved: empty replay
        block = make_block(cfg, env, agent)              # 24 transitions
        n_blocks = 64 // int(block.priorities.shape[0]) + 1
        for _ in range(n_blocks):
            assert service.add(block, timeout=1.0)
        batch = None
        deadline = time.monotonic() + 5.0
        while batch is None and time.monotonic() < deadline:
            batch = service.get_batch(timeout=0.1)
        assert batch is not None, "service never served once min-fill passed"
        assert batch.items["obs"].shape[0] == cfg.batch_size
        assert bool(jnp.all(batch.is_weights > 0))
    finally:
        service.stop()
    assert service.stats.transitions_added >= 64
    assert service.stats.batches_sampled >= 1


def test_priority_writeback_applied_on_drain():
    """Write-backs queued before stop() are applied during the drain."""
    preset = tiny_preset(min_fill=8)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    service = ReplayService(cfg, empty_replay(cfg, env)).start()
    block = make_block(cfg, env, agent)
    assert service.add(block, timeout=1.0)
    batch = None
    deadline = time.monotonic() + 5.0
    while batch is None and time.monotonic() < deadline:
        batch = service.get_batch(timeout=0.1)
    assert batch is not None
    service.write_back(batch.indices,
                       jnp.full((cfg.batch_size,), 7.0, jnp.float32))
    service.stop()
    assert service.stats.updates_applied == 1
    assert service.learner_steps == 1


# --- ingest staging ----------------------------------------------------------

def test_block_stager_put_path_bit_identical():
    """Forcing the put path on a CPU host must still be value-preserving:
    staged leaves land on the device bitwise-equal, already-resident leaves
    pass through untouched, and the default stager passes through on CPU."""
    preset = tiny_preset()
    block = make_block(preset.apex, preset.env, preset.agent)
    host = jax.tree.map(np.asarray, block)   # gateway-style numpy leaves
    stager = BlockStager(passthrough=False)
    staged = stager.stage(host)
    assert stager.blocks_staged == 1
    for got, want in zip(jax.tree.leaves(staged), jax.tree.leaves(block)):
        assert isinstance(got, jax.Array)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # device-resident leaves are not re-put (no redundant copy/dispatch)
    again = stager.stage(staged)
    for a, b in zip(jax.tree.leaves(again), jax.tree.leaves(staged)):
        assert a is b
    default = BlockStager()                  # auto-detect: CPU passes through
    assert default.passthrough
    assert default.stage(host) is host
    assert default.blocks_staged == 0


def test_staged_shard_matches_unstaged_and_reports_h2d():
    """A shard with a (forced-put) ingest stager must produce the exact
    replay state of an unstaged shard over the same add stream, while the
    h2d_us / blocks_staged counters populate."""
    preset = tiny_preset(min_fill=10**6)     # sampler stays quiet
    cfg, env, agent = preset.apex, preset.env, preset.agent
    blocks = [make_block(cfg, env, agent, seed=s) for s in range(3)]

    def run(stager):
        svc = ReplayService(cfg, empty_replay(cfg, env), stager=stager).start()
        try:
            for b in blocks:
                assert svc.add(b, timeout=5.0)
        finally:
            svc.stop()
        return svc

    plain = run(None)
    staged = run(BlockStager(passthrough=False))
    np.testing.assert_array_equal(np.asarray(staged.replay_state.tree),
                                  np.asarray(plain.replay_state.tree))
    for got, want in zip(jax.tree.leaves(staged.replay_state.storage),
                         jax.tree.leaves(plain.replay_state.storage)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert plain.stats.blocks_staged == 0
    assert staged.stats.blocks_staged == len(blocks)
    # h2d_us is a derived view (histogram mean) — read it via snapshot().
    assert staged.snapshot().h2d_us > 0.0


def test_run_async_staged_ingest_end_to_end():
    """The pipelined staged drain (stage k+1 before applying k, flush at
    queue-dry) must preserve every end-to-end invariant."""
    preset = tiny_preset()
    acfg = AsyncConfig(actor_threads=2, total_learner_steps=4,
                       max_seconds=60.0, seed=5, ingest_staging=True)
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    assert res.stats["learner_steps"] == 4
    assert res.service_stats.updates_applied == 4
    assert res.service_stats.transitions_added == res.stats["actor_transitions"]
    # CPU host: the default stager passes through (no puts to count)
    assert res.service_stats.blocks_staged == 0


# --- end to end -------------------------------------------------------------

def test_run_async_end_to_end():
    preset = tiny_preset()
    acfg = AsyncConfig(actor_threads=2, total_learner_steps=8,
                       max_seconds=60.0, seed=3,
                       ingest_staging=INGEST_STAGING,
                       inference_batching=bool(INFERENCE_MODE),
                       inference_mode=INFERENCE_MODE or "wave",
                       metrics_dir=METRICS_DIR,
                       trace_sample_rate=1.0 if METRICS_DIR else 0.0)
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    s = res.stats
    if METRICS_DIR:
        assert os.path.exists(os.path.join(METRICS_DIR, "metrics.jsonl"))
        assert os.path.exists(os.path.join(METRICS_DIR, "spans.jsonl"))
    assert s["learner_steps"] == 8
    assert int(res.learner.learner_step) == 8
    assert s["actor_transitions"] > 0
    assert s["learner_transitions"] == 8 * preset.apex.batch_size
    assert s["param_version"] >= 1          # learner published snapshots
    assert s["replay_size"] > 0
    assert s["generate_consume_ratio"] > 0
    # every consumed batch's priorities came back to the replay service
    assert res.service_stats.updates_applied == 8
    assert res.service_stats.transitions_added == s["actor_transitions"]
