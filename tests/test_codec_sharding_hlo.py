"""Unit tests for the obs codec, the sharding rule engine, the HLO
collective parser and the dry-run probe extrapolation math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import codec
from repro.launch import hlo_analysis, mesh as mesh_lib


# --- codec -------------------------------------------------------------------

def test_codec_uint8_lossless():
    obs = jnp.arange(48, dtype=jnp.uint8).reshape(4, 12)
    enc = codec.encode(obs)
    out = codec.decode(enc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(obs, np.float32))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(0.1, 100.0))
def test_codec_float_quantization_error_bounded(seed, scale):
    rng = np.random.RandomState(seed)
    obs = jnp.asarray(scale * rng.randn(3, 17), jnp.float32)
    enc = codec.encode(obs)
    out = codec.decode(enc)
    rng_span = float(obs.max() - obs.min())
    err = float(jnp.max(jnp.abs(out - obs)))
    assert err <= rng_span / 255.0 + 1e-5  # half-step rounding bound x2


def test_codec_compression_ratio():
    obs = jnp.zeros((8, 128), jnp.float32)
    enc = codec.encode(obs)
    assert codec.storage_bytes(enc) < obs.size * 4 / 3.5   # ~4x smaller


# --- sharding rules ------------------------------------------------------------

def test_param_sharding_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_shardings
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    shapes = {
        "embed": {"w": jax.ShapeDtypeStruct((512, 64), jnp.float32)},
        "layers": {
            "mixer": {"wq": jax.ShapeDtypeStruct((2, 64, 128), jnp.float32),
                      "wo": jax.ShapeDtypeStruct((2, 128, 64), jnp.float32)},
            "mlp": {"w_gate": jax.ShapeDtypeStruct((2, 4, 64, 32), jnp.float32),
                    "router": jax.ShapeDtypeStruct((2, 64, 4), jnp.float32)},
            "pre_ln": {"scale": jax.ShapeDtypeStruct((64,), jnp.float32)},
        },
        "head": {"w": jax.ShapeDtypeStruct((64, 512), jnp.float32)},
    }
    s = param_shardings(shapes, mesh)
    assert s["embed"]["w"].spec == P("model", ("data",))
    assert s["layers"]["mixer"]["wq"].spec == P(None, ("data",), "model")
    assert s["layers"]["mixer"]["wo"].spec == P(None, "model", ("data",))
    # 4-D MoE expert tensor: experts over model
    assert s["layers"]["mlp"]["w_gate"].spec == P(None, "model", ("data",), None)
    assert s["layers"]["pre_ln"]["scale"].spec == P()
    assert s["head"]["w"].spec == P(("data",), "model")


def test_divisibility_guard_drops_axis():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_shardings
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    # 49155 (granite vocab) is not divisible by model=16 on the real mesh —
    # here model=1 divides everything, so emulate by a prime dim with a
    # fake 3-wide mesh
    mesh3 = mesh_lib.make_mesh((1, 1), ("data", "model"))
    shapes = {"embed": {"w": jax.ShapeDtypeStruct((49155, 64), jnp.float32)}}
    s = param_shardings(shapes, mesh3)
    # with axis size 1 everything divides; the guard logic itself:
    from repro.launch.sharding import _fit
    spec = _fit(("model", ("data",)), (49155, 64), mesh3)
    assert spec == P("model", ("data",))  # size-1 axes always fit


# --- HLO collective parser ------------------------------------------------------

HLO_SAMPLE = """
  %ag = f32[128,256]{1,0} all-gather(f32[8,256]{1,0} %x), dimensions={0}
  %ar.1 = bf16[1024]{0} all-reduce(bf16[1024]{0} %y), to_apply=%add
  %rs = f32[16,16]{1,0} reduce-scatter(f32[256,16]{1,0} %z), dimensions={0}
  %cp = u8[64]{0} collective-permute(u8[64]{0} %w)
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(f32[4,4]{1,0} %p, f32[4,4]{1,0} %q)
  %plain = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""


def test_parse_collectives_counts_and_bytes():
    stats = hlo_analysis.parse_collectives(HLO_SAMPLE)
    assert stats.count_by_op == {"all-gather": 1, "all-reduce": 1,
                                 "reduce-scatter": 1, "collective-permute": 1,
                                 "all-to-all": 1}
    assert stats.bytes_by_op["all-gather"] == 128 * 256 * 4
    assert stats.bytes_by_op["all-reduce"] == 1024 * 2
    assert stats.bytes_by_op["reduce-scatter"] == 16 * 16 * 4
    assert stats.bytes_by_op["collective-permute"] == 64
    assert stats.bytes_by_op["all-to-all"] == 2 * 4 * 4 * 4  # tuple summed
    assert stats.total_bytes == sum(stats.bytes_by_op.values())


def test_roofline_terms_math():
    t = hlo_analysis.roofline_terms(
        flops=1e12, hbm_bytes=1e12, collective_bytes=1e9, chips=256,
        peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9, flops_are_global=False)
    assert t["compute_s"] == pytest.approx(1e12 / 197e12)
    assert t["memory_s"] == pytest.approx(1e12 / 819e9)
    assert t["collective_s"] == pytest.approx(1e9 / 50e9)
    assert t["bottleneck"] == "memory"
    tg = hlo_analysis.roofline_terms(
        flops=1e12, hbm_bytes=1e12, collective_bytes=1e9, chips=256,
        peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9, flops_are_global=True)
    assert tg["compute_s"] == pytest.approx(1e12 / 256 / 197e12)


# --- probe extrapolation ----------------------------------------------------------

def test_probe_extrapolation_linear():
    """fixed + L*per_layer recovery from (k, 2k) samples."""
    fixed, per_layer, k, L = 7.0, 3.0, 2, 40
    c_k = fixed + k * per_layer
    c_2k = fixed + 2 * k * per_layer
    per = (c_2k - c_k) / k
    fix = c_k - k * per
    assert fix + L * per == pytest.approx(fixed + L * per_layer)
