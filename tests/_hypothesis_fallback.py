"""Degrade gracefully when ``hypothesis`` is absent.

CI installs hypothesis from ``pyproject.toml`` and runs the full property
suites. In a bare environment the import below fails, and we substitute
stand-ins: ``@given(...)`` rewraps the test so it calls
``pytest.importorskip("hypothesis")`` at run time — each property test
reports as *skipped* instead of breaking collection for the whole module —
while the deterministic tests in the same file still run.

Usage (replaces ``from hypothesis import given, settings, strategies as st``):

    from _hypothesis_fallback import given, settings, st
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning another stand-in, so decorator-time expressions
        like ``st.lists(st.floats(...), min_size=1)`` evaluate fine."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg replacement (no functools.wraps: copying the original
            # signature would make pytest hunt for fixtures named after the
            # hypothesis-drawn parameters).
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
