"""Sum-tree unit + property tests (the replay's sampling core)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import sumtree


def test_init_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        sumtree.init(48)
    with pytest.raises(ValueError):
        sumtree.rebuild(jnp.ones(3))


def test_write_and_total():
    tree = sumtree.init(8)
    tree = sumtree.write(tree, jnp.array([0, 3, 7]), jnp.array([1.0, 2.0, 3.0]))
    assert float(sumtree.total(tree)) == pytest.approx(6.0)
    np.testing.assert_allclose(
        np.asarray(sumtree.leaves(tree)),
        [1.0, 0, 0, 2.0, 0, 0, 0, 3.0])


def test_write_overwrites():
    tree = sumtree.init(4)
    tree = sumtree.write(tree, jnp.array([1]), jnp.array([5.0]))
    tree = sumtree.write(tree, jnp.array([1]), jnp.array([2.0]))
    assert float(sumtree.total(tree)) == pytest.approx(2.0)


def test_sample_deterministic_regions():
    """Offsets map to leaves by inverse CDF: leaf k covers
    [prefix(k), prefix(k)+p_k)."""
    tree = sumtree.rebuild(jnp.array([1.0, 2.0, 0.0, 3.0]))
    u = jnp.array([0.0, 0.5, 1.0, 2.5, 3.0, 5.9])
    idx = sumtree.sample(tree, u)
    np.testing.assert_array_equal(np.asarray(idx), [0, 0, 1, 1, 3, 3])


def test_zero_mass_leaf_never_sampled():
    leaves = jnp.array([1.0, 0.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0])
    tree = sumtree.rebuild(leaves)
    idx = sumtree.sample_stratified(tree, jax.random.key(0), 512)
    assert set(np.asarray(idx).tolist()) <= {0, 3, 5, 7}


@settings(max_examples=50, deadline=None)
@given(
    vals=st.lists(st.floats(min_value=0.0, max_value=100.0,
                            allow_nan=False, width=32),
                  min_size=16, max_size=16),
)
def test_sum_invariant_property(vals):
    """Every internal node equals the sum of its children after writes."""
    tree = np.asarray(sumtree.rebuild(jnp.asarray(vals, jnp.float32)))
    for i in range(1, 16):
        assert tree[i] == pytest.approx(tree[2 * i] + tree[2 * i + 1], abs=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    hot=st.integers(0, 31),
)
def test_sampling_frequency_tracks_priority(seed, hot):
    """A leaf holding 50%% of the mass is sampled ~50%% of the time."""
    leaves = np.ones(32, np.float32)
    leaves[hot] = 31.0  # half the total mass
    tree = sumtree.rebuild(jnp.asarray(leaves))
    idx = np.asarray(sumtree.sample_stratified(tree, jax.random.key(seed), 256))
    frac = (idx == hot).mean()
    assert 0.35 <= frac <= 0.65


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_sample_matches_manual_cdf(data):
    n = 8
    vals = data.draw(st.lists(
        st.floats(min_value=0.0078125, max_value=10.0, allow_nan=False,
                  width=32),
        min_size=n, max_size=n))
    u_frac = data.draw(st.floats(min_value=0.0, max_value=0.999,
                                 allow_nan=False))
    leaves = np.asarray(vals, np.float32)
    tree = sumtree.rebuild(jnp.asarray(leaves))
    total = leaves.sum()
    u = np.float32(u_frac) * total
    got = int(sumtree.sample(tree, jnp.asarray([u]))[0])
    # manual inverse CDF with the same f32 arithmetic tolerance
    cdf = np.cumsum(leaves)
    expect = int(np.searchsorted(cdf, u, side="right"))
    assert abs(got - expect) <= 1 or leaves[got] > 0
