"""Sum-tree unit + property tests (the replay's sampling core)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import sumtree


def test_init_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        sumtree.init(48)
    with pytest.raises(ValueError):
        sumtree.rebuild(jnp.ones(3))


def test_write_and_total():
    tree = sumtree.init(8)
    tree = sumtree.write(tree, jnp.array([0, 3, 7]), jnp.array([1.0, 2.0, 3.0]))
    assert float(sumtree.total(tree)) == pytest.approx(6.0)
    np.testing.assert_allclose(
        np.asarray(sumtree.leaves(tree)),
        [1.0, 0, 0, 2.0, 0, 0, 0, 3.0])


def test_write_overwrites():
    tree = sumtree.init(4)
    tree = sumtree.write(tree, jnp.array([1]), jnp.array([5.0]))
    tree = sumtree.write(tree, jnp.array([1]), jnp.array([2.0]))
    assert float(sumtree.total(tree)) == pytest.approx(2.0)


def test_sample_deterministic_regions():
    """Offsets map to leaves by inverse CDF: leaf k covers
    [prefix(k), prefix(k)+p_k)."""
    tree = sumtree.rebuild(jnp.array([1.0, 2.0, 0.0, 3.0]))
    u = jnp.array([0.0, 0.5, 1.0, 2.5, 3.0, 5.9])
    idx = sumtree.sample(tree, u)
    np.testing.assert_array_equal(np.asarray(idx), [0, 0, 1, 1, 3, 3])


def test_zero_mass_leaf_never_sampled():
    leaves = jnp.array([1.0, 0.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0])
    tree = sumtree.rebuild(leaves)
    idx = sumtree.sample_stratified(tree, jax.random.key(0), 512)
    assert set(np.asarray(idx).tolist()) <= {0, 3, 5, 7}


@settings(max_examples=50, deadline=None)
@given(
    vals=st.lists(st.floats(min_value=0.0, max_value=100.0,
                            allow_nan=False, width=32),
                  min_size=16, max_size=16),
)
def test_sum_invariant_property(vals):
    """Every internal node equals the sum of its children after writes."""
    tree = np.asarray(sumtree.rebuild(jnp.asarray(vals, jnp.float32)))
    for i in range(1, 16):
        assert tree[i] == pytest.approx(tree[2 * i] + tree[2 * i + 1], abs=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    hot=st.integers(0, 31),
)
def test_sampling_frequency_tracks_priority(seed, hot):
    """A leaf holding 50%% of the mass is sampled ~50%% of the time."""
    leaves = np.ones(32, np.float32)
    leaves[hot] = 31.0  # half the total mass
    tree = sumtree.rebuild(jnp.asarray(leaves))
    idx = np.asarray(sumtree.sample_stratified(tree, jax.random.key(seed), 256))
    frac = (idx == hot).mean()
    assert 0.35 <= frac <= 0.65


@settings(max_examples=50, deadline=None)
@given(
    cap_pow=st.integers(2, 7),
    n_writes=st.integers(0, 24),
    seed=st.integers(0, 2**31 - 1),
    dup=st.booleans(),
)
def test_incremental_update_bit_identical_to_rebuild(cap_pow, n_writes, seed, dup):
    """The O(B log C) incremental update must round-trip bit-identically with
    the scatter + full-rebuild oracle — including duplicate indices (last
    writer wins), zero-size write batches, and zero-valued writes."""
    cap = 1 << cap_pow
    rng = np.random.RandomState(seed)
    leaves = jnp.asarray(rng.uniform(0.0, 10.0, cap).astype(np.float32))
    tree = sumtree.rebuild(leaves)
    idx = jnp.asarray(rng.randint(0, cap, n_writes).astype(np.int32))
    if dup and n_writes >= 3:
        idx = idx.at[1].set(idx[0]).at[2].set(idx[0])  # forced duplicates
    vals = jnp.asarray(rng.uniform(0.0, 5.0, n_writes).astype(np.float32))
    if dup:
        vals = vals.at[: n_writes // 2].set(0.0)  # zero writes kill leaves
    oracle = sumtree.write_rebuild(tree, idx, vals)
    np.testing.assert_array_equal(np.asarray(sumtree.update(tree, idx, vals)),
                                  np.asarray(oracle))
    # chained updates preserve the invariant bit-exactly too
    tree2 = sumtree.update(sumtree.update(tree, idx, vals), idx, vals * 0.5)
    oracle2 = sumtree.write_rebuild(oracle, idx, vals * 0.5)
    np.testing.assert_array_equal(np.asarray(tree2), np.asarray(oracle2))


def test_incremental_update_full_capacity_write():
    """A batch covering every leaf (B == C) still matches the rebuild."""
    cap = 32
    rng = np.random.RandomState(0)
    tree = sumtree.rebuild(jnp.asarray(rng.uniform(0, 1, cap), jnp.float32))
    idx = jnp.arange(cap, dtype=jnp.int32)
    vals = jnp.asarray(rng.uniform(0, 9, cap), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(sumtree.update(tree, idx, vals)),
        np.asarray(sumtree.write_rebuild(tree, idx, vals)))


def test_update_matches_scatter_index_handling():
    """Negatives in [-C, -1] wrap numpy-style (like ``.at[idx].set``),
    anything else out of [0, C) drops — bitwise equal to the oracle."""
    tree = sumtree.rebuild(jnp.array([1.0, 2.0, 3.0, 4.0]))
    idx = jnp.array([-1, 4, -5, 1])
    vals = jnp.array([9.0, 8.0, 6.0, 7.0])
    out = sumtree.update(tree, idx, vals)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(sumtree.write_rebuild(tree, idx, vals)))
    leaves = np.asarray(sumtree.leaves(out))
    assert leaves[3] == 9.0   # -1 wrapped to C-1
    assert leaves[1] == 7.0
    np.testing.assert_array_equal(leaves[[0, 2]], [1.0, 3.0])  # 4/-5 dropped


def test_sample_with_mass_matches_two_gather():
    """Fused descent+mass must be bitwise the descent plus a leaf gather."""
    leaves = jax.random.uniform(jax.random.key(5), (64,))
    tree = sumtree.rebuild(leaves)
    u = jax.random.uniform(jax.random.key(6), (33,)) * sumtree.total(tree)
    idx, mass = sumtree.sample_with_mass(tree, u)
    ref_idx = sumtree.sample(tree, u)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
    np.testing.assert_array_equal(np.asarray(mass),
                                  np.asarray(sumtree.leaves(tree)[ref_idx]))


def test_backend_switch_interpret_matches_xla():
    """set_backend("interpret") routes write/sample through the Pallas
    kernels (interpreter on CPU) and must be bit-identical to the XLA path."""
    leaves = jax.random.uniform(jax.random.key(7), (128,))
    tree = sumtree.rebuild(leaves)
    idx = jnp.array([3, 100, 3, 77], jnp.int32)
    vals = jnp.array([0.5, 2.0, 1.5, 0.0], jnp.float32)
    u = jax.random.uniform(jax.random.key(8), (17,)) * sumtree.total(tree)
    assert sumtree.backend() == "xla"  # auto-detect off-TPU
    xla_write = sumtree.write(tree, idx, vals)
    xla_sample = sumtree.sample_with_mass(tree, u)
    sumtree.set_backend("interpret")
    try:
        assert sumtree.backend() == "interpret"
        np.testing.assert_array_equal(
            np.asarray(sumtree.write(tree, idx, vals)), np.asarray(xla_write))
        got_idx, got_mass = sumtree.sample_with_mass(tree, u)
        np.testing.assert_array_equal(np.asarray(got_idx),
                                      np.asarray(xla_sample[0]))
        np.testing.assert_array_equal(np.asarray(got_mass),
                                      np.asarray(xla_sample[1]))
    finally:
        sumtree.set_backend(None)
    with pytest.raises(ValueError):
        sumtree.set_backend("cuda")


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_sample_matches_manual_cdf(data):
    n = 8
    vals = data.draw(st.lists(
        st.floats(min_value=0.0078125, max_value=10.0, allow_nan=False,
                  width=32),
        min_size=n, max_size=n))
    u_frac = data.draw(st.floats(min_value=0.0, max_value=0.999,
                                 allow_nan=False))
    leaves = np.asarray(vals, np.float32)
    tree = sumtree.rebuild(jnp.asarray(leaves))
    total = leaves.sum()
    u = np.float32(u_frac) * total
    got = int(sumtree.sample(tree, jnp.asarray([u]))[0])
    # manual inverse CDF with the same f32 arithmetic tolerance
    cdf = np.cumsum(leaves)
    expect = int(np.searchsorted(cdf, u, side="right"))
    assert abs(got - expect) <= 1 or leaves[got] > 0
