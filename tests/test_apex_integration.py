"""End-to-end Ape-X behaviour: DQN and DPG presets run, learn, stay finite;
the distributed (shard_map) path matches the structure of the single-shard
path; staleness and ablation knobs (Fig. 6/7) work."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import apex_dpg, apex_dqn
from repro.core import apex
from repro.launch import mesh as mesh_lib


def run_preset(preset, iters, seed=0):
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(
        preset.apex, preset.env, preset.agent, optimizer)
    state = init_fn(jax.random.key(seed))
    metrics = None
    for _ in range(iters):
        state, metrics = step_fn(state)
    return state, metrics


def test_apex_dqn_reduced_runs_and_learns():
    preset = apex_dqn.reduced()
    state, metrics = run_preset(preset, 30)
    assert int(state.learner_step) > 0
    assert int(state.replay.size) > 0
    assert bool(jnp.isfinite(metrics["loss"]))
    # greedy lane should be collecting reward by now on the short chain
    assert float(metrics["frames"]) == 30 * 16 * 24


def test_apex_dqn_improves_over_training():
    """The mean episode return on ChainWorld improves with training — the
    paper's core claim at toy scale (prioritized distributed replay learns)."""
    preset = apex_dqn.reduced()
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(
        preset.apex, preset.env, preset.agent, optimizer)
    state = init_fn(jax.random.key(3))
    early, late = [], []
    for it in range(120):
        state, m = step_fn(state)
        r = float(m["mean_ep_return"])
        if not np.isnan(r):
            (early if it < 30 else late).append(r)
    assert np.mean(late[-30:]) > np.mean(early)


def test_apex_dpg_reduced_runs():
    preset = apex_dpg.reduced()
    state, metrics = run_preset(preset, 20)
    assert int(state.learner_step) > 0
    assert bool(jnp.isfinite(metrics["critic_loss"]))
    assert bool(jnp.isfinite(metrics["policy_loss"]))


def test_param_staleness_respected():
    """actor_params must lag params by up to param_sync_period iterations."""
    preset = apex_dqn.reduced()
    cfg = dataclasses.replace(preset.apex, param_sync_period=4,
                              learner_steps_per_iter=1)
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(cfg, preset.env, preset.agent,
                                          optimizer)
    state = init_fn(jax.random.key(0))
    # warm up past min_fill so the learner actually updates params
    for _ in range(10):
        state, _ = step_fn(state)
    # iteration 10 just ran; iterations 11, 12, 13 don't sync (12 % 4 == 0
    # does), so check lag exists at some point within a period
    lags = []
    for _ in range(4):
        state, _ = step_fn(state)
        d = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(state.actor_params)))
        lags.append(d)
    assert max(lags) > 0  # stale at least part of the period


def test_replicate_k_ablation_fills_replay_faster():
    """Fig. 6 knob: k-fold duplication multiplies ingest volume."""
    preset = apex_dqn.reduced()
    base = dataclasses.replace(preset.apex, learner_steps_per_iter=0)
    dup = dataclasses.replace(base, replicate_k=4)
    optimizer = preset.make_optimizer()
    for cfg, expect_mult in ((base, 1), (dup, 4)):
        init_fn, step_fn = apex.make_train_fn(cfg, preset.env, preset.agent,
                                              optimizer)
        state = init_fn(jax.random.key(0))
        state, _ = step_fn(state)
        added = int(state.replay.total_added)
        assert added == expect_mult * cfg.lanes_per_shard * cfg.window


def test_fixed_eps_set_mode():
    """Fig. 7 knob: fixed 6-value eps set instead of the full ladder."""
    preset = apex_dqn.reduced()
    cfg = dataclasses.replace(preset.apex, eps_mode="fixed_set")
    eps = np.asarray(apex.lane_epsilons(cfg, 0))
    assert len(set(np.round(eps, 6).tolist())) <= 6
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(cfg, preset.env, preset.agent,
                                          optimizer)
    state = init_fn(jax.random.key(0))
    state, m = step_fn(state)
    assert bool(jnp.isfinite(m["mean_initial_priority"]))


def test_shard_map_single_device_mesh():
    mesh = mesh_lib.make_mesh((1,), ("data",))
    preset = apex_dqn.reduced(num_shards=1)
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(
        preset.apex, preset.env, preset.agent, optimizer, mesh=mesh)
    state = init_fn(jax.random.key(0))
    for _ in range(5):
        state, metrics = step_fn(state)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state.frames[0]) == 5 * 16 * 24


def test_compressed_replay_learns():
    """uint8 obs codec (the paper's PNG analogue): the loop runs and learns
    with compressed storage; decode fuses into the learner forward."""
    preset = apex_dqn.reduced()
    cfg = dataclasses.replace(preset.apex, compress_obs=True)
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(cfg, preset.env, preset.agent,
                                          optimizer)
    state = init_fn(jax.random.key(0))
    for _ in range(8):
        state, m = step_fn(state)
    assert bool(jnp.isfinite(m["loss"]))
    # storage really is uint8
    assert state.replay.storage["obs"]["data"].dtype == jnp.uint8
