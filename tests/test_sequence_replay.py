"""Prioritized sequence replay (the LLM-scale integration, paper §6):
ingest->sample->update->write-back round trips; prioritization focuses on
hard sequences; training reduces loss on the synthetic mixture."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replay as replay_lib, sequence_replay as seqrep
from repro.data import pipeline as data_lib
from repro.models import registry, transformer
from repro.optim import optimizers as optim


def _setup(seq_len=32, batch=8):
    cfg = registry.get_config("llama3.2-1b").reduced(d_model=128, vocab=256)
    params = transformer.init(cfg, jax.random.key(0))
    optimizer = optim.adamw(1e-3)
    scfg = seqrep.SeqReplayConfig(
        replay=replay_lib.ReplayConfig(capacity=256, min_fill=batch),
        seq_len=seq_len, batch_size=batch, ingest_batch=batch,
        param_sync_period=2, learner_steps_per_round=1)
    apply_fn = lambda p, toks: transformer.apply(p, toks, cfg=cfg)
    state = seqrep.init_state(scfg, params, optimizer, jax.random.key(1))
    pcfg = data_lib.PipelineConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                   batch_size=batch)
    return cfg, scfg, apply_fn, optimizer, state, pcfg


def test_round_step_runs_and_loss_decreases():
    cfg, scfg, apply_fn, optimizer, state, pcfg = _setup()

    @jax.jit
    def round_step(state, step):
        b = data_lib.make_batch(pcfg, jax.random.key(7), step)
        return seqrep.round_step(scfg, apply_fn, optimizer, state,
                                 b["tokens"], b["labels"])

    losses = []
    for it in range(30):
        state, m = round_step(state, it)
        losses.append(float(m["loss"]))
    assert int(state.replay.size) > 0
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_initial_priorities_from_stale_copy():
    """Scoring must use actor_params, not the learner's params (Alg. 1)."""
    cfg, scfg, apply_fn, optimizer, state, pcfg = _setup()
    b = data_lib.make_batch(pcfg, jax.random.key(3), 0)
    # corrupt learner params; actor copy is untouched
    bad = jax.tree.map(lambda x: x * 100.0, state.params)
    state = state._replace(params=bad)
    p_stale = seqrep.score_sequences(apply_fn, state.actor_params,
                                     b["tokens"], b["labels"])
    s2 = seqrep.ingest(scfg, apply_fn, state, b["tokens"], b["labels"])
    # the leaf masses must reflect the stale scores, not the corrupted params
    from repro.core import priority as prio, sumtree
    leaves = np.asarray(sumtree.leaves(s2.replay.tree))[:8]
    np.testing.assert_allclose(
        leaves, np.asarray(prio.to_leaf(p_stale, scfg.replay.alpha)), rtol=1e-4)


def test_priorities_follow_sequence_difficulty():
    """After training a while, freshly-scored hard (high-entropy) sequences
    carry higher priority than easy ones."""
    cfg, scfg, apply_fn, optimizer, state, pcfg = _setup(seq_len=64)

    @jax.jit
    def round_step(state, step):
        b = data_lib.make_batch(pcfg, jax.random.key(7), step)
        return seqrep.round_step(scfg, apply_fn, optimizer, state,
                                 b["tokens"], b["labels"])

    for it in range(40):
        state, _ = round_step(state, it)
    b = data_lib.make_batch(pcfg, jax.random.key(99), 1000)
    prios = np.asarray(seqrep.score_sequences(apply_fn, state.params,
                                              b["tokens"], b["labels"]))
    uniq = np.array([len(set(r.tolist())) for r in np.asarray(b["tokens"])])
    # rank correlation between sequence diversity and loss should be positive
    order = uniq.argsort()
    lo, hi = prios[order[:3]].mean(), prios[order[-3:]].mean()
    assert hi > lo
