"""Multi-device checks run in a subprocess with forced host devices, so the
main test process keeps seeing 1 device (per the dry-run isolation rule)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_apex_dqn_on_4_shards():
    """The distributed loop runs on a real (host) 4-device data mesh and the
    ladder/replay span shards."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import apex_dqn
        from repro.core import apex
        from repro.launch import mesh as mesh_lib
        mesh = mesh_lib.make_mesh((4,), ("data",))
        preset = apex_dqn.reduced(num_shards=4)
        opt = preset.make_optimizer()
        init_fn, step_fn = apex.make_train_fn(
            preset.apex, preset.env, preset.agent, opt, mesh=mesh)
        st = init_fn(jax.random.key(0))
        for _ in range(4):
            st, m = step_fn(st)
        assert st.replay.storage["obs"].shape[0] == 4
        assert bool(jnp.isfinite(m["loss"]))
        # all shards contributed frames
        assert int(st.frames.sum()) == 4 * preset.apex.lanes_per_shard * \
            preset.apex.rollout_len * 4
        print("MULTI_OK", float(m["loss"]))
    """, devices=4)
    assert "MULTI_OK" in out


def test_dryrun_entrypoint_smoke():
    """python -m repro.launch.dryrun runs end-to-end for one cheap combo and
    emits the roofline record."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-1.6b", "--shape", "decode_32k"],
        env=env, capture_output=True, text=True, timeout=900, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "bottleneck" in out.stdout
