"""Sample-plane property tests: the three SampleSource implementations are
bit-identical and interchangeable.

The load-bearing claim of the transport-agnostic refactor is that a learner
cannot tell where its batches came from: ``LocalFabricSource`` (in-process
fabric), ``RemoteFabricSource`` (loopback gateway, frames over TCP), and a
``StagedSource``-wrapped local (device-staged double buffering) must produce
bit-identical ``LearnerBatch`` contents and IS weights for the same
seed/priority state, and their priority write-backs must land identically in
the shard sum-trees.

Determinism protocol: blocks are queued *before* the fabrics start and
``min_fill`` equals the total transitions added, so every add applies before
the first prefetch; sampling then draws from one deterministic rng stream
per shard, and no write-back interleaves until all compared batches are
drawn (prefetch does not mutate the tree, so trailing prefetches are
harmless).
"""

import queue
import socket
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _apex_helpers import item_example, make_block, tiny_preset

from repro.core.sampling import LearnerBatch
from repro.net import ReplayGateway, RemoteFabricSource
from repro.net.learner_client import parse_hostport
from repro.runtime import (AsyncConfig, LocalFabricSource, ParamStore,
                           ReplayFabric, SourceStats, StagedSource,
                           run_async)

BLOCKS = 4


def filled_fabric(preset, shards, blocks, fns=None):
    """A started fabric with every block applied deterministically before
    the first sample (see module docstring)."""
    fabric = ReplayFabric(preset.apex, item_example(preset.env),
                          num_shards=shards,
                          add_queue_depth=len(blocks) + 1, fns=fns)
    for b in blocks:
        assert fabric.add(b, timeout=1.0)
    return fabric.start()


def sources_preset(shards):
    # 4 blocks x 24 transitions = 96 = min_fill: the sampling gate opens
    # only once every block has been applied, on every shard.
    return tiny_preset(min_fill=96, batch_size=16, capacity=512)


def drain_batches(source, k, timeout=30.0):
    out = []
    deadline = time.monotonic() + timeout
    while len(out) < k:
        assert time.monotonic() < deadline, "source starved for too long"
        b = source.get_batch(timeout=0.1)
        if b is not None:
            out.append(b)
    return out


def assert_batches_bit_identical(a: LearnerBatch, b: LearnerBatch):
    for name, x, y in (("indices", a.indices, b.indices),
                       ("is_weights", a.is_weights, b.is_weights)):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, name
        np.testing.assert_array_equal(x, y, err_msg=name)
    ax, bx = jax.tree.leaves(a.items), jax.tree.leaves(b.items)
    assert len(ax) == len(bx)
    for x, y in zip(ax, bx):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("shards", [1, 2])
def test_local_remote_staged_bit_identical(shards):
    """Acceptance property: same batches, same IS weights, same write-back
    effect on the shard sum-trees, across every source implementation AND
    both remote byte paths (tcp socket vs same-host shm ring) — the ring
    upgrade must be invisible to the learner, bit for bit."""
    preset = sources_preset(shards)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    blocks = [make_block(cfg, env, agent, seed=s) for s in range(BLOCKS)]

    fab_local = filled_fabric(preset, shards, blocks)
    fab_tcp = filled_fabric(preset, shards, blocks, fns=fab_local.fns)
    fab_shm = filled_fabric(preset, shards, blocks, fns=fab_local.fns)
    fab_staged = filled_fabric(preset, shards, blocks, fns=fab_local.fns)
    fabs = (fab_local, fab_tcp, fab_shm, fab_staged)

    gw_tcp = ReplayGateway(fab_tcp, ParamStore({}), sample_timeout_s=0.2,
                           accept_shm=False).start()
    gw_shm = ReplayGateway(fab_shm, ParamStore({}),
                           sample_timeout_s=0.2).start()
    src_local = LocalFabricSource(fab_local).start()
    src_tcp = RemoteFabricSource(gw_tcp.host, gw_tcp.port,
                                 transport="tcp").start()
    src_shm = RemoteFabricSource(gw_shm.host, gw_shm.port,
                                 transport="shm").start()
    src_staged = StagedSource(LocalFabricSource(fab_staged)).start()
    assert src_tcp.transport_kind == "tcp"
    assert src_shm.transport_kind == "shm"
    named = (("local", src_local), ("tcp", src_tcp), ("shm", src_shm),
             ("staged", src_staged))
    k = 6
    try:
        got = {name: drain_batches(src, k) for name, src in named}
        for i in range(k):
            for name in ("tcp", "shm", "staged"):
                assert_batches_bit_identical(got["local"][i], got[name][i])

        # Identical write-backs (deterministic synthetic priorities) must
        # land identically in every fabric's shard sum-trees.
        rng = np.random.default_rng(7)
        prios = [rng.uniform(0.1, 2.0, size=cfg.batch_size)
                 .astype(np.float32) for _ in range(k)]
        for name, src in named:
            for i in range(k):
                src.write_back(np.asarray(got[name][i].indices), prios[i])
        for src in (src_tcp, src_shm):
            src._flush_writebacks()  # remote rounds park until the next
                                     # sample request; ship them now
        # remote write-backs land asynchronously through the gateway
        deadline = time.monotonic() + 30.0
        while (gw_tcp.snapshot().priority_updates < k
               or gw_shm.snapshot().priority_updates < k):
            assert time.monotonic() < deadline
            time.sleep(0.01)
        assert src_local.stats.writebacks == k
        assert src_staged.stats.writebacks == k
        # k rounds coalesced into one frame per flush on the remote paths
        for src in (src_tcp, src_shm):
            assert src.stats.writebacks == k
            assert src.stats.writeback_frames == 1
    finally:
        src_staged.stop()
        src_shm.stop()
        src_tcp.stop()
        gw_shm.stop()
        gw_tcp.stop()
        for f in fabs:
            f.stop()
    assert gw_tcp.error is None and gw_shm.error is None
    assert gw_shm.snapshot().shm_connections == 1
    for f in fabs:
        assert f.error is None
    for s_local, s_tcp, s_shm, s_staged in zip(*[f.replay_states()
                                                 for f in fabs]):
        for other in (s_tcp, s_shm, s_staged):
            np.testing.assert_array_equal(np.asarray(s_local.tree),
                                          np.asarray(other.tree))
            np.testing.assert_array_equal(np.asarray(s_local.size),
                                          np.asarray(other.size))
            for x, y in zip(jax.tree.leaves(s_local.storage),
                            jax.tree.leaves(other.storage)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --- StagedSource unit behavior ---------------------------------------------

class ScriptedSource:
    """SampleSource stub: serves a scripted batch sequence, records calls."""

    def __init__(self, batches):
        self._q = queue.Queue()
        for b in batches:
            self._q.put(b)
        self.writebacks = []
        self.published = []
        self.stats = SourceStats()
        self.started = self.stopped = False

    def start(self):
        self.started = True
        return self

    def stop(self):
        self.stopped = True

    def get_batch(self, timeout=None):
        try:
            return self._q.get(timeout=timeout or 0.01)
        except queue.Empty:
            return None

    def write_back(self, indices, priorities, trace_id=0):
        self.writebacks.append((indices, priorities))

    def publish_params(self, version, params):
        self.published.append(version)

    def snapshot(self):
        raise NotImplementedError

    @property
    def error(self):
        return None


def make_learner_batch(i, n=4):
    return LearnerBatch(
        indices=np.full((n,), i, np.int32),
        items={"x": np.arange(n, dtype=np.float32) + i},
        is_weights=np.ones((n,), np.float32))


def test_staged_source_preserves_order_and_passes_through():
    inner = ScriptedSource([make_learner_batch(i) for i in range(5)])
    staged = StagedSource(inner, poll_s=0.005).start()
    try:
        got = drain_batches(staged, 5, timeout=10.0)
        for i, b in enumerate(got):
            assert int(np.asarray(b.indices)[0]) == i
            # on CPU targets staging passes host leaves through untouched
            # (host == device there); on accelerators they'd be jax.Arrays
        staged.write_back(got[0].indices, np.ones(4, np.float32))
        staged.publish_params(3, {"w": np.zeros(2)})
        assert len(inner.writebacks) == 1
        assert inner.published == [3]
        assert staged.stats.staged == 5
        assert staged.get_batch(timeout=0.05) is None  # scripted source dry
        assert staged.stats.starved_polls >= 1
    finally:
        staged.stop()
    assert inner.started and inner.stopped


def test_staged_source_peer_close_is_end_of_stream_not_error():
    """The serving host may win the teardown race: a STOP/EOF surfacing in
    the stager after the learner already finished must not turn the run
    into a worker death — it becomes SourceClosed only if the consumer
    keeps asking for batches."""
    from repro.runtime.sources import SourceClosed

    class Closing(ScriptedSource):
        def get_batch(self, timeout=None):
            b = super().get_batch(timeout)
            if b is None:
                raise SourceClosed("peer hung up")
            return b

    staged = StagedSource(Closing([make_learner_batch(0)]),
                          poll_s=0.005).start()
    try:
        got = drain_batches(staged, 1, timeout=10.0)  # queued batch delivered
        assert int(np.asarray(got[0].indices)[0]) == 0
        deadline = time.monotonic() + 5.0
        while not staged._peer_closed and time.monotonic() < deadline:
            time.sleep(0.005)
        assert staged.error is None          # a finished learner sees no error
        with pytest.raises(SourceClosed):    # a still-hungry one fails fast
            staged.get_batch(timeout=0.05)
    finally:
        staged.stop()
    assert staged.error is None


def test_staged_source_surfaces_stager_death():
    class Exploding(ScriptedSource):
        def get_batch(self, timeout=None):
            raise RuntimeError("boom")

    staged = StagedSource(Exploding([]), poll_s=0.005).start()
    try:
        deadline = time.monotonic() + 5.0
        while staged.error is None and time.monotonic() < deadline:
            time.sleep(0.005)
        assert staged.error is not None
        with pytest.raises(RuntimeError, match="stager died"):
            staged.get_batch(timeout=0.05)
    finally:
        staged.stop()


# --- RemoteFabricSource unit behavior ---------------------------------------

class StarvedFabric:
    def get_batch(self, timeout=None):
        return None

    def write_back(self, indices, priorities, trace_id=0):
        pass


def test_remote_source_starved_returns_none():
    gw = ReplayGateway(StarvedFabric(), ParamStore({}),
                       sample_timeout_s=0.01).start()
    src = RemoteFabricSource(gw.host, gw.port).start()
    try:
        # Under full-suite CPU load the gateway's handler thread may not be
        # scheduled within one client timeout — keep polling (every poll
        # must yield None) until the request has landed server-side.
        deadline = time.monotonic() + 30.0
        while True:
            assert src.get_batch(timeout=1.0) is None
            assert src.stats.starved_polls >= 1
            snap = gw.snapshot()
            if snap.sample_requests >= 1 or time.monotonic() > deadline:
                break
        assert snap.sample_requests >= 1
        assert snap.sample_starved >= 1
        assert snap.sample_sends == 0
    finally:
        src.stop()
        gw.stop()
    assert gw.error is None


def test_remote_source_param_push_publishes_at_gateway():
    store = ParamStore({"w": jnp.zeros((3,))})
    gw = ReplayGateway(StarvedFabric(), store).start()
    src = RemoteFabricSource(gw.host, gw.port).start()
    try:
        src.publish_params(1, {"w": np.full((3,), 5.0, np.float32)})
        deadline = time.monotonic() + 10.0
        while store.version < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert store.version == 1
        np.testing.assert_array_equal(np.asarray(store.get().params["w"]),
                                      np.full((3,), 5.0, np.float32))
        assert gw.snapshot().param_pushes == 1
    finally:
        src.stop()
        gw.stop()
    assert gw.error is None


@pytest.mark.parametrize("transport", ["tcp", "shm"])
def test_coalesced_writebacks_preserve_order_and_last_writer_wins(transport):
    """Satellite: several write_back rounds ship as ONE coalesced
    PRIORITY_UPDATE frame, and a key written twice keeps its *later*
    priority — the wire semantics must equal per-round frames applied in
    call order."""
    preset = sources_preset(1)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    blocks = [make_block(cfg, env, agent, seed=s) for s in range(BLOCKS)]
    fab_direct = filled_fabric(preset, 1, blocks)
    fab_remote = filled_fabric(preset, 1, blocks, fns=fab_direct.fns)
    gw = ReplayGateway(fab_remote, ParamStore({}),
                       sample_timeout_s=0.2).start()
    src = RemoteFabricSource(gw.host, gw.port, transport=transport).start()
    try:
        batch = drain_batches(src, 1)[0]
        idx = np.asarray(batch.indices)
        # Three rounds touching overlapping keys: round 2 rewrites round 1's
        # keys, round 3 rewrites a subset again. LWW = round 3 > 2 > 1.
        rounds = [(idx, np.full(idx.shape, 0.125, np.float32)),
                  (idx, np.full(idx.shape, 0.75, np.float32)),
                  (idx[: len(idx) // 2 or 1],
                   np.full((len(idx) // 2 or 1,), 2.5, np.float32))]
        for r_idx, r_prio in rounds:
            src.write_back(r_idx, r_prio)
            fab_direct.write_back(r_idx, r_prio)  # reference: in-order frames
        src._flush_writebacks()
        deadline = time.monotonic() + 30.0
        while gw.snapshot().priority_updates < len(rounds):
            assert time.monotonic() < deadline
            time.sleep(0.01)
        snap = gw.snapshot()
        assert snap.priority_frames == 1          # one frame on the wire...
        assert snap.priority_updates == len(rounds)  # ...carrying 3 rounds
        assert src.stats.writeback_frames == 1
        while fab_direct.snapshot().updates_applied < len(rounds):
            assert time.monotonic() < deadline
            time.sleep(0.01)
    finally:
        src.stop()
        gw.stop()
        fab_direct.stop()
        fab_remote.stop()
    assert gw.error is None
    assert fab_direct.error is None and fab_remote.error is None
    for s_direct, s_remote in zip(fab_direct.replay_states(),
                                  fab_remote.replay_states()):
        np.testing.assert_array_equal(np.asarray(s_direct.tree),
                                      np.asarray(s_remote.tree))


def test_parse_hostport():
    assert parse_hostport("h:123") == ("h", 123)
    assert parse_hostport("123") == ("127.0.0.1", 123)
    with pytest.raises(ValueError, match="HOST:PORT"):
        parse_hostport("nope")
    with pytest.raises(ValueError, match="HOST:PORT"):
        parse_hostport("h:")
    # out-of-range ports fail here, not as an OverflowError (or a futile
    # retry loop) deep inside the connect path
    with pytest.raises(ValueError, match="65535"):
        parse_hostport("h:99999")
    with pytest.raises(ValueError, match="65535"):
        parse_hostport("h:0")


# --- runner integration ------------------------------------------------------

def test_run_async_sample_staging_end_to_end():
    preset = tiny_preset()
    res = run_async(preset.apex,
                    AsyncConfig(actor_threads=1, total_learner_steps=20,
                                sample_staging=True, max_seconds=120),
                    preset.env, preset.agent, preset.make_optimizer())
    assert res.stats["learner_steps"] == 20
    assert res.source_stats is not None and res.source_stats.staged >= 20
    assert res.stats["param_version"] >= 1


@pytest.mark.parametrize("transport", ["tcp", "shm"])
def test_run_async_serve_plus_remote_learner_loopback(transport):
    """The full two-process topology on loopback, over both byte paths: one
    runtime serves actors + fabric + gateway (no local learner), the other
    runs only the learner against it; params flow back through PARAM_PUSH.
    Every assertion holds identically for tcp and shm — batch-level
    bit-identity across the two paths is pinned down by
    ``test_local_remote_staged_bit_identical`` (live runs sample on racing
    clocks, so run-level trajectories are not comparable)."""
    preset = tiny_preset()
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    steps = 15
    serve_out = {}

    def serve():
        serve_out["res"] = run_async(
            preset.apex,
            AsyncConfig(actor_threads=1, serve_sampling=True,
                        gateway_port=port, total_learner_steps=steps,
                        transport=transport, max_seconds=180),
            preset.env, preset.agent, preset.make_optimizer())

    th = threading.Thread(target=serve, daemon=True)
    th.start()
    res = run_async(
        preset.apex,
        AsyncConfig(actor_threads=0, learner_remote=f"127.0.0.1:{port}",
                    total_learner_steps=steps, sample_staging=True,
                    transport=transport, max_seconds=180),
        preset.env, preset.agent, preset.make_optimizer())
    th.join(timeout=180)
    assert not th.is_alive()
    assert res.stats["learner_steps"] == steps
    assert res.stats["param_version"] == steps  # publish_every=1
    assert res.source_stats.writebacks == steps
    serve_res = serve_out["res"]
    assert serve_res.stats["learner_steps"] >= steps
    g = serve_res.gateway_stats
    assert g.priority_updates >= steps
    assert g.sample_sends >= steps
    assert g.param_pushes >= 1
    assert g.shm_connections == (1 if transport == "shm" else 0)
    # write-backs coalesced: never more frames than rounds
    assert res.source_stats.writeback_frames <= res.source_stats.writebacks
    # the serving side's actors kept generating experience
    assert serve_res.stats["actor_transitions"] > 0


def test_async_config_rejects_incoherent_remote_combos():
    preset = tiny_preset()
    with pytest.raises(ValueError, match="learner-only"):
        run_async(preset.apex,
                  AsyncConfig(actor_threads=2, learner_remote="h:1"),
                  preset.env, preset.agent, preset.make_optimizer())
    with pytest.raises(ValueError, match="two sides"):
        run_async(preset.apex,
                  AsyncConfig(actor_threads=0, learner_remote="h:1",
                              serve_sampling=True),
                  preset.env, preset.agent, preset.make_optimizer())
