"""Wire protocol: frame/array-tree round trips (fp32 bit-identical, uint8
obs codec-equal), the host/device codec twins, gateway routing into a
fabric, backpressure propagation, and param serving."""

import socket
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _apex_helpers import item_example, make_block, tiny_preset
from _hypothesis_fallback import given, settings, st

from repro.core import codec
from repro.net import wire
from repro.net.gateway import ReplayGateway
from repro.runtime import ParamStore, ReplayFabric, phases


def assert_tree_equal(a, b):
    ka, kb = sorted(a), sorted(b)
    assert ka == kb
    for k in ka:
        if isinstance(a[k], dict):
            assert_tree_equal(a[k], b[k])
        else:
            x, y = np.asarray(a[k]), np.asarray(b[k])
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(x, y)


# --- array-tree / block round trips -----------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 64),
       dim=st.integers(1, 32))
def test_tree_round_trip_bit_identical(seed, n, dim):
    """Every dtype the runtime ships must survive the wire bit-for-bit,
    including nested dicts and scalars."""
    rng = np.random.RandomState(seed)
    tree = {
        "f32": rng.randn(n, dim).astype(np.float32),
        "u8": rng.randint(0, 256, (n, dim), np.uint8),
        "i32": rng.randint(-5, 5, (n,), np.int32),
        "scalar": np.float32(rng.randn()),
        "nested": {"a": rng.randn(dim).astype(np.float32),
                   "b": {"deep": rng.randn(1).astype(np.float64)}},
    }
    out = wire.decode_tree(wire.encode_tree(tree))
    assert_tree_equal(tree, out)


_PRESET_CACHE: dict = {}


def _cached_preset():
    if "p" not in _PRESET_CACHE:
        _PRESET_CACHE["p"] = tiny_preset()
    return _PRESET_CACHE["p"]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_block_round_trip_matches_in_process_path(seed):
    """Acceptance: a TransitionBlock encoded by wire.py and decoded on the
    gateway side is bit-identical to the in-process block — same bytes the
    fabric's add queue would have carried."""
    preset = _cached_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    block = make_block(cfg, env, agent, seed=seed)
    dec = wire.decode_block(wire.encode_block(block))
    assert_tree_equal({"items": wire.jax_to_np(block.items),
                       "priorities": np.asarray(block.priorities)},
                      {"items": dec.items, "priorities": dec.priorities})


def test_block_round_trip_quantized_uint8_passthrough():
    """ChainWorld obs are uint8: wire quantization must be lossless and add
    no scale/offset overhead."""
    preset = tiny_preset()
    block = make_block(preset.apex, preset.env, preset.agent)
    raw = wire.encode_block(block)
    quant = wire.encode_block(block, quantize_obs=True)
    assert len(quant) == len(raw)
    dec = wire.decode_block(quant)
    np.testing.assert_array_equal(np.asarray(block.items["obs"]),
                                  dec.items["obs"])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 32),
       dim=st.integers(2, 24))
def test_block_round_trip_quantized_float_codec_equal(seed, n, dim):
    """Acceptance: float obs shipped under wire quantization decode to
    exactly what the replay codec would store — codec-equal, and ~4x
    smaller on the wire."""
    rng = np.random.RandomState(seed)
    items = {"obs": rng.randn(n, dim).astype(np.float32) * 3.0,
             "action": rng.randint(0, 4, (n,), np.int32),
             "returns": rng.randn(n).astype(np.float32),
             "discount_n": rng.rand(n).astype(np.float32),
             "next_obs": rng.randn(n, dim).astype(np.float32)}
    block = phases.TransitionBlock(items=items,
                                   priorities=rng.rand(n).astype(np.float32))
    dec = wire.decode_block(wire.encode_block(block, quantize_obs=True))
    for key in ("obs", "next_obs"):
        want = np.asarray(codec.decode(codec.encode(jnp.asarray(items[key]))))
        np.testing.assert_array_equal(dec.items[key], want)
    for key in ("action", "returns", "discount_n"):  # untouched: bit-exact
        np.testing.assert_array_equal(dec.items[key], items[key])
    np.testing.assert_array_equal(dec.priorities, block.priorities)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 16),
       dim=st.integers(1, 33))
def test_codec_np_matches_device_codec(seed, n, dim):
    """codec.encode_np/decode_np (the host-side wire path) produce the same
    bytes as the jitted device codec — one quantization, two backends."""
    rng = np.random.RandomState(seed)
    x = (rng.randn(n, dim) * rng.uniform(0.1, 10)).astype(np.float32)
    enc_np, enc_dev = codec.encode_np(x), codec.encode(jnp.asarray(x))
    np.testing.assert_array_equal(enc_np.data, np.asarray(enc_dev.data))
    np.testing.assert_array_equal(enc_np.scale, np.asarray(enc_dev.scale))
    np.testing.assert_array_equal(enc_np.offset, np.asarray(enc_dev.offset))
    np.testing.assert_array_equal(codec.decode_np(enc_np),
                                  np.asarray(codec.decode(enc_dev)))


def test_params_round_trip():
    preset = tiny_preset()
    params = preset.agent.init(jax.random.key(0),
                               item_example(preset.env)["obs"][None])
    version, dec = wire.decode_params(wire.encode_params(41, params))
    assert version == 41
    assert_tree_equal(wire.jax_to_np(params), dec)


# --- policy plane: ACT_REQUEST / ACT_RESULT ----------------------------------

def _key_safe(leaf):
    import jax.random as jr
    if jax.dtypes.issubdtype(getattr(leaf, "dtype", None),
                             jax.dtypes.prng_key):
        leaf = jr.key_data(leaf)
    return np.asarray(leaf)


def test_act_round_trip_bit_identical():
    """An ActorSlice survives ACT_REQUEST/ACT_RESULT bit-for-bit, typed PRNG
    key included — the receiver rebuilds it against its own locally derived
    example slice, so only leaf bytes cross the wire, never pickled trees."""
    preset = _cached_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    sl = phases.initial_actor_slice(cfg, env, seed=3, actor_id=1)
    example = phases.initial_actor_slice(cfg, env, seed=3, actor_id=1)

    dec, sid = wire.decode_act_request(wire.encode_act_request(sl, 1), example)
    assert sid == 1
    for a, b in zip(jax.tree.leaves(sl), jax.tree.leaves(dec)):
        np.testing.assert_array_equal(_key_safe(a), _key_safe(b))

    block = make_block(cfg, env, agent, seed=5)
    metrics = {"transitions": np.float32(4.0), "eps": np.float32(0.1)}
    out_sl, out_block, out_metrics = wire.decode_act_result(
        wire.encode_act_result(sl, block, metrics), example)
    for a, b in zip(jax.tree.leaves(sl), jax.tree.leaves(out_sl)):
        np.testing.assert_array_equal(_key_safe(a), _key_safe(b))
    assert_tree_equal({"items": wire.jax_to_np(block.items),
                       "priorities": np.asarray(block.priorities)},
                      {"items": out_block.items,
                       "priorities": np.asarray(out_block.priorities)})
    assert set(out_metrics) == set(metrics)
    for k in metrics:
        np.testing.assert_array_equal(out_metrics[k], metrics[k])


def test_act_request_rejects_geometry_mismatch():
    """A peer built against different (cfg, env) geometry must die with a
    WireError naming the leaf mismatch, not a deep unflatten crash."""
    preset = _cached_preset()
    cfg, env = preset.apex, preset.env
    sl = phases.initial_actor_slice(cfg, env, seed=3, actor_id=0)
    payload = wire.encode_act_request(sl, 0)
    with pytest.raises(wire.WireError, match="leaves"):
        wire.decode_act_request(payload, {"just": np.zeros(3),
                                          "two": np.zeros(2)})
    with pytest.raises(wire.WireError, match="ACT_REQUEST"):
        wire.decode_act_request(wire.encode_tree({"nope": np.zeros(3)}), sl)


# --- framing -----------------------------------------------------------------

def _socketpair_reader():
    a, b = socket.socketpair()
    return a, wire.FrameReader(b), b


def test_frame_reader_reassembles_split_frames():
    """Frames fragmented arbitrarily by the transport must reassemble, and
    a timeout mid-frame must resume, not corrupt."""
    a, reader, b = _socketpair_reader()
    payload = wire.encode_json({"actor_id": 7, "protocol": 1})
    buf = wire.frame(wire.HELLO, payload) + wire.frame(wire.STOP)
    try:
        a.sendall(buf[:5])
        assert reader.read_frame(timeout=0.02) is None  # mid-frame timeout
        a.sendall(buf[5:])
        msg, got = reader.read_frame(timeout=1.0)
        assert msg == wire.HELLO
        assert wire.decode_json(got) == {"actor_id": 7, "protocol": 1}
        msg, got = reader.read_frame(timeout=1.0)
        assert msg == wire.STOP and len(got) == 0
    finally:
        a.close()
        b.close()


def test_frame_reader_rejects_bad_magic_and_version():
    a, reader, b = _socketpair_reader()
    try:
        a.sendall(b"JUNK" * (wire._HEADER.size // 4))  # one full bad header
        with pytest.raises(wire.WireError, match="magic"):
            reader.read_frame(timeout=1.0)
    finally:
        a.close()
        b.close()
    a, reader, b = _socketpair_reader()
    try:
        bad = bytearray(wire.frame(wire.STOP))
        bad[4:6] = (9999).to_bytes(2, "little")  # future protocol version
        a.sendall(bytes(bad))
        with pytest.raises(wire.WireError, match="version"):
            reader.read_frame(timeout=1.0)
    finally:
        a.close()
        b.close()


# --- gateway -----------------------------------------------------------------

class FakeFabric:
    """Records added blocks; optionally refuses the first N adds."""

    def __init__(self, refuse_first: int = 0):
        self.blocks = []
        self.refusals_left = refuse_first
        self.refused = 0

    def add(self, block, timeout=None, trace_id=0):
        if self.refusals_left > 0:
            self.refusals_left -= 1
            self.refused += 1
            time.sleep(0.001)
            return False
        self.blocks.append(block)
        return True


def _client(gw):
    sock = socket.create_connection((gw.host, gw.port), timeout=5.0)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return sock, wire.FrameReader(sock)


def _await(cond, timeout=5.0):
    deadline = time.monotonic() + timeout
    while not cond() and time.monotonic() < deadline:
        time.sleep(0.005)
    assert cond()


def test_gateway_routes_blocks_and_acks():
    preset = tiny_preset()
    block = make_block(preset.apex, preset.env, preset.agent)
    fabric = FakeFabric()
    store = ParamStore({"w": jnp.zeros((2,))})
    gw = ReplayGateway(fabric, store).start()
    sock, reader = _client(gw)
    try:
        wire.send_frame(sock, wire.HELLO, wire.encode_json(
            {"actor_id": 0, "protocol": wire.PROTOCOL_VERSION}))
        payload = wire.encode_block(block)
        for _ in range(3):
            wire.send_frame(sock, wire.ADD_BLOCK, payload)
        acks = 0
        while acks < 3:
            msg, _ = reader.read_frame(timeout=5.0)
            assert msg == wire.ADD_ACK
            acks += 1
        assert len(fabric.blocks) == 3
        assert_tree_equal(fabric.blocks[0].items,
                          wire.jax_to_np(block.items))
        snap = gw.snapshot()
        assert snap.blocks_in == 3
        assert snap.transitions_in == 3 * int(block.priorities.shape[0])
    finally:
        sock.close()
        gw.stop()
    assert gw.error is None


def test_gateway_holds_ack_under_fabric_backpressure():
    """No ACK while the fabric refuses the block: the client's in-flight
    window stays open, which is how backpressure crosses the socket."""
    preset = tiny_preset()
    block = make_block(preset.apex, preset.env, preset.agent)
    fabric = FakeFabric(refuse_first=5)
    gw = ReplayGateway(fabric, ParamStore({}), add_timeout_s=0.001).start()
    sock, reader = _client(gw)
    try:
        wire.send_frame(sock, wire.ADD_BLOCK, wire.encode_block(block))
        msg, _ = reader.read_frame(timeout=10.0)
        assert msg == wire.ADD_ACK        # arrives only after retries
        assert fabric.refused == 5
        assert gw.snapshot().add_retries == 5
        assert len(fabric.blocks) == 1
    finally:
        sock.close()
        gw.stop()
    assert gw.error is None


def test_gateway_serves_params_honoring_version():
    params0 = {"w": jnp.arange(4, dtype=jnp.float32)}
    store = ParamStore(params0)
    gw = ReplayGateway(FakeFabric(), store).start()
    sock, reader = _client(gw)
    try:
        # fresh client (have=-1) gets the v0 snapshot
        wire.send_frame(sock, wire.PARAM_PULL, wire.encode_json({"have": -1}))
        msg, payload = reader.read_frame(timeout=5.0)
        assert msg == wire.PARAM
        version, got = wire.decode_params(payload)
        assert version == 0
        np.testing.assert_array_equal(got["w"],
                                      np.arange(4, dtype=np.float32))
        # same version again: unchanged (no tensor bytes on the wire)
        wire.send_frame(sock, wire.PARAM_PULL, wire.encode_json({"have": 0}))
        msg, payload = reader.read_frame(timeout=5.0)
        assert msg == wire.PARAM_UNCHANGED
        assert wire.decode_json(payload) == {"version": 0}
        # learner publishes; the next pull ships the new snapshot
        store.publish({"w": jnp.full((4,), 9.0)})
        wire.send_frame(sock, wire.PARAM_PULL, wire.encode_json({"have": 0}))
        msg, payload = reader.read_frame(timeout=5.0)
        assert msg == wire.PARAM
        version, got = wire.decode_params(payload)
        assert version == 1
        np.testing.assert_array_equal(got["w"], np.full((4,), 9.0, np.float32))
        assert gw.snapshot().param_sends == 2
    finally:
        sock.close()
        gw.stop()
    assert gw.error is None


def test_decode_rejects_corrupt_payloads_as_wire_errors():
    """Corrupt payloads must surface as WireError — the containment class
    receivers catch per connection — never raw struct/numpy/json errors."""
    for decoder in (wire.decode_tree, wire.decode_block, wire.decode_params,
                    wire.decode_json):
        with pytest.raises(wire.WireError):
            decoder(b"\x01\x02")
    # structurally valid tree missing the block fields
    with pytest.raises(wire.WireError, match="ADD_BLOCK"):
        wire.decode_block(wire.encode_tree({"nope": np.zeros(3)}))


def test_gateway_drops_malformed_connection_not_gateway():
    fabric = FakeFabric()
    gw = ReplayGateway(fabric, ParamStore({})).start()
    bad, _ = _client(gw)
    try:
        bad.sendall(b"garbage-that-is-not-a-frame!")
        _await(lambda: gw.snapshot().wire_errors == 1)
        # valid header, corrupt payload: same containment, not a gateway
        # error (the live-repro case from review)
        bad2, _ = _client(gw)
        try:
            bad2.sendall(wire.frame(wire.ADD_BLOCK, b"\x01\x02"))
            _await(lambda: gw.snapshot().wire_errors == 2)
        finally:
            bad2.close()
        # the gateway survives and serves the next, well-behaved client
        preset = tiny_preset()
        block = make_block(preset.apex, preset.env, preset.agent)
        sock, reader = _client(gw)
        try:
            wire.send_frame(sock, wire.ADD_BLOCK, wire.encode_block(block))
            msg, _ = reader.read_frame(timeout=5.0)
            assert msg == wire.ADD_ACK
            assert len(fabric.blocks) == 1
        finally:
            sock.close()
    finally:
        bad.close()
        gw.stop()
    assert gw.error is None


def test_gateway_block_lands_in_real_fabric_identically():
    """End to end through a real ReplayFabric: the same block added
    in-process and via the gateway produces identical shard replay states
    (storage + sum-tree bytes)."""
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    item = item_example(env)
    block = make_block(cfg, env, agent)

    direct = ReplayFabric(cfg, item, num_shards=2).start()
    via_gw = ReplayFabric(cfg, item, num_shards=2, fns=direct.fns).start()
    gw = ReplayGateway(via_gw, ParamStore({})).start()
    sock, reader = _client(gw)
    try:
        payload = wire.encode_block(block)
        for _ in range(4):
            assert direct.add(block, timeout=1.0)
            wire.send_frame(sock, wire.ADD_BLOCK, payload)
        acks = 0
        while acks < 4:
            msg, _ = reader.read_frame(timeout=10.0)
            acks += msg == wire.ADD_ACK
    finally:
        sock.close()
        gw.stop()
        direct.stop()
        via_gw.stop()
    assert gw.error is None and direct.error is None and via_gw.error is None
    for s_direct, s_gw in zip(direct.replay_states(), via_gw.replay_states()):
        np.testing.assert_array_equal(np.asarray(s_direct.tree),
                                      np.asarray(s_gw.tree))
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), s_direct.storage, s_gw.storage)
        assert int(s_direct.size) == int(s_gw.size)


# --- sample plane ------------------------------------------------------------

def test_sample_batch_and_priority_update_round_trip():
    """The sample-plane payloads ship the learner contract bit-identically:
    int32 keys, fp32 weights/items, nested item dicts."""
    rng = np.random.default_rng(3)
    batch = {
        "indices": rng.integers(0, 1 << 20, size=16).astype(np.int32),
        "is_weights": rng.random(16).astype(np.float32),
        "items": {"obs": rng.integers(0, 255, (16, 8)).astype(np.uint8),
                  "nested": {"returns": rng.random(16).astype(np.float32)}},
    }
    from repro.core.sampling import LearnerBatch
    lb = LearnerBatch(batch["indices"], batch["items"], batch["is_weights"])
    out = wire.decode_sample_batch(wire.encode_sample_batch(lb))
    np.testing.assert_array_equal(out.indices, batch["indices"])
    assert out.indices.dtype == np.int32
    np.testing.assert_array_equal(out.is_weights, batch["is_weights"])
    assert out.is_weights.dtype == np.float32
    assert_tree_equal(out.items, batch["items"])

    idx2, prios2, counts = wire.decode_priority_update(
        wire.encode_priority_update(batch["indices"],
                                    batch["is_weights"] * 2.0))
    np.testing.assert_array_equal(idx2, batch["indices"])
    np.testing.assert_array_equal(prios2, batch["is_weights"] * 2.0)
    # uncoalesced frames carry one round spanning every key
    np.testing.assert_array_equal(counts, [len(batch["indices"])])
    # coalesced: per-round lengths survive, and inconsistent ones are
    # rejected before any write-back applies
    _, _, counts2 = wire.decode_priority_update(wire.encode_priority_update(
        batch["indices"], batch["is_weights"], counts=[10, 6]))
    np.testing.assert_array_equal(counts2, [10, 6])
    with pytest.raises(wire.WireError, match="counts"):
        wire.decode_priority_update(wire.encode_priority_update(
            batch["indices"], batch["is_weights"], counts=[10, 10]))

    with pytest.raises(wire.WireError, match="SAMPLE_BATCH"):
        wire.decode_sample_batch(wire.encode_tree({"nope": np.zeros(3)}))
    with pytest.raises(wire.WireError, match="PRIORITY_UPDATE"):
        wire.decode_priority_update(wire.encode_tree({"nope": np.zeros(3)}))


def test_gateway_serves_sample_plane_against_real_fabric():
    """SAMPLE_REQUEST pops a real prioritized batch (empty reply while the
    fabric is below min-fill), PRIORITY_UPDATE routes the write-back."""
    preset = tiny_preset(min_fill=24, batch_size=8)
    block = make_block(preset.apex, preset.env, preset.agent)
    fabric = ReplayFabric(preset.apex, item_example(preset.env)).start()
    gw = ReplayGateway(fabric, ParamStore({}), sample_timeout_s=0.05).start()
    sock, reader = _client(gw)
    try:
        # below min-fill: starved (empty) reply
        wire.send_frame(sock, wire.SAMPLE_REQUEST)
        msg, payload = reader.read_frame(timeout=5.0)
        assert msg == wire.SAMPLE_BATCH and len(payload) == 0
        # the counter bump trails the reply send; poll instead of racing it
        _await(lambda: gw.snapshot().sample_starved == 1)

        assert fabric.add(block, timeout=5.0)
        deadline = time.monotonic() + 10.0
        batch = None
        while batch is None:
            assert time.monotonic() < deadline
            wire.send_frame(sock, wire.SAMPLE_REQUEST)
            msg, payload = reader.read_frame(timeout=5.0)
            assert msg == wire.SAMPLE_BATCH
            if len(payload):
                batch = wire.decode_sample_batch(payload)
        assert batch.indices.shape == (8,)
        assert batch.is_weights.dtype == np.float32

        wire.send_frame(sock, wire.PRIORITY_UPDATE, wire.encode_priority_update(
            batch.indices, np.full((8,), 0.5, np.float32)))
        _await(lambda: gw.snapshot().priority_updates == 1)
        _await(lambda: fabric.snapshot().updates_applied == 1)
    finally:
        sock.close()
        gw.stop()
        fabric.stop()
    assert gw.error is None and fabric.error is None


# --- satellite: payload cap + version mismatch + param cache -----------------

def test_frame_reader_rejects_oversized_length_prefix():
    """A corrupt/hostile 4-byte length must be rejected before any
    payload-sized allocation happens."""
    a, b = socket.socketpair()
    try:
        reader = wire.FrameReader(b, max_payload=1024)
        a.sendall(wire._HEADER.pack(wire.MAGIC, wire.PROTOCOL_VERSION,
                                    wire.ADD_BLOCK, 1 << 30, 0))
        with pytest.raises(wire.WireError, match="exceeds cap"):
            reader.read_frame(timeout=1.0)
        # and the sender-side guard fails fast with the same class
        with pytest.raises(wire.WireError, match="exceeds cap"):
            wire.frame(wire.ADD_BLOCK, b"x" * (wire.MAX_PAYLOAD + 1))
    finally:
        a.close()
        b.close()


def test_version_mismatch_rejected_in_both_directions():
    """A client speaking a newer protocol than the server is dropped by the
    gateway (connection-contained); a server speaking a newer protocol than
    the client raises at the client's reader. Either way the first frame is
    where it dies."""
    # client newer than server: gateway drops that one connection
    gw = ReplayGateway(FakeFabric(), ParamStore({})).start()
    newer, _ = _client(gw)
    try:
        newer.sendall(wire._HEADER.pack(wire.MAGIC,
                                        wire.PROTOCOL_VERSION + 1,
                                        wire.HELLO, 0, 0))
        _await(lambda: gw.snapshot().wire_errors == 1)
        # gateway survives for well-versioned peers
        ok, reader = _client(gw)
        try:
            preset = tiny_preset()
            block = make_block(preset.apex, preset.env, preset.agent)
            wire.send_frame(ok, wire.ADD_BLOCK, wire.encode_block(block))
            msg, _ = reader.read_frame(timeout=5.0)
            assert msg == wire.ADD_ACK
        finally:
            ok.close()
    finally:
        newer.close()
        gw.stop()
    assert gw.error is None

    # server newer than client: the client's reader refuses the frame
    srv, cli = socket.socketpair()
    try:
        reader = wire.FrameReader(cli)
        srv.sendall(wire._HEADER.pack(wire.MAGIC, wire.PROTOCOL_VERSION + 1,
                                      wire.PARAM, 0, 0))
        with pytest.raises(wire.WireError, match="version"):
            reader.read_frame(timeout=1.0)
        # ... and an *older* server is equally rejected (no silent downgrade)
        reader2 = wire.FrameReader(cli)
        srv.sendall(wire._HEADER.pack(wire.MAGIC, wire.PROTOCOL_VERSION - 1,
                                      wire.PARAM, 0, 0))
        with pytest.raises(wire.WireError, match="version"):
            reader2.read_frame(timeout=1.0)
    finally:
        srv.close()
        cli.close()


def test_gateway_param_cache_under_version_churn(monkeypatch):
    """The per-version encoded-params cache must serve every version exactly
    once per publication (K pulling actors share one encode) and never serve
    stale bytes after a publish."""
    calls = {"n": 0}
    real = wire.encode_params

    def counting(version, params):
        calls["n"] += 1
        return real(version, params)

    monkeypatch.setattr(wire, "encode_params", counting)
    store = ParamStore({"w": jnp.zeros((4,))})
    gw = ReplayGateway(FakeFabric(), store).start()
    sock_a, reader_a = _client(gw)
    sock_b, reader_b = _client(gw)
    try:
        def pull(sock, reader, have):
            wire.send_frame(sock, wire.PARAM_PULL,
                            wire.encode_json({"have": have}))
            msg, payload = reader.read_frame(timeout=5.0)
            assert msg == wire.PARAM
            return wire.decode_params(payload)

        # two clients pull v0: one encode, identical bytes
        v_a, got_a = pull(sock_a, reader_a, -1)
        v_b, got_b = pull(sock_b, reader_b, -1)
        assert (v_a, v_b) == (0, 0)
        assert calls["n"] == 1

        # churn: publish 3 versions back to back, then both clients pull —
        # each gets the *latest*, which is encoded exactly once
        for i in range(1, 4):
            store.publish({"w": jnp.full((4,), float(i))})
        v_a, got_a = pull(sock_a, reader_a, 0)
        v_b, got_b = pull(sock_b, reader_b, 0)
        assert (v_a, v_b) == (3, 3)
        np.testing.assert_array_equal(got_a["w"],
                                      np.full((4,), 3.0, np.float32))
        assert calls["n"] == 2

        # a client already at the tip gets PARAM_UNCHANGED (no encode)
        wire.send_frame(sock_a, wire.PARAM_PULL,
                        wire.encode_json({"have": 3}))
        msg, payload = reader_a.read_frame(timeout=5.0)
        assert msg == wire.PARAM_UNCHANGED
        assert wire.decode_json(payload) == {"version": 3}
        assert calls["n"] == 2
    finally:
        sock_a.close()
        sock_b.close()
        gw.stop()
    assert gw.error is None
