"""Launcher flag validation: incoherent combinations fail up front with
actionable messages, not deep inside the runtime."""

import pytest

from repro.launch import train


def validate(argv):
    ap = train.build_parser()
    return train.validate_args(ap, ap.parse_args(argv))


def assert_rejected(argv, needle, capsys):
    with pytest.raises(SystemExit):
        validate(argv)
    err = capsys.readouterr().err
    assert needle in err, err


def test_defaults_resolve():
    args = validate([])
    assert args.actor_threads == 1  # default resolved


def test_learner_remote_implies_learner_only_process():
    args = validate(["--runtime", "async",
                     "--learner-remote", "hostA:7777"])
    assert args.actor_threads == 0


def test_async_only_flags_rejected_under_sync(capsys):
    assert_rejected(["--actor-procs", "2"], "--runtime async", capsys)
    assert_rejected(["--sample-staging"], "--runtime async", capsys)
    assert_rejected(["--learner-remote", "h:1"], "--runtime async", capsys)
    assert_rejected(["--replay-shards", "2"], "--runtime async", capsys)
    assert_rejected(["--ingest-staging"], "--runtime async", capsys)
    assert_rejected(["--add-queue-depth", "8"], "--runtime async", capsys)
    assert_rejected(["--sample-queue-depth", "4"], "--runtime async", capsys)


def test_ingest_plane_flags():
    args = validate(["--runtime", "async", "--ingest-staging",
                     "--add-queue-depth", "8", "--sample-queue-depth", "4"])
    assert args.ingest_staging
    assert args.add_queue_depth == 8 and args.sample_queue_depth == 4


def test_queue_depths_must_be_positive(capsys):
    assert_rejected(["--runtime", "async", "--add-queue-depth", "0"],
                    "--add-queue-depth", capsys)
    assert_rejected(["--runtime", "async", "--sample-queue-depth", "-1"],
                    "--sample-queue-depth", capsys)


def test_serve_sampling_conflicts(capsys):
    assert_rejected(["--runtime", "async", "--serve-sampling",
                     "--sample-staging"], "no local learner", capsys)
    assert_rejected(["--runtime", "async", "--serve-sampling",
                     "--learn-batches", "4"], "no local learner", capsys)
    assert_rejected(["--gateway-port", "7777"], "--runtime async", capsys)
    assert_rejected(["--runtime", "async", "--learner-remote", "h:1",
                     "--gateway-port", "7777"], "learner-only", capsys)
    assert_rejected(["--runtime", "async", "--learner-remote", "h:99999"],
                    "65535", capsys)
    args = validate(["--runtime", "async", "--serve-sampling",
                     "--gateway-host", "0.0.0.0", "--gateway-port", "7777"])
    assert args.gateway_host == "0.0.0.0"
    # gateway flags with nothing that would run a gateway
    assert_rejected(["--runtime", "async", "--gateway-port", "7777"],
                    "no gateway will run", capsys)
    assert_rejected(["--runtime", "async", "--gateway-host", "0.0.0.0"],
                    "no gateway will run", capsys)
    assert_rejected(["--runtime", "async", "--serve-sampling",
                     "--gateway-port", "70000"], "[0, 65535]", capsys)


def test_learner_remote_conflicts(capsys):
    assert_rejected(["--runtime", "async", "--learner-remote", "h:1",
                     "--replay-shards", "2"], "learner-only", capsys)
    assert_rejected(["--runtime", "async", "--learner-remote", "h:1",
                     "--actor-threads", "2"], "learner-only", capsys)
    assert_rejected(["--runtime", "async", "--learner-remote", "h:1",
                     "--serve-sampling"], "two sides", capsys)
    assert_rejected(["--runtime", "async", "--learner-remote", "nonsense"],
                    "HOST:PORT", capsys)
    # the ingest plane lives with the fabric, not the learner-only process
    assert_rejected(["--runtime", "async", "--learner-remote", "h:1",
                     "--ingest-staging"], "learner-only", capsys)
    assert_rejected(["--runtime", "async", "--learner-remote", "h:1",
                     "--add-queue-depth", "8"], "learner-only", capsys)


def test_no_experience_source_rejected(capsys):
    assert_rejected(["--runtime", "async", "--actor-threads", "0"],
                    "no experience source", capsys)


def test_actor_procs_with_zero_threads_allowed():
    args = validate(["--runtime", "async", "--actor-threads", "0",
                     "--actor-procs", "2"])
    assert args.actor_threads == 0 and args.actor_procs == 2


def test_inference_batching_needs_threads(capsys):
    assert_rejected(["--runtime", "async", "--actor-threads", "0",
                     "--actor-procs", "1", "--inference-batching"],
                    "nothing to batch", capsys)


def test_serve_policy_relaxes_nothing_to_batch():
    # proc actors dial the policy gateway as thin clients, so the shared
    # engine has remote work even with zero in-process threads
    args = validate(["--runtime", "async", "--actor-threads", "0",
                     "--actor-procs", "1", "--inference-batching",
                     "--serve-policy", "127.0.0.1:0"])
    assert args.serve_policy == "127.0.0.1:0"


def test_inference_plane_flags_accepted_under_async():
    args = validate(["--runtime", "async", "--inference-batching",
                     "--inference-mode", "slots",
                     "--serve-policy", "0.0.0.0:7901"])
    assert args.inference_mode == "slots"
    assert args.serve_policy == "0.0.0.0:7901"


def test_inference_plane_flags_rejected_under_sync(capsys):
    assert_rejected(["--inference-mode", "slots"], "--runtime async", capsys)
    assert_rejected(["--serve-policy", "h:1"], "--runtime async", capsys)


def test_inference_plane_flags_need_batching_engine(capsys):
    assert_rejected(["--runtime", "async", "--inference-mode", "slots"],
                    "--inference-batching", capsys)
    assert_rejected(["--runtime", "async", "--serve-policy", "h:1"],
                    "--inference-batching", capsys)


def test_serve_policy_spec_validated(capsys):
    assert_rejected(["--runtime", "async", "--inference-batching",
                     "--serve-policy", "nonsense"], "HOST:PORT", capsys)
    assert_rejected(["--runtime", "async", "--inference-batching",
                     "--serve-policy", "h:99999"], "65535", capsys)


def test_inference_plane_conflicts_with_learner_remote(capsys):
    assert_rejected(["--runtime", "async", "--learner-remote", "h:1",
                     "--inference-mode", "slots"], "learner-only", capsys)
    assert_rejected(["--runtime", "async", "--learner-remote", "h:1",
                     "--serve-policy", "h:2"], "learner-only", capsys)


def test_llm_mode_conflicts(capsys):
    assert_rejected(["--mode", "llm"], "--arch", capsys)
    assert_rejected(["--mode", "llm", "--arch", "llama3.2-1b",
                     "--runtime", "async"], "apex modes only", capsys)


def test_scalar_bounds(capsys):
    assert_rejected(["--iterations", "0"], "--iterations", capsys)
    assert_rejected(["--runtime", "async", "--learn-batches", "0"],
                    "--learn-batches", capsys)


def test_metrics_flags_accepted_under_async(tmp_path):
    args = validate(["--runtime", "async",
                     "--metrics-dir", str(tmp_path),
                     "--trace-sample-rate", "0.25"])
    assert args.metrics_dir == str(tmp_path)
    assert args.trace_sample_rate == 0.25


def test_metrics_flags_rejected_under_sync(capsys):
    assert_rejected(["--metrics-dir", "/tmp/m"], "--runtime async", capsys)
    assert_rejected(["--trace-sample-rate", "0.5"], "--runtime async",
                    capsys)


def test_trace_sample_rate_bounds(capsys):
    assert_rejected(["--runtime", "async", "--metrics-dir", "/tmp/m",
                     "--trace-sample-rate", "-0.1"], "[0, 1]", capsys)
    assert_rejected(["--runtime", "async", "--metrics-dir", "/tmp/m",
                     "--trace-sample-rate", "1.5"], "[0, 1]", capsys)


def test_trace_sample_rate_requires_metrics_dir(capsys):
    assert_rejected(["--runtime", "async", "--trace-sample-rate", "0.5"],
                    "--metrics-dir", capsys)


def test_checkpoint_flags_accepted_under_async(tmp_path):
    args = validate(["--runtime", "async",
                     "--checkpoint-dir", str(tmp_path),
                     "--checkpoint-every-s", "5", "--resume"])
    assert args.checkpoint_dir == str(tmp_path)
    assert args.checkpoint_every_s == 5.0
    assert args.resume


def test_checkpoint_flags_rejected_under_sync(capsys):
    assert_rejected(["--checkpoint-dir", "/tmp/c"], "--runtime async",
                    capsys)
    assert_rejected(["--checkpoint-every-s", "5"], "--runtime async",
                    capsys)
    assert_rejected(["--resume"], "--runtime async", capsys)


def test_resume_requires_checkpoint_dir(capsys):
    assert_rejected(["--runtime", "async", "--resume"],
                    "--checkpoint-dir", capsys)


def test_checkpoint_interval_must_be_positive(capsys):
    assert_rejected(["--runtime", "async", "--checkpoint-dir", "/tmp/c",
                     "--checkpoint-every-s", "0"],
                    "--checkpoint-every-s", capsys)


def test_checkpoint_dir_needs_local_fabric_and_learner(capsys):
    assert_rejected(["--runtime", "async", "--checkpoint-dir", "/tmp/c",
                     "--learner-remote", "h:1"],
                    "single-process topology", capsys)
    assert_rejected(["--runtime", "async", "--checkpoint-dir", "/tmp/c",
                     "--serve-sampling"],
                    "single-process topology", capsys)
