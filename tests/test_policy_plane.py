"""Policy plane end to end: a PolicyClient's remote rollout through the
gateway + slot-scheduled InferenceServer is bit-identical to the local
jitted act_phase, STOP propagates to parked clients on engine shutdown,
and a policy-only gateway contains fabric-plane frames per connection."""

import dataclasses
import socket
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from _apex_helpers import init_actor, tiny_preset

from repro.net import PolicyClient, wire
from repro.net.gateway import ReplayGateway
from repro.runtime import InferenceServer, ParamStore, phases


def _raw(leaf):
    if jnp.issubdtype(getattr(leaf, "dtype", None), jax.dtypes.prng_key):
        leaf = jax.random.key_data(leaf)
    return np.asarray(leaf)


def _assert_slices_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(_raw(x), _raw(y))


def _stack(num_actors: int, mode: str = "slots"):
    """Tiny preset + slots-mode engine + policy-only gateway, started."""
    preset = tiny_preset()
    cfg = dataclasses.replace(preset.apex, num_shards=num_actors)
    env, agent = preset.env, preset.agent
    slices = [init_actor(cfg, env, jax.random.key(t))[0]
              for t in range(num_actors)]
    params = agent.init(jax.random.key(7), slices[0].obs[:1])
    store = ParamStore(params)
    server = InferenceServer(cfg, env, agent, store, max_batch=num_actors,
                             mode=mode)
    server.warm(slices[0])
    server.start()
    gw = ReplayGateway(None, store, inference=server,
                       act_example=slices[0]).start()
    return cfg, env, agent, slices, params, server, gw


def test_remote_act_bit_identical_to_local():
    """The acceptance property for thin-client actors: a rollout served
    over the wire is bit-identical (slice, block, and PRNG key) to the same
    request submitted in-process to the same engine — the wire adds zero
    numeric change — and stays within float tolerance of the eager
    act_phase reference."""
    K = 2
    cfg, env, agent, slices, params, server, gw = _stack(K)
    clients = []
    try:
        clients = [PolicyClient(gw.host, gw.port, example=slices[0],
                                transport="tcp") for _ in range(K)]
        for t in range(K):
            sl_remote = sl_local = sl_eager = slices[t]
            for _ in range(3):
                # same input through both doors of the same engine: lone
                # requests ride identical padded waves, so results must
                # match bit-for-bit if the wire codec is truly lossless
                ref = server.act(sl_local, t)
                assert ref is not None
                out = clients[t].act(sl_remote, t)
                assert out is not None
                sl_remote, block, _metrics = out
                sl_local, ref_block, _ = ref
                _assert_slices_equal(sl_remote, sl_local)
                np.testing.assert_array_equal(
                    np.asarray(block.priorities),
                    np.asarray(ref_block.priorities))
                for a, b in zip(jax.tree.leaves(block.items),
                                jax.tree.leaves(ref_block.items)):
                    np.testing.assert_array_equal(_raw(a), _raw(b))
                # and the eager single-actor reference agrees numerically
                sl_eager, eager_block, _ = phases.act_phase(
                    cfg, env, agent, params, sl_eager, t)
                np.testing.assert_allclose(
                    np.asarray(block.priorities),
                    np.asarray(eager_block.priorities),
                    rtol=1e-5, atol=1e-6)
        snap = gw.snapshot()
        assert snap.act_requests == K * 3
    finally:
        for c in clients:
            c.close()
        gw.stop()
        server.stop()
    assert gw.error is None and server.error is None


def test_concurrent_clients_batch_into_shared_waves():
    """Concurrency across gateway connections *is* the batching: K clients
    submitting together must produce fewer dispatches than requests while
    every client still gets its own lane (distinct rng/eps shard)."""
    K, R = 3, 5
    cfg, env, agent, slices, params, server, gw = _stack(K)
    results = [[] for _ in range(K)]
    clients = []
    try:
        clients = [PolicyClient(gw.host, gw.port, example=slices[0],
                                transport="tcp") for _ in range(K)]
        barrier = threading.Barrier(K)

        def worker(t):
            sl = slices[t]
            for _ in range(R):
                barrier.wait(timeout=60.0)
                out = clients[t].act(sl, t)
                assert out is not None
                sl, block, _ = out
                results[t].append(block)

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in range(K)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120.0)
            assert not th.is_alive()
        stats = server.snapshot()
        assert stats.requests == K * R
        assert stats.dispatches < K * R  # batched, not serialized
        for t in range(K):  # lanes never cross-wire
            sl = slices[t]
            for r in range(R):
                sl, ref_block, _ = phases.act_phase(cfg, env, agent, params,
                                                    sl, t)
                np.testing.assert_allclose(
                    np.asarray(results[t][r].priorities),
                    np.asarray(ref_block.priorities), rtol=1e-5, atol=1e-6)
    finally:
        for c in clients:
            c.close()
        gw.stop()
        server.stop()
    assert gw.error is None and server.error is None


def test_engine_stop_propagates_stop_to_remote_client():
    """When the runtime stops the engine, a remote act() must resolve to
    None (the thin client's clean-exit signal), not hang or error."""
    K = 2
    cfg, env, agent, slices, params, server, gw = _stack(K)
    client = None
    try:
        client = PolicyClient(gw.host, gw.port, example=slices[0],
                              transport="tcp")
        out = client.act(slices[0], 0)  # plane is live first
        assert out is not None
        server.stop(join=False)
        assert client.act(slices[0], 0) is None
        assert client.stats["stopped"] == 1
    finally:
        if client is not None:
            client.close()
        gw.stop()
        server.stop()
    assert gw.error is None and server.error is None


def test_policy_only_gateway_contains_fabric_frames():
    """A policy-only gateway (fabric=None) must reject ADD_BLOCK as a
    per-connection wire error — and survive to serve the next client."""
    K = 1
    cfg, env, agent, slices, params, server, gw = _stack(K)
    client = None
    try:
        sock = socket.create_connection((gw.host, gw.port), timeout=5.0)
        try:
            sock.sendall(wire.frame(wire.ADD_BLOCK,
                                    wire.encode_tree({"x": np.zeros(3)})))
            deadline = time.monotonic() + 5.0
            while gw.snapshot().wire_errors < 1:
                assert time.monotonic() < deadline
                time.sleep(0.005)
        finally:
            sock.close()
        # the gateway survives and still serves the policy plane
        client = PolicyClient(gw.host, gw.port, example=slices[0],
                              transport="tcp")
        assert client.act(slices[0], 0) is not None
    finally:
        if client is not None:
            client.close()
        gw.stop()
        server.stop()
    assert gw.error is None and server.error is None


def test_gateway_requires_engine_or_fabric():
    store = ParamStore({"w": jnp.zeros((2,))})
    try:
        ReplayGateway(None, store)
    except ValueError as e:
        assert "neither" in str(e)
    else:
        raise AssertionError("fabric-less, engine-less gateway accepted")
    preset = tiny_preset()
    sl = init_actor(preset.apex, preset.env, jax.random.key(0))[0]
    try:
        ReplayGateway(None, store, inference=object())
    except ValueError as e:
        assert "act_example" in str(e)
    else:
        raise AssertionError("engine without act_example accepted")
    del sl
