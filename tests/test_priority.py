"""Priority math: eps-ladder, IS weights, TD errors (paper §4.1, Schaul'16)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import priority as prio


def test_epsilon_ladder_paper_values():
    """eps_i = 0.4^(1 + 7i/(N-1)): first actor 0.4, last 0.4^8."""
    eps = np.asarray(prio.epsilon_ladder(360))
    assert eps[0] == pytest.approx(0.4)
    assert eps[-1] == pytest.approx(0.4 ** 8, rel=1e-5)
    assert (np.diff(eps) < 0).all()  # monotone: lane 0 explores most


def test_epsilon_ladder_single_actor():
    assert float(prio.epsilon_ladder(1)[0]) == pytest.approx(0.4)


def test_fixed_epsilon_set_tiles():
    eps = np.asarray(prio.fixed_epsilon_set(12))
    assert len(set(eps.tolist())) == 6
    np.testing.assert_allclose(eps[:6], eps[6:])


def test_to_leaf_applies_exponent_and_floor():
    leaf = prio.to_leaf(jnp.asarray([0.0, 1.0, 4.0]), alpha=0.5)
    np.testing.assert_allclose(
        np.asarray(leaf), [prio.MIN_PRIORITY ** 0.5, 1.0, 2.0], rtol=1e-5)


def test_importance_weights_shape_and_norm():
    leaf = jnp.asarray([1.0, 2.0, 4.0])
    w = prio.importance_weights(leaf, jnp.asarray(7.0), jnp.asarray(100))
    w = np.asarray(w)
    assert w.max() == pytest.approx(1.0)
    # lower-probability samples get larger weights
    assert w[0] > w[1] > w[2]


def test_importance_weights_beta_zero_uniform():
    leaf = jnp.asarray([1.0, 5.0, 0.1])
    w = prio.importance_weights(leaf, jnp.asarray(6.1), jnp.asarray(10), beta=0.0)
    np.testing.assert_allclose(np.asarray(w), 1.0)


def test_td_error_nstep():
    d = prio.td_error_nstep(jnp.asarray(1.0), jnp.asarray(2.0),
                            jnp.asarray(0.9), jnp.asarray(3.0))
    assert float(d) == pytest.approx(2.0 + 0.9 * 3.0 - 1.0)
