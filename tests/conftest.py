"""Suite-wide fixtures.

The full suite JIT-compiles several hundred distinct XLA programs in one
process. On some jaxlib builds the accumulated live executables eventually
segfault LLVM's code emission partway through the run (observed: a plain
`lax.scan` compile crashing in `backend_compile` only when every earlier
module had run first — each half of the suite passes in isolation).
Dropping compiled-program caches between modules keeps the live-executable
population bounded; modules recompile what they actually use.
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
    gc.collect()
