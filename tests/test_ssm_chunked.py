"""Chunked (block-parallel) SSM paths vs their exact sequential oracles —
the §Perf rewrite that turns Mamba2/RWKV6 training into MXU matmuls."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.models import registry
from repro.models.ssm import (mamba2_apply, mamba2_init, rwkv6_init,
                              rwkv6_timemix)


def _mamba_cfg(chunk=8):
    cfg = registry.get_config("zamba2-2.7b").reduced()
    return dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                            chunk=chunk))


@pytest.mark.parametrize("L", [16, 37, 64, 100])
def test_mamba2_chunked_matches_scan(L):
    cfg = _mamba_cfg()
    p = mamba2_init(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(L), (2, L, cfg.d_model))
    y1, s1 = mamba2_apply(p, cfg, x, return_state=True, method="scan")
    y2, s2 = mamba2_apply(p, cfg, x, return_state=True, method="chunked")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1["ssm"]), np.asarray(s2["ssm"]),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_chunked_with_initial_state():
    cfg = _mamba_cfg()
    p = mamba2_init(jax.random.key(0), cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.key(1), (1, 24, cfg.d_model))
    _, st = mamba2_apply(p, cfg, x, return_state=True, method="scan")
    st0 = jax.tree.map(lambda a: 0.3 * jnp.ones_like(a), st)
    y1, _ = mamba2_apply(p, cfg, x, state=st0, return_state=True, method="scan")
    y2, _ = mamba2_apply(p, cfg, x, state=st0, return_state=True,
                         method="chunked")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("L", [16, 33, 64])
def test_rwkv6_chunked_matches_scan(L):
    cfg = registry.get_config("rwkv6-1.6b").reduced()
    p = rwkv6_init(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(L), (2, L, cfg.d_model))
    y1, s1 = rwkv6_timemix(p, cfg, x, return_state=True, method="scan")
    y2, s2 = rwkv6_timemix(p, cfg, x, return_state=True, method="chunked")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1["wkv"]), np.asarray(s2["wkv"]),
                               rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), L=st.integers(8, 48))
def test_mamba2_chunked_property(seed, L):
    """Chunk boundaries never change the result (any L vs chunk=8)."""
    cfg = _mamba_cfg()
    p = mamba2_init(jax.random.key(seed), cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.key(seed + 1), (1, L, cfg.d_model))
    y1, _ = mamba2_apply(p, cfg, x, method="scan")
    y2, _ = mamba2_apply(p, cfg, x, method="chunked")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=5e-4, atol=5e-4)
