"""Substrate tests: envs, optimizers, data pipeline, checkpointing, learner
losses (manual-math checks)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import learner
from repro.data import pipeline
from repro.envs.synthetic import ChainWorld, PointMass, batch_reset, batch_step
from repro.optim import optimizers as optim


# --- envs ------------------------------------------------------------------

def test_chainworld_contract():
    env = ChainWorld(length=8, max_steps=10)
    states, obs = batch_reset(env, jax.random.key(0), 4)
    assert obs.shape == (4, 10) and obs.dtype == jnp.uint8
    for _ in range(12):
        a = jnp.ones((4,), jnp.int32)  # always right
        states, out = batch_step(env, states, a)
    # moving right reaches the goal in 7 steps: all lanes saw a terminal
    assert out.obs.shape == (4, 10)


def test_chainworld_goal_reward_and_reset():
    env = ChainWorld(length=4, max_steps=50, slip_prob=0.0)
    states, _ = batch_reset(env, jax.random.key(0), 1)
    rewards, discounts = [], []
    for _ in range(3):
        states, out = batch_step(env, states, jnp.ones((1,), jnp.int32))
        rewards.append(float(out.reward[0]))
        discounts.append(float(out.discount[0]))
    assert rewards == [0.0, 0.0, 1.0]       # goal at pos 3
    assert discounts[-1] == 0.0             # terminal
    assert int(states.pos[0]) == 0          # auto-reset


def test_pointmass_contract():
    env = PointMass(max_steps=5)
    states, obs = batch_reset(env, jax.random.key(0), 3)
    assert obs.shape == (3, 6)
    for _ in range(5):
        states, out = batch_step(env, states, jnp.zeros((3, 2)))
    assert float(out.discount[0]) == 0.0    # timeout terminal


# --- optimizers -------------------------------------------------------------

def test_centered_rmsprop_matches_manual():
    opt = optim.centered_rmsprop(learning_rate=0.1, decay=0.9, eps=1e-8)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -1.0])}
    state = opt.init(p)
    up, state = opt.update(g, state, p)
    ms = 0.1 * np.asarray([0.25, 1.0])
    mg = 0.1 * np.asarray([0.5, -1.0])
    expect = -0.1 * np.asarray([0.5, -1.0]) / np.sqrt(ms - mg * mg + 1e-8)
    np.testing.assert_allclose(np.asarray(up["w"]), expect, rtol=1e-5)


def test_adam_bias_correction_first_step():
    opt = optim.adam(learning_rate=1.0, b1=0.9, b2=0.999, eps=0.0)
    p = {"w": jnp.asarray([0.0])}
    g = {"w": jnp.asarray([3.0])}
    state = opt.init(p)
    up, _ = opt.update(g, state, p)
    # first Adam step with bias correction = -lr * sign-ish(g)
    np.testing.assert_allclose(np.asarray(up["w"]), [-1.0], rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped = optim.clip_by_global_norm(g, 1.0)  # norm is 5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6], rtol=1e-5)
    # under the threshold: untouched
    same = optim.clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(same["b"]), [4.0])


def test_periodic_target_update():
    p = {"w": jnp.asarray([5.0])}
    t = {"w": jnp.asarray([0.0])}
    t1 = optim.periodic_target_update(p, t, jnp.asarray(3), 4)
    assert float(t1["w"][0]) == 0.0
    t2 = optim.periodic_target_update(p, t, jnp.asarray(4), 4)
    assert float(t2["w"][0]) == 5.0


# --- learner losses ----------------------------------------------------------

def test_dqn_loss_manual():
    """Double-Q n-step loss against hand-computed numbers."""
    q_table = {"s": jnp.asarray([[1.0, 2.0], [0.5, 0.25]])}

    def apply_fn(params, obs):
        # obs is an index selecting a row of the table
        return params["s"][obs]

    out = learner.dqn_loss(
        q_table, {"s": q_table["s"] * 0.5}, apply_fn,
        obs=jnp.asarray([0]), action=jnp.asarray([1]),
        returns=jnp.asarray([1.0]), discount_n=jnp.asarray([0.9]),
        next_obs=jnp.asarray([1]), is_weights=jnp.asarray([2.0]))
    # online argmax at next state row1 -> action 0 (0.5 > 0.25)
    # target q = 0.5 * 0.5 = 0.25 ; G = 1 + .9*.25 = 1.225 ; td = G - 2 = -0.775
    assert float(out.new_priorities[0]) == pytest.approx(0.775, rel=1e-5)
    assert float(out.loss) == pytest.approx(0.5 * 2.0 * 0.775 ** 2, rel=1e-5)


def test_sequence_loss_masks_and_weights():
    logits = jnp.zeros((2, 3, 4))  # uniform => nll = log(4)

    def apply_fn(params, tokens):
        return logits

    labels = jnp.asarray([[0, 1, -1], [2, -1, -1]])
    out = learner.sequence_loss({}, apply_fn, jnp.zeros((2, 3), jnp.int32),
                                labels, jnp.asarray([1.0, 0.5]))
    np.testing.assert_allclose(np.asarray(out.new_priorities),
                               np.log(4.0), rtol=1e-5)
    assert float(out.loss) == pytest.approx(np.log(4.0) * 0.75, rel=1e-5)


# --- data pipeline ------------------------------------------------------------

def test_pipeline_deterministic_and_sharded():
    cfg = pipeline.PipelineConfig(vocab_size=1000, seq_len=32, batch_size=4)
    rng = jax.random.key(0)
    a = pipeline.make_batch(cfg, rng, step=3, shard=0)
    b = pipeline.make_batch(cfg, rng, step=3, shard=0)
    c = pipeline.make_batch(cfg, rng, step=3, shard=1)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert a["tokens"].shape == (4, 32)
    assert (np.asarray(a["tokens"]) < 1000).all()
    assert (np.asarray(a["labels"][:, -1]) == -1).all()


def test_pipeline_languages_have_different_entropy():
    """Prioritization needs per-sequence loss differences: low-temperature
    languages repeat symbols more."""
    cfg = pipeline.PipelineConfig(vocab_size=1000, seq_len=256, batch_size=32)
    batch = pipeline.make_batch(cfg, jax.random.key(1), step=0)
    uniq = [len(set(row.tolist())) for row in np.asarray(batch["tokens"])]
    assert max(uniq) > 2 * min(uniq)  # spread of per-doc diversity


# --- checkpoint ----------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "step": jnp.asarray(7, jnp.int32)}
    path = str(tmp_path / "ckpt_7.npz")
    ckpt.save(path, tree, step=7)
    restored = ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert int(restored["step"]) == 7
    assert ckpt.latest(str(tmp_path)) == path


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ckpt_1.npz")
    ckpt.save(path, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        ckpt.restore(path, {"w": jnp.zeros((3,))})
