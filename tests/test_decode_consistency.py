"""Prefill + token-by-token decode must reproduce full-sequence logits for
every decoder architecture (KV/latent/SSM/WKV cache correctness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry, transformer

DECODERS = [a for a in registry.ARCH_IDS
            if not registry.get_config(a).encoder_only]


@pytest.mark.parametrize("arch", DECODERS)
def test_decode_matches_full_forward(arch):
    cfg = registry.get_config(arch).reduced()
    rng = jax.random.key(0)
    params = transformer.init(cfg, rng)
    B, S, prompt = 2, 16, 9
    off = 4 if cfg.input_mode == "mixed" else 0
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.input_mode == "mixed":
        kw["prefix_embeddings"] = jax.random.normal(
            rng, (B, off, cfg.d_model), jnp.float32)
    full = transformer.apply(params, toks, cfg=cfg, **kw)
    cache = transformer.init_cache(cfg, B, S + off)
    logits, cache = transformer.prefill(params, toks[:, :prompt], cfg=cfg,
                                        cache=cache, **kw)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, off + prompt - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(prompt, S):
        step_logits, cache = transformer.decode_step(
            params, toks[:, t:t + 1], jnp.asarray(off + t), cfg=cfg, cache=cache)
        np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                   np.asarray(full[:, off + t]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", DECODERS)
def test_chunked_prefill_matches_full_prefill(arch):
    """prefill_chunk consuming the prompt C tokens at a time (+ decode tail)
    must land the cache exactly where one full prefill would — the invariant
    the ContinuousBatcher's admission path rests on."""
    cfg = registry.get_config(arch).reduced()
    if getattr(cfg, "swa_ring_cache", False):
        pytest.skip("ring cache layout takes the unchunked path")
    params = transformer.init(cfg, jax.random.key(0))
    B, S, prompt, C = 2, 16, 11, 4
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    full = transformer.apply(params, toks, cfg=cfg)

    # chunked: (prompt-1)//C full chunks, remainder + last token via decode
    cache = transformer.init_cache(cfg, B, S)
    nfull = (prompt - 1) // C
    for k in range(nfull):
        _, cache = transformer.prefill_chunk(
            params, toks[:, k * C:(k + 1) * C], jnp.asarray(k * C),
            cfg=cfg, cache=cache)
    logits = None
    for t in range(nfull * C, prompt):
        logits, cache = transformer.decode_step(
            params, toks[:, t:t + 1], jnp.asarray(t), cfg=cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, prompt - 1]),
                               rtol=2e-3, atol=2e-3)
    # and continued decode stays on the full-forward trajectory
    for t in range(prompt, S):
        logits, cache = transformer.decode_step(
            params, toks[:, t:t + 1], jnp.asarray(t), cfg=cfg, cache=cache)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b"])
def test_sliding_window_decode(arch):
    """SWA decode with positions beyond the window stays consistent."""
    cfg = registry.get_config(arch).reduced()  # window=32 in reduced
    assert cfg.sliding_window is not None
    params = transformer.init(cfg, jax.random.key(0))
    B, S = 1, 48  # exceeds the 32-token window
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full = transformer.apply(params, toks, cfg=cfg)
    cache = transformer.init_cache(cfg, B, S)
    _, cache = transformer.prefill(params, toks[:, :40], cfg=cfg, cache=cache)
    for t in range(40, S):
        logits, cache = transformer.decode_step(
            params, toks[:, t:t + 1], jnp.asarray(t), cfg=cfg, cache=cache)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_swa_ring_cache_matches_full_cache():
    """O(window) ring KV cache (§Perf iteration 7): decoding far beyond the
    window with the ring must match the full-cache/full-forward logits."""
    import dataclasses
    cfg = registry.get_config("h2o-danube-1.8b").reduced()  # window=32
    ring_cfg = dataclasses.replace(cfg, swa_ring_cache=True)
    B, S, prompt = 2, 80, 20
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    params = transformer.init(cfg, jax.random.key(0))
    full = transformer.apply(params, toks, cfg=cfg)
    cache = transformer.init_cache(ring_cfg, B, S)
    assert cache["k"].shape[2] == cfg.sliding_window  # O(window) allocation
    _, cache = transformer.prefill(params, toks[:, :prompt], cfg=ring_cfg,
                                   cache=cache)
    for t in range(prompt, S):
        lg, cache = transformer.decode_step(
            params, toks[:, t:t + 1], jnp.asarray(t), cfg=ring_cfg, cache=cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)
