"""n-step construction: ring == trajectory == manual; episode truncation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import nstep


def manual_nstep(reward, discount, n):
    lanes, T = reward.shape
    W = T - n + 1
    R = np.zeros((lanes, W))
    G = np.ones((lanes, W))
    for t in range(W):
        d = np.ones(lanes)
        for k in range(n):
            R[:, t] += d * reward[:, t + k]
            d = d * discount[:, t + k]
        G[:, t] = d
    return R, G


@settings(max_examples=30, deadline=None)
@given(
    lanes=st.integers(1, 5), T=st.integers(1, 12), n=st.integers(1, 6),
    seed=st.integers(0, 100),
)
def test_from_trajectory_matches_manual(lanes, T, n, seed):
    if T < n:
        T = n
    rng = np.random.RandomState(seed)
    reward = rng.randn(lanes, T).astype(np.float32)
    discount = (rng.rand(lanes, T) > 0.2).astype(np.float32) * 0.97
    R, G = nstep.from_trajectory(jnp.asarray(reward), jnp.asarray(discount), n)
    R_m, G_m = manual_nstep(reward, discount, n)
    np.testing.assert_allclose(np.asarray(R), R_m, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(G), G_m, rtol=1e-5, atol=1e-5)


def test_episode_truncation_blocks_reward_leak():
    """A terminal (discount 0) inside the window truncates: later rewards
    (from the next episode) must not contribute."""
    reward = jnp.asarray([[1.0, 1.0, 100.0, 100.0]])
    discount = jnp.asarray([[0.9, 0.0, 0.9, 0.9]])  # terminal after step 1
    R, G = nstep.from_trajectory(reward, discount, 3)
    # window at t=0: 1 + 0.9*1 + 0.9*0*100 = 1.9 ; gamma^n = 0
    assert float(R[0, 0]) == pytest.approx(1.9)
    assert float(G[0, 0]) == 0.0


def test_ring_matches_trajectory():
    """Streaming ring (paper Appendix F) emits the same transitions as bulk
    trajectory construction."""
    lanes, T, n = 3, 12, 3
    rng = np.random.RandomState(0)
    reward = rng.randn(lanes, T).astype(np.float32)
    discount = (rng.rand(lanes, T) > 0.15).astype(np.float32) * 0.99
    obs = rng.randn(lanes, T + 1, 4).astype(np.float32)

    ring = nstep.ring_init({"obs": jnp.zeros((lanes, 4))}, n, lanes)
    emitted = []
    for t in range(T):
        ring, tr = nstep.ring_push(
            ring, {"obs": jnp.asarray(obs[:, t])},
            jnp.asarray(reward[:, t]), jnp.asarray(discount[:, t]), n)
        if bool(tr.valid[0]):
            emitted.append(tr)
    R_traj, G_traj = nstep.from_trajectory(jnp.asarray(reward),
                                           jnp.asarray(discount), n)
    # ring emits transition for t-n when pushing t; first valid push is t=n
    # (ring needs n+1 records) => windows 0..T-n-1 (one fewer than bulk, whose
    # last window uses obs[T] which the ring hasn't seen as a *record*)
    assert len(emitted) == T - n
    for w, tr in enumerate(emitted):
        np.testing.assert_allclose(np.asarray(tr.returns),
                                   np.asarray(R_traj[:, w]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(tr.discount_n),
                                   np.asarray(G_traj[:, w]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(tr.first["obs"]), obs[:, w])
        np.testing.assert_allclose(np.asarray(tr.last["obs"]), obs[:, w + n])


def test_ring_not_valid_before_warm():
    ring = nstep.ring_init({"o": jnp.zeros((2, 1))}, 3, 2)
    for t in range(3):
        ring, tr = nstep.ring_push(ring, {"o": jnp.ones((2, 1))},
                                   jnp.ones(2), jnp.ones(2), 3)
        assert not bool(tr.valid[0])
    ring, tr = nstep.ring_push(ring, {"o": jnp.ones((2, 1))},
                               jnp.ones(2), jnp.ones(2), 3)
    assert bool(tr.valid[0])
