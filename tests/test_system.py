"""System-level behaviour: the paper's architectural claims at toy scale.

These encode Ape-X's *qualitative* findings (prioritization beats uniform;
learner gates on min-fill; replay is sharded; actors are disposable) as cheap
CPU tests — the quantitative versions live in benchmarks/.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import apex_dqn
from repro.core import apex, priority as prio, replay as replay_lib
from repro.launch import mesh as mesh_lib


def run(cfg, preset, iters, seed=0):
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(cfg, preset.env, preset.agent,
                                          optimizer)
    state = init_fn(jax.random.key(seed))
    returns = []
    for _ in range(iters):
        state, m = step_fn(state)
        r = float(m["mean_ep_return"])
        if not np.isnan(r):
            returns.append(r)
    return state, returns


def test_prioritized_beats_uniform_on_sparse_reward():
    """Paper Fig. 12: prioritized replay extracts more from the same data on
    sparse-reward tasks. alpha=0 recovers uniform sampling. At toy scale the
    comparison is noisy, so it is seed-averaged with a loose margin — the
    quantitative version is benchmarks/bench_prioritization.py."""
    preset = apex_dqn.reduced()
    iters = 70
    scores = {"prioritized": [], "uniform": []}
    for name, alpha in (("prioritized", 0.6), ("uniform", 0.0)):
        cfg = dataclasses.replace(
            preset.apex,
            replay=dataclasses.replace(preset.apex.replay, alpha=alpha,
                                       beta=0.4 if alpha else 0.0))
        for seed in (1, 2, 3):
            _, rets = run(cfg, preset, iters, seed=seed)
            scores[name].append(np.mean(rets[-20:]) if rets else 0.0)
    p, u = np.mean(scores["prioritized"]), np.mean(scores["uniform"])
    assert np.isfinite(p) and np.isfinite(u)
    assert p >= u - 0.5, (p, u)


def test_learner_waits_for_min_fill():
    preset = apex_dqn.reduced()
    cfg = dataclasses.replace(
        preset.apex,
        replay=dataclasses.replace(preset.apex.replay, min_fill=10_000))
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(cfg, preset.env, preset.agent,
                                          optimizer)
    state = init_fn(jax.random.key(0))
    state, m = step_fn(state)
    assert float(m["updated"]) == 0.0        # gate held
    assert int(state.learner_step) == 0


def test_replay_is_sharded_not_replicated():
    """Cross-shard isolation: the paper's 'shared' memory is logical —
    physical shards never exchange items."""
    mesh = mesh_lib.make_mesh((1,), ("data",))
    preset = apex_dqn.reduced(num_shards=1)
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(
        preset.apex, preset.env, preset.agent, optimizer, mesh=mesh)
    state = init_fn(jax.random.key(0))
    state, _ = step_fn(state)
    # replay storage carries the shard leading axis
    assert state.replay.storage["obs"].shape[0] == 1


def test_eps_ladder_spans_shards():
    """Global ladder: lane (shard s, lane l) uses eps_{s*L+l}."""
    preset = apex_dqn.reduced()
    cfg = dataclasses.replace(preset.apex, num_shards=4, lanes_per_shard=8)
    e0 = np.asarray(apex.lane_epsilons(cfg, 0))
    e3 = np.asarray(apex.lane_epsilons(cfg, 3))
    full = np.asarray(prio.epsilon_ladder(32))
    np.testing.assert_allclose(e0, full[:8], rtol=1e-6)
    np.testing.assert_allclose(e3, full[24:], rtol=1e-6)


def test_failure_tolerance_actor_state_disposable():
    """Paper Appendix F: actors may be killed at any time. Re-initializing
    env/actor state (keeping learner + replay) must keep training running."""
    preset = apex_dqn.reduced()
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(preset.apex, preset.env,
                                          preset.agent, optimizer)
    state = init_fn(jax.random.key(0))
    for _ in range(6):
        state, _ = step_fn(state)
    # "restart" actors: fresh env state + rng, keep learner state and replay
    fresh = init_fn(jax.random.key(99))
    state = state._replace(env_state=fresh.env_state, obs=fresh.obs,
                           rng=fresh.rng, ep_return=fresh.ep_return)
    for _ in range(4):
        state, m = step_fn(state)
    assert bool(jnp.isfinite(m["loss"]))
    assert int(state.learner_step) > 0
