"""Continuous batching: per-row decode positions. Rows decode at independent
offsets within one batched step and must match the full forward pass at each
row's own position."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import ContinuousBatcher, WaveBatcher
from repro.models import registry, transformer
from repro.runtime import ParamStore

ARCHS = ["llama3.2-1b", "deepseek-v2-236b", "h2o-danube-1.8b", "stablelm-1.6b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_ragged_decode_matches_full(arch):
    cfg = registry.get_config(arch).reduced()
    rng = jax.random.key(0)
    params = transformer.init(cfg, rng)
    B, S = 3, 18
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = transformer.apply(params, toks, cfg=cfg)
    cache = transformer.init_cache(cfg, B, S)
    # rows staggered: row b starts decoding from position 0 but with a lag of
    # 2*b steps — at any instant the batch holds three different positions
    lags = np.array([0, 2, 4])
    pos = jnp.asarray(-lags, jnp.int32)  # negative = not yet started
    errs = []
    for step in range(S + int(lags.max())):
        cur = np.asarray(pos)
        active = (cur >= 0) & (cur < S)
        safe = np.clip(cur, 0, S - 1)
        tok = toks[jnp.arange(B), jnp.asarray(safe)][:, None]
        step_pos = jnp.asarray(np.maximum(cur, 0), jnp.int32)
        logits, cache = transformer.decode_step(params, tok, step_pos,
                                                cfg=cfg, cache=cache)
        for b in range(B):
            if active[b]:
                errs.append(float(jnp.max(
                    jnp.abs(logits[b, 0] - full[b, cur[b]]))))
        pos = pos + 1
    assert max(errs) < 5e-3


def test_continuous_batcher_serves_ragged_requests():
    """The batcher admits requests as slots free and completes all of them."""
    cfg = registry.get_config("llama3.2-1b").reduced()
    params = transformer.init(cfg, jax.random.key(0))
    rng = jax.random.key(1)
    requests = [jax.random.randint(jax.random.fold_in(rng, i),
                                   (np.random.RandomState(i).randint(3, 9),),
                                   0, cfg.vocab_size)
                for i in range(7)]
    batcher = ContinuousBatcher(cfg, params, slots=3, max_len=32,
                                max_new_tokens=5)
    results = batcher.run([np.asarray(r) for r in requests])
    assert len(results) == 7
    for i, out in results.items():
        assert 1 <= len(out) <= 5
        assert all(0 <= t < cfg.vocab_size for t in out)


def _ragged_prompts(cfg, n, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, size=rng.randint(3, 11))
            for _ in range(n)]


def test_wave_and_continuous_emit_identical_tokens():
    """Scheduling must not change tokens: the wave-coalescing baseline and
    the continuous scheduler run the same compiled step/prefill, so every
    request's greedy output is identical — only the step count (the barrier
    tax) differs."""
    cfg = registry.get_config("llama3.2-1b").reduced()
    params = transformer.init(cfg, jax.random.key(0))
    prompts = _ragged_prompts(cfg, 7)
    budgets = [1 + (i * 3) % 6 for i in range(7)]  # ragged new-token budgets
    cont = ContinuousBatcher(cfg, params, slots=3, max_len=32,
                             max_new_tokens=6)
    wave = WaveBatcher(cfg, params, slots=3, max_len=32, max_new_tokens=6)
    out_c = cont.run(prompts, new_tokens=budgets)
    out_w = wave.run(prompts, new_tokens=budgets)
    assert out_c == out_w
    assert all(len(out_c[i]) == budgets[i] for i in range(7))
    # the barrier really was a barrier: wave pays at least as many steps
    assert wave.steps >= cont.steps


def test_chunked_prefill_is_a_pure_optimization():
    """prefill_chunk on vs off must emit identical tokens (the chunk path
    only changes how prompts enter the cache, never what comes out)."""
    cfg = registry.get_config("llama3.2-1b").reduced()
    params = transformer.init(cfg, jax.random.key(0))
    prompts = _ragged_prompts(cfg, 5, seed=11)
    chunked = ContinuousBatcher(cfg, params, slots=2, max_len=32,
                                max_new_tokens=4, prefill_chunk=4)
    stepwise = ContinuousBatcher(cfg, params, slots=2, max_len=32,
                                 max_new_tokens=4, prefill_chunk=0)
    assert chunked._chunk == 4 and stepwise._chunk == 0
    out_a, out_b = chunked.run(prompts), stepwise.run(prompts)
    assert out_a == out_b
    # the chunk path genuinely replaced prompt decode steps
    assert chunked.steps < stepwise.steps


def test_hot_swap_drains_in_flight_requests_under_churn():
    """Version churn mid-run: the batcher must finish every admitted request
    on its admission-time params (admission == completion version), take the
    swap only when slots drain, and drop nothing."""
    cfg = registry.get_config("llama3.2-1b").reduced()
    params = transformer.init(cfg, jax.random.key(0))
    store = ParamStore(params)
    prompts = _ragged_prompts(cfg, 8, seed=5)

    published = []

    def churn(step):
        # publish twice at deterministic schedule points; identical params
        # (fresh version) keep outputs comparable to the no-churn run
        if step in (3, 9):
            published.append(store.publish(params))

    batcher = ContinuousBatcher(cfg, params, slots=3, max_len=32,
                                max_new_tokens=4, param_store=store,
                                on_step=churn)
    out = batcher.run(prompts)
    assert len(out) == len(prompts)            # zero drops
    assert len(published) == 2
    assert batcher.swaps >= 1                  # churn was observed and taken
    for rid in range(len(prompts)):
        assert rid in batcher.admission_version
        # the hot-swap contract: a request completes on the params it was
        # admitted under — the swap waited for it
        assert (batcher.admission_version[rid]
                == batcher.completion_version[rid])
    # final version converged onto the last publication
    assert batcher._version == store.version
    # and because the published trees were identical, the served tokens
    # match a churn-free run exactly
    baseline = ContinuousBatcher(cfg, params, slots=3, max_len=32,
                                 max_new_tokens=4)
    assert out == baseline.run(prompts)


def test_continuous_batcher_ssm_state_isolation():
    """Slot reuse must not leak SSM recurrent state across requests: serving
    the same prompt as request #1 and as a slot-reused later request must
    produce identical outputs."""
    cfg = registry.get_config("rwkv6-1.6b").reduced()
    params = transformer.init(cfg, jax.random.key(0))
    rng = np.random.RandomState(3)
    probe = rng.randint(0, cfg.vocab_size, size=6)
    fillers = [rng.randint(0, cfg.vocab_size, size=5) for _ in range(2)]
    # run A: probe alone
    b1 = ContinuousBatcher(cfg, params, slots=1, max_len=32, max_new_tokens=4)
    solo = b1.run([probe])[0]
    # run B: two fillers first on one slot, probe reuses the slot afterwards
    b2 = ContinuousBatcher(cfg, params, slots=1, max_len=32, max_new_tokens=4)
    out = b2.run([fillers[0], fillers[1], probe])
    assert out[2] == solo
