"""Transport plane: scatter-gather encode identity, the tcp/shm Transport
pair behind one API, ring wraparound + backpressure, the auto-upgrade
handshake and its fallback, and teardown semantics (either side may win the
shutdown race; a writer killed mid-frame must never hang the reader)."""

import socket
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest
from _apex_helpers import make_block, tiny_preset
from _hypothesis_fallback import given, settings, st

from repro.core import codec
from repro.net import transport, wire
from repro.net.gateway import ReplayGateway
from repro.net.learner_client import RemoteFabricSource
from repro.runtime import ParamStore
from repro.runtime.sources import SourceClosed


# --- scatter-gather encode: bitwise identity ---------------------------------

def _join(segments) -> bytes:
    return b"".join(bytes(memoryview(s)) for s in segments)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 96),
       dim=st.integers(1, 48))
def test_tree_iov_bitwise_identical_to_concatenated(seed, n, dim):
    """Property (acceptance): the iovec encoder hands out buffer views whose
    concatenation is byte-for-byte the classic single-buffer encoding —
    leaves straddle the inline threshold in both directions."""
    rng = np.random.RandomState(seed)
    tree = {
        "big_f32": rng.randn(n, dim).astype(np.float32),    # usually > 1 KiB
        "tiny": rng.randint(0, 256, (3,), np.uint8),        # always inlined
        "i64": rng.randint(-9, 9, (n,), np.int64),
        "scalar": np.float32(rng.randn()),
        "nested": {"a": rng.randn(dim).astype(np.float64),
                   "b": {"deep": rng.randint(0, 2, (n, 2), np.uint8)}},
    }
    assert _join(wire.encode_tree_iov(tree)) == wire.encode_tree(tree)


def test_block_batch_params_iov_twins_and_frames_identical():
    preset = tiny_preset()
    block = make_block(preset.apex, preset.env, preset.agent)
    assert _join(wire.encode_block_iov(block)) == wire.encode_block(block)
    assert (_join(wire.encode_block_iov(block, quantize_obs=True))
            == wire.encode_block(block, quantize_obs=True))

    from repro.core.sampling import LearnerBatch
    rng = np.random.default_rng(0)
    lb = LearnerBatch(rng.integers(0, 99, 8).astype(np.int32),
                      {"obs": rng.random((8, 2000)).astype(np.float32)},
                      rng.random(8).astype(np.float32))
    assert (_join(wire.encode_sample_batch_iov(lb))
            == wire.encode_sample_batch(lb))

    params = {"w": rng.random((700,)).astype(np.float32), "b": np.int32(3)}
    assert _join(wire.encode_params_iov(9, params)) == wire.encode_params(
        9, params)

    # ... and the framed wire bytes are identical too (what actually ships)
    payload = wire.encode_params(9, params)
    framed = wire.frame(wire.PARAM, payload)
    assert _join(wire.frame_iov(wire.PARAM,
                                wire.encode_params_iov(9, params))) == framed


# --- wire quantization beyond obs (satellite) --------------------------------

def test_priority_update_quantized_round_trip():
    rng = np.random.default_rng(1)
    idx = rng.integers(0, 1 << 16, 64).astype(np.int32)
    prios = rng.uniform(0.01, 4.0, 64).astype(np.float32)
    raw = wire.encode_priority_update(idx, prios)
    quant = wire.encode_priority_update(idx, prios, quantize=True)
    assert len(quant) < len(raw)  # uint8 data beats fp32 at this size
    idx2, prios2, counts = wire.decode_priority_update(quant)
    np.testing.assert_array_equal(idx2, idx)  # keys stay exact
    np.testing.assert_array_equal(counts, [64])
    # priorities are affine-quantized: codec-accurate, not bit-exact
    err = np.abs(prios2 - prios).max()
    assert err <= (prios.max() - prios.min()) / 254


def test_params_quantized_round_trip_and_exact_leaf_passthrough():
    rng = np.random.default_rng(2)
    params = {"w": (rng.standard_normal((64, 32)) * 0.3).astype(np.float32),
              "step": np.int32(17),          # non-float: must stay bit-exact
              "scale": np.float32(1.5)}      # scalar: stays bit-exact
    version, dec = wire.decode_params(wire.encode_params(
        5, params, quantize=True))
    assert version == 5
    assert dec["step"] == 17 and dec["step"].dtype == np.int32
    assert dec["scale"] == np.float32(1.5)
    w = params["w"]
    assert np.abs(dec["w"] - w).max() <= (w.max() - w.min()) / 254


def test_codec_single_api_dispatches_host_vs_device():
    """Satellite: one ``codec.encode``/``decode`` serving both backends —
    numpy in, numpy out (host path); jax in, jax out (device path) — with
    the legacy ``encode_np``/``decode_np`` names aliased to the host path."""
    x_np = np.linspace(-2, 2, 48, dtype=np.float32).reshape(6, 8)
    enc_host = codec.encode(x_np)
    assert isinstance(enc_host.data, np.ndarray)
    assert isinstance(codec.decode(enc_host), np.ndarray)
    enc_dev = codec.encode(jnp.asarray(x_np))
    assert not isinstance(enc_dev.data, np.ndarray)
    np.testing.assert_array_equal(enc_host.data, np.asarray(enc_dev.data))
    np.testing.assert_array_equal(codec.decode(enc_host),
                                  np.asarray(codec.decode(enc_dev)))
    assert codec.encode_np is not None and codec.decode_np is not None
    enc_legacy = codec.encode_np(x_np)
    np.testing.assert_array_equal(enc_legacy.data, enc_host.data)


# --- transport pairs ---------------------------------------------------------

def _pair(kind, *, ring_bytes=1 << 16, accept_shm=True):
    """A connected (client, server, listener) triple. For upgrade-seeking
    kinds the server runs one recv to serve the in-band handshake."""
    lst = transport.listen("127.0.0.1", 0, accept_shm=accept_shm,
                           ring_bytes=ring_bytes)
    box = {}

    def srv():
        conn = lst.accept(timeout=10.0)
        box["server"] = conn
        if kind != "tcp":
            conn.recv(timeout=1.0)  # serves SHM_REQ (upgrade or NACK)

    th = threading.Thread(target=srv, daemon=True)
    th.start()
    client = transport.connect("127.0.0.1", lst.port, kind,
                               ring_bytes=ring_bytes)
    th.join(timeout=10.0)
    assert "server" in box
    return client, box["server"], lst


def _close_all(*closeables):
    for c in closeables:
        try:
            c.close()
        except Exception:
            pass


@pytest.mark.parametrize("kind", ["tcp", "shm"])
def test_transport_pair_round_trips_data_and_control(kind):
    """Same bytes, either byte path: bulk data frames (ring on shm), small
    data frames (socket even on shm — below the ring cutover), and control
    frames (always socket) round trip bitwise in both directions, and both
    ends agree on the negotiated kind."""
    client, server, lst = _pair(kind)
    try:
        assert client.kind == kind and server.kind == kind
        rng = np.random.default_rng(5)
        # 32 KB of floats: above the ring cutover, so on shm this frame
        # genuinely rides the ring (the int32 batch below stays sub-cutover
        # and exercises the socket-routed data path).
        payload = wire.encode_tree({"x": rng.random((8000,)).astype(np.float32)})
        client.send(wire.ADD_BLOCK, payload)               # data plane
        client.send(wire.HELLO, wire.encode_json({"hi": 1}))  # control plane
        msg, got = server.recv(timeout=5.0)
        assert msg == wire.ADD_BLOCK and bytes(got) == payload
        msg, got = server.recv(timeout=5.0)
        assert msg == wire.HELLO and wire.decode_json(got) == {"hi": 1}
        # reverse direction, iovec payload
        server.send(wire.SAMPLE_BATCH, wire.encode_tree_iov(
            {"y": np.arange(500, dtype=np.int32)}))
        msg, got = client.recv(timeout=5.0)
        assert msg == wire.SAMPLE_BATCH
        np.testing.assert_array_equal(wire.decode_tree(got)["y"],
                                      np.arange(500, dtype=np.int32))
        assert client.bytes_out > 0 and server.bytes_in > 0
    finally:
        _close_all(client, server, lst)


def test_shm_small_ring_wraparound_under_backpressure():
    """Many frames through a ring a fraction of their aggregate size: the
    writer parks on ring-full, the reader frees space, every payload
    survives the split copies bitwise."""
    client, server, lst = _pair("shm", ring_bytes=1 << 12)  # 4 KiB ring
    n_frames, errs = 48, []
    rng = np.random.default_rng(6)
    payloads = [wire.encode_tree({"d": rng.integers(0, 256, 1500)
                                  .astype(np.uint8)}) for _ in range(n_frames)]

    def producer():
        try:
            for p in payloads:
                client.send(wire.ADD_BLOCK, p)
        except Exception as e:  # pragma: no cover - surfaced by the assert
            errs.append(e)

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    try:
        for i in range(n_frames):
            msg, got = server.recv(timeout=10.0)
            assert msg == wire.ADD_BLOCK
            assert bytes(got) == payloads[i], f"frame {i} corrupted"
        th.join(timeout=10.0)
        assert not errs
    finally:
        _close_all(client, server, lst)


def test_shm_frame_exceeding_ring_is_rejected_not_wedged():
    client, server, lst = _pair("shm", ring_bytes=1 << 12)
    try:
        with pytest.raises(wire.WireError, match="ring"):
            client.send(wire.ADD_BLOCK, b"x" * (1 << 13))
        # the connection survives the refusal
        client.send(wire.ADD_BLOCK, b"ok")
        msg, got = server.recv(timeout=5.0)
        assert (msg, bytes(got)) == (wire.ADD_BLOCK, b"ok")
    finally:
        _close_all(client, server, lst)


def test_auto_falls_back_to_tcp_when_refused_and_strict_shm_raises():
    client, server, lst = _pair("auto", accept_shm=False)
    try:
        assert client.kind == "tcp" and server.kind == "tcp"
        client.send(wire.ADD_BLOCK, b"still works")
        msg, got = server.recv(timeout=5.0)
        assert (msg, bytes(got)) == (wire.ADD_BLOCK, b"still works")
    finally:
        _close_all(client, server, lst)

    lst2 = transport.listen("127.0.0.1", 0, accept_shm=False)
    box = {}

    def srv():
        conn = lst2.accept(timeout=10.0)
        box["server"] = conn
        try:
            conn.recv(timeout=1.0)
        except EOFError:
            pass

    th = threading.Thread(target=srv, daemon=True)
    th.start()
    try:
        with pytest.raises(transport.ShmUnavailable):
            transport.connect("127.0.0.1", lst2.port, "shm")
        th.join(timeout=10.0)
    finally:
        _close_all(box.get("server"), lst2)


def test_ring_data_committed_before_control_is_delivered_first():
    """The cross-channel ordering rule: a data frame committed to the ring
    before a control frame's socket send is delivered before it — this is
    what makes flush-writebacks-then-BYE race-free."""
    client, server, lst = _pair("shm")
    try:
        # 4096 entries keeps the update above the ring cutover — the point
        # is ring-vs-socket ordering, not the small-frame socket path.
        client.send(wire.PRIORITY_UPDATE, wire.encode_priority_update(
            np.arange(4096, dtype=np.int32), np.ones(4096, np.float32)))
        client.send(wire.BYE, wire.encode_json({"rollouts": 1}))
        time.sleep(0.05)  # let both frames become readable before one recv
        msg, _ = server.recv(timeout=5.0)
        assert msg == wire.PRIORITY_UPDATE
        msg, _ = server.recv(timeout=5.0)
        assert msg == wire.BYE
    finally:
        _close_all(client, server, lst)


# --- teardown semantics (satellite) ------------------------------------------

@pytest.mark.parametrize("kind", ["tcp", "shm"])
def test_teardown_drains_committed_frames_then_eof(kind):
    """Either side may win the shutdown race: after the peer closes, frames
    it committed before dying are still delivered, then EOFError — on both
    byte paths."""
    client, server, lst = _pair(kind)
    try:
        last_words = b"last words! " * 4096   # above the ring cutover
        client.send(wire.ADD_BLOCK, last_words)
        client.close()
        msg, got = server.recv(timeout=5.0)
        assert (msg, bytes(got)) == (wire.ADD_BLOCK, last_words)
        with pytest.raises(EOFError):
            deadline = time.monotonic() + 5.0
            while time.monotonic() < deadline:
                server.recv(timeout=0.2)
    finally:
        _close_all(server, lst)


def test_shm_reader_fails_fast_when_writer_killed_mid_frame():
    """A writer killed mid-frame never published it (head only advances
    after the last byte), so the reader must see clean EOF *fast* — not a
    torn frame, not a hang."""
    client, server, lst = _pair("shm")
    try:
        committed = b"committed" * 4096       # above the ring cutover
        client.send(wire.ADD_BLOCK, committed)
        # simulate death mid-write: bytes in the data area, head NOT bumped
        ring = client._send_ring
        i = ring.head % ring.size
        ring._data[i:i + 64] = b"\xde" * 64
        client._sock.close()  # the "process died" signal

        msg, got = server.recv(timeout=5.0)   # committed frame survives
        assert (msg, bytes(got)) == (wire.ADD_BLOCK, committed)
        t0 = time.monotonic()
        with pytest.raises(EOFError):
            server.recv(timeout=10.0)
        assert time.monotonic() - t0 < 5.0, "reader hung on a torn frame"
    finally:
        _close_all(client, server, lst)


def test_shm_send_raises_transport_closed_when_peer_dies_with_ring_full():
    client, server, lst = _pair("shm", ring_bytes=1 << 12)
    errs = []

    def producer():
        try:
            while True:
                client.send(wire.ADD_BLOCK, b"z" * 1024)
        except Exception as e:
            errs.append(e)

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    try:
        time.sleep(0.2)        # let the producer fill the ring and park
        server.close()         # peer dies without ever consuming
        th.join(timeout=10.0)
        assert not th.is_alive(), "send wedged on a dead peer"
        assert errs and isinstance(errs[0], transport.TransportClosed)
    finally:
        _close_all(client, lst)


def test_shm_teardown_surfaces_source_closed_like_the_socket_path():
    """Satellite: the learner-plane contract on the ring path — when the
    serving gateway goes away, ``get_batch`` raises ``SourceClosed`` (fail
    fast), exactly like the socket path."""

    class StarvedFabric:
        def get_batch(self, timeout=None):
            return None

        def write_back(self, indices, priorities, trace_id=0):
            pass

    gw = ReplayGateway(StarvedFabric(), ParamStore({}),
                       sample_timeout_s=0.01).start()
    src = RemoteFabricSource(gw.host, gw.port, transport="shm").start()
    try:
        assert src.get_batch(timeout=1.0) is None  # connected and starved
        assert src.transport_kind == "shm"
        gw.stop()                                  # serving side wins teardown
        t0 = time.monotonic()
        with pytest.raises(SourceClosed):
            deadline = time.monotonic() + 10.0
            while time.monotonic() < deadline:
                src.get_batch(timeout=0.2)
        assert time.monotonic() - t0 < 8.0, "learner hung on a dead gateway"
    finally:
        src.stop()
        gw.stop()


# --- gateway over both transports (tier-1 matrix value) ----------------------

class RecordingFabric:
    def __init__(self):
        self.blocks = []

    def add(self, block, timeout=None, trace_id=0):
        self.blocks.append(block)
        return True


@pytest.mark.parametrize("kind", ["tcp", "shm"])
def test_gateway_routes_blocks_over_either_transport(kind):
    """The gateway's handler never knows which byte path a client chose:
    blocks route into the fabric identically over tcp and shm, and the
    stats record the upgrade."""
    preset = tiny_preset()
    block = make_block(preset.apex, preset.env, preset.agent)
    fabric = RecordingFabric()
    gw = ReplayGateway(fabric, ParamStore({"w": jnp.zeros(2)})).start()
    conn = transport.connect(gw.host, gw.port, kind)
    try:
        assert conn.kind == kind
        conn.send(wire.HELLO, wire.encode_json(
            {"actor_id": 0, "protocol": wire.PROTOCOL_VERSION}))
        conn.send(wire.ADD_BLOCK, wire.encode_block_iov(block))
        msg, _ = conn.recv(timeout=10.0)
        assert msg == wire.ADD_ACK
        assert len(fabric.blocks) == 1
        np.testing.assert_array_equal(fabric.blocks[0].priorities,
                                      np.asarray(block.priorities))
        # params serve over the same connection
        conn.send(wire.PARAM_PULL, wire.encode_json({"have": -1}))
        msg, payload = conn.recv(timeout=10.0)
        assert msg == wire.PARAM
        version, got = wire.decode_params(payload)
        assert version == 0
        np.testing.assert_array_equal(got["w"], np.zeros(2, np.float32))
        snap = gw.snapshot()
        assert snap.blocks_in == 1
        assert snap.shm_connections == (1 if kind == "shm" else 0)
    finally:
        conn.close()
        gw.stop()
    assert gw.error is None
