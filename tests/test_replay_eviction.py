"""``evict_prioritized`` freed-count accounting (paper Appendix D).

Victims are drawn WITH replacement from the eviction distribution, and a
victim may already be a free slot, so the size decrement must count distinct
*live* victims only — not the number of draws. These tests pin that
accounting down deterministically (no hypothesis needed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import replay, sumtree

CFG = replay.ReplayConfig(capacity=32, soft_capacity=24, min_fill=2)


def make_items(n, base=0):
    return {"x": jnp.arange(base, base + n, dtype=jnp.float32)}


def filled_state(n, priority=1.0):
    state = replay.init(CFG, {"x": jnp.zeros((), jnp.float32)})
    return replay.add_fifo(CFG, state, make_items(n),
                           jnp.full((n,), priority, jnp.float32))


def test_eviction_reduces_size_by_distinct_live_victims():
    state = filled_state(24)
    new = replay.evict_prioritized(CFG, state, jax.random.key(0), num=8)
    leaves = np.asarray(sumtree.leaves(new.tree))
    live_after = int((leaves > 0).sum())
    # size bookkeeping must agree exactly with the live-leaf count
    assert int(new.size) == live_after
    # with replacement, distinct victims <= draws
    assert 24 - int(new.size) <= 8
    assert int(new.size) >= 24 - 8


def test_duplicate_victims_only_freed_once():
    """Force duplicates: a single overwhelming-priority slot attracts nearly
    every draw, so 16 draws must evict far fewer than 16 items."""
    state = filled_state(24, priority=1e-6)
    state = replay.set_priorities(
        CFG, state, jnp.array([3]), jnp.array([1e6], jnp.float32))
    # evict_alpha < 0 inverts preference; use a config that prefers high
    # priority so the hot slot dominates the eviction distribution too
    cfg_hot = replay.ReplayConfig(capacity=32, soft_capacity=24, min_fill=2,
                                  evict_alpha=CFG.alpha)  # ratio = 1
    new = replay.evict_prioritized(cfg_hot, state, jax.random.key(1), num=16)
    freed = 24 - int(new.size)
    assert freed < 16          # duplicates collapsed
    assert freed >= 1          # but the hot slot itself went
    assert int(new.size) == int((np.asarray(sumtree.leaves(new.tree)) > 0).sum())


def test_evicting_already_free_slots_does_not_underflow():
    """Repeated eviction rounds never double-count dead slots or push size
    below the live count (or zero)."""
    state = filled_state(8)
    rng = jax.random.key(2)
    for i in range(6):
        rng, sub = jax.random.split(rng)
        state = replay.evict_prioritized(CFG, state, sub, num=8)
        leaves = np.asarray(sumtree.leaves(state.tree))
        assert int(state.size) == int((leaves > 0).sum())
        assert int(state.size) >= 0
    # everything dead by now: another round must be a no-op on size
    before = int(state.size)
    state = replay.evict_prioritized(CFG, state, jax.random.key(3), num=8)
    assert int(state.size) == before == 0 or int(state.size) <= before


def test_eviction_prefers_low_priority_items():
    """alpha_evict < 0 (paper: -0.4): low-priority slots should die first."""
    state = replay.init(CFG, {"x": jnp.zeros((), jnp.float32)})
    prios = jnp.concatenate([jnp.full((12,), 0.01), jnp.full((12,), 10.0)])
    state = replay.add_fifo(CFG, state, make_items(24), prios)
    new = replay.evict_prioritized(CFG, state, jax.random.key(4), num=10)
    leaves = np.asarray(sumtree.leaves(new.tree))
    low_dead = int((leaves[:12] == 0).sum())
    high_dead = int((leaves[12:24] == 0).sum())
    assert low_dead > high_dead


def test_stale_writeback_cannot_resurrect_evicted_slot():
    """Decoupled-learner hazard: a priority write-back for a slot that an
    eviction freed in the meantime must stay a no-op, or size drifts away
    from the live-leaf count."""
    state = filled_state(24)
    # evict everything deterministically via repeated prioritized rounds
    rng = jax.random.key(7)
    for _ in range(12):
        rng, sub = jax.random.split(rng)
        state = replay.evict_prioritized(CFG, state, sub, num=24)
        if int(state.size) == 0:
            break
    assert int(state.size) == 0
    # a stale learner write-back arrives for long-dead slots
    state = replay.set_priorities(
        CFG, state, jnp.array([1, 5, 9]), jnp.array([3.0, 3.0, 3.0]))
    leaves = np.asarray(sumtree.leaves(state.tree))
    assert int((leaves > 0).sum()) == 0          # still dead
    assert int(state.size) == 0                  # invariant holds
    assert float(sumtree.total(state.tree)) == pytest.approx(0.0)


def test_total_mass_drops_with_eviction():
    state = filled_state(24)
    total_before = float(sumtree.total(state.tree))
    new = replay.evict_prioritized(CFG, state, jax.random.key(5), num=8)
    assert float(sumtree.total(new.tree)) < total_before
    # freed slots contribute exactly zero mass
    leaves = np.asarray(sumtree.leaves(new.tree))
    np.testing.assert_allclose(float(sumtree.total(new.tree)),
                               leaves.sum(), rtol=1e-5)
