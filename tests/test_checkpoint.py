"""Checkpoint plane unit coverage (Appendix F's save/resume substrate).

The snapshot service and every resume path stand on three promises made
by ``repro.checkpoint.checkpoint``:

* ``save``/``restore`` round-trip arbitrary pytrees bit-exactly;
* ``latest`` picks the numerically newest ``<prefix><step>.npz`` and
  ignores everything else (sidecars, tmp droppings, foreign prefixes);
* ``save`` is atomic — a crash at any instant leaves either a fully
  usable checkpoint or garbage that ``latest`` ignores and the next
  ``save`` sweeps up.
"""

import json
import os

import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.checkpoint import checkpoint as ckpt


def _tree(rng: np.random.Generator) -> dict:
    return {
        "params": {
            "w": rng.standard_normal((3, 4)).astype(np.float32),
            "b": rng.standard_normal((4,)).astype(np.float64),
        },
        "counters": np.int64(rng.integers(0, 2**40)),
        "stack": [rng.integers(0, 255, (2, 2), dtype=np.uint8),
                  (np.float32(rng.random()), np.int32(7))],
    }


def _assert_tree_equal(a, b):
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(x, y)


# -- round trip -------------------------------------------------------------

def test_round_trip_bit_exact(tmp_path):
    for seed in range(5):
        tree = _tree(np.random.default_rng(seed))
        path = ckpt.save(str(tmp_path / f"ckpt_{seed}.npz"), tree, step=seed)
        _assert_tree_equal(ckpt.restore(path, tree), tree)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_round_trip_property(seed):
    import tempfile
    tree = _tree(np.random.default_rng(seed))
    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save(os.path.join(d, "ckpt_0.npz"), tree, step=0)
        _assert_tree_equal(ckpt.restore(path, tree), tree)


def test_sidecar_records_step_and_keys(tmp_path):
    tree = {"a": np.ones(2, np.float32)}
    path = ckpt.save(str(tmp_path / "ckpt_7.npz"), tree, step=7)
    with open(path + ".json") as f:
        meta = json.load(f)
    assert meta["step"] == 7
    assert meta["keys"] == ["a"]


# -- latest() ---------------------------------------------------------------

def test_latest_orders_numerically_not_lexically(tmp_path):
    tree = {"x": np.zeros(1, np.float32)}
    for step in (2, 10, 9):  # lexically "9" > "10"
        ckpt.save(str(tmp_path / f"ckpt_{step}.npz"), tree, step=step)
    assert ckpt.latest(str(tmp_path)).endswith("ckpt_10.npz")


def test_latest_respects_prefix_and_ignores_noise(tmp_path):
    tree = {"x": np.zeros(1, np.float32)}
    ckpt.save(str(tmp_path / "ckpt_3.npz"), tree, step=3)
    ckpt.save(str(tmp_path / "other_9.npz"), tree, step=9)
    (tmp_path / "ckpt_99.npz.tmp.npz").write_bytes(b"torn")
    (tmp_path / "ckpt_notanumber.npz").write_bytes(b"junk")
    assert ckpt.latest(str(tmp_path)).endswith("ckpt_3.npz")
    assert ckpt.latest(str(tmp_path), prefix="other_").endswith("other_9.npz")


def test_latest_missing_or_empty_dir_is_none(tmp_path):
    assert ckpt.latest(str(tmp_path / "nope")) is None
    assert ckpt.latest(str(tmp_path)) is None


# -- restore errors ---------------------------------------------------------

def test_restore_missing_key_raises(tmp_path):
    path = ckpt.save(str(tmp_path / "ckpt_0.npz"),
                     {"a": np.ones(2, np.float32)}, step=0)
    with pytest.raises(KeyError, match="missing key"):
        ckpt.restore(path, {"a": np.ones(2, np.float32),
                            "b": np.ones(3, np.float32)})


def test_restore_shape_mismatch_raises(tmp_path):
    path = ckpt.save(str(tmp_path / "ckpt_0.npz"),
                     {"a": np.ones((2, 3), np.float32)}, step=0)
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(path, {"a": np.ones((3, 2), np.float32)})


# -- atomicity --------------------------------------------------------------

def test_interrupted_savez_leaks_nothing(tmp_path, monkeypatch):
    """A crash inside np.savez must leave no tmp file and no sidecar — and
    must not disturb the previous good checkpoint."""
    tree = {"a": np.ones(4, np.float32)}
    good = ckpt.save(str(tmp_path / "ckpt_1.npz"), tree, step=1)

    def boom(*a, **k):
        raise OSError("disk full")
    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError, match="disk full"):
        ckpt.save(str(tmp_path / "ckpt_2.npz"), tree, step=2)
    leftovers = [n for n in os.listdir(tmp_path)
                 if ".tmp" in n or n.startswith("ckpt_2")]
    assert leftovers == []
    assert ckpt.latest(str(tmp_path)) == good
    _assert_tree_equal(ckpt.restore(good, tree), tree)


def test_crash_between_sidecar_and_rename_is_invisible(tmp_path,
                                                       monkeypatch):
    """The npz rename is the commit point: dying right before it leaves a
    sidecar + tmp that latest() ignores and the next save sweeps."""
    tree = {"a": np.arange(3, dtype=np.float32)}
    real_replace = os.replace

    def crashing_replace(src, dst):
        if dst.endswith(".npz") and not dst.endswith(".json"):
            raise KeyboardInterrupt  # simulated SIGINT mid-commit
        return real_replace(src, dst)
    monkeypatch.setattr(os, "replace", crashing_replace)
    with pytest.raises(KeyboardInterrupt):
        ckpt.save(str(tmp_path / "ckpt_5.npz"), tree, step=5)
    monkeypatch.setattr(os, "replace", real_replace)
    assert ckpt.latest(str(tmp_path)) is None

    # The next save in the directory sweeps any stale tmp droppings.
    (tmp_path / "ckpt_9.npz.tmp.npz").write_bytes(b"orphan")
    ckpt.save(str(tmp_path / "ckpt_6.npz"), tree, step=6)
    names = set(os.listdir(tmp_path))
    assert not any(".tmp" in n for n in names)
    assert ckpt.latest(str(tmp_path)).endswith("ckpt_6.npz")


def test_sidecar_never_dangles_ahead_of_npz(tmp_path, monkeypatch):
    """Ordering inside save(): the sidecar lands before the npz rename, so
    observing ckpt_N.npz implies its sidecar exists (the reader's
    invariant); a torn save may leave neither, never npz-without-meta."""
    tree = {"a": np.zeros(1, np.float32)}
    order = []
    real_replace = os.replace

    def recording_replace(src, dst):
        order.append(os.path.basename(dst))
        return real_replace(src, dst)
    monkeypatch.setattr(os, "replace", recording_replace)
    ckpt.save(str(tmp_path / "ckpt_0.npz"), tree, step=0)
    assert order == ["ckpt_0.npz.json", "ckpt_0.npz"]
