"""Fault-tolerance plane: shard snapshots restore bit-identically, the
runtime checkpoint/resume path continues an interrupted run, the actor
supervisor detects and respawns dead actor processes (including with
thread-actors running — the old monitor's blind spot), and severed
transports reconnect instead of dying.

The full crash scenarios (SIGKILLed learner resumed from its latest
snapshot, etc.) live in ``test_chaos.py`` behind ``REPRO_TEST_CHAOS``;
everything here runs in the default tier-1 suite.
"""

import socket
import threading
import time

import jax
import numpy as np
import pytest
from _apex_helpers import item_example, tiny_preset

from repro.checkpoint import checkpoint as ckpt_lib
from repro.net import RemoteActorLoop, RemoteActorSpec, ReplayGateway
from repro.runtime import (AsyncConfig, ParamStore, ReplayFabric,
                           SnapshotService, run_async)
from repro.testing import chaos


def _flat(tree):
    return jax.tree_util.tree_leaves(tree)


def _assert_trees_equal(a, b):
    la, lb = _flat(a), _flat(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _feed(fabric, cfg, env, agent, blocks: int, seed: int = 0):
    from _apex_helpers import make_block
    for i in range(blocks):
        block = make_block(cfg, env, agent, seed=seed + i)
        assert fabric.add(block, timeout=10.0)


def _draw(fabric, n: int, timeout_s: float = 30.0):
    out = []
    deadline = time.monotonic() + timeout_s
    while len(out) < n:
        assert time.monotonic() < deadline, "fabric starved"
        b = fabric.get_batch(timeout=0.05)
        if b is not None:
            out.append(b)
    return out


# --- shard checkpoint / restore -------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_shard_checkpoint_restore_bit_identical(seed, tmp_path):
    """The Appendix-F property: capture → (npz round trip) → restore
    rebuilds byte-identical shard state, and two fabrics restored from the
    same snapshot draw byte-identical sample streams — rng, sum tree,
    eviction clock and min-fill counters all continue exactly."""
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent

    src = ReplayFabric(cfg, item_example(env), num_shards=2,
                       seed=seed).start()
    _feed(src, cfg, env, agent, blocks=6, seed=seed * 10)
    drawn = _draw(src, 3)
    # write back fresh priorities so the sum tree isn't pristine
    for b in drawn:
        prios = np.linspace(0.1, 2.0, b.indices.shape[0]).astype(np.float32)
        src.write_back(b.indices, jax.numpy.asarray(prios))
    captured = src.checkpoint_shards()  # answered between ops, while hot
    src.stop()

    # npz round trip through the real checkpoint plane
    path = str(tmp_path / f"ckpt_{seed}.npz")
    fresh = ReplayFabric(cfg, item_example(env), num_shards=2, seed=99)
    ckpt_lib.save(path, {"shards": captured}, step=seed)
    restored = ckpt_lib.restore(path, {"shards": fresh.checkpoint_shards()})

    replicas = []
    for _ in range(2):
        fab = ReplayFabric(cfg, item_example(env), num_shards=2, seed=99)
        fab.restore_shards(restored["shards"])
        # capture(restore(capture)) is the identity, bit for bit
        _assert_trees_equal(fab.checkpoint_shards(), captured)
        assert fab.snapshot().replay_size == src.snapshot().replay_size
        replicas.append(fab.start())
    try:
        streams = [_draw(fab, 4) for fab in replicas]
        for b0, b1 in zip(*streams):
            np.testing.assert_array_equal(np.asarray(b0.indices),
                                          np.asarray(b1.indices))
            np.testing.assert_array_equal(np.asarray(b0.is_weights),
                                          np.asarray(b1.is_weights))
            _assert_trees_equal(b0.items, b1.items)
    finally:
        for fab in replicas:
            fab.stop()


def test_restore_shards_rejects_geometry_mismatch():
    preset = tiny_preset()
    fab = ReplayFabric(preset.apex, item_example(preset.env), num_shards=2)
    one = ReplayFabric(preset.apex, item_example(preset.env), num_shards=1)
    with pytest.raises(ValueError, match="replay_shards geometry"):
        fab.restore_shards(one.checkpoint_shards())


# --- snapshot service ------------------------------------------------------

def test_snapshot_service_rejects_bad_interval(tmp_path):
    preset = tiny_preset()
    fab = ReplayFabric(preset.apex, item_example(preset.env), num_shards=1)
    with pytest.raises(ValueError, match="checkpoint interval"):
        SnapshotService(str(tmp_path), fab, {"live": (0, None)},
                        ParamStore({}), every_s=0.0)


def test_run_async_checkpoint_and_resume(tmp_path):
    """A checkpointing run leaves a resumable snapshot; a second run with
    ``resume=True`` continues from it — step clock, learner slice, param
    version, and replay contents all carry over."""
    preset = tiny_preset()
    ckpt_dir = str(tmp_path / "snaps")
    res1 = run_async(
        preset.apex,
        AsyncConfig(actor_threads=2, total_learner_steps=6,
                    checkpoint_dir=ckpt_dir, checkpoint_every_s=0.2,
                    max_seconds=120, seed=11),
        preset.env, preset.agent, preset.make_optimizer())
    assert res1.stats["learner_steps"] == 6
    assert res1.stats["snapshots"] >= 1           # final save at minimum
    newest = ckpt_lib.latest(ckpt_dir)
    assert newest is not None and newest.endswith("ckpt_6.npz")

    res2 = run_async(
        preset.apex,
        AsyncConfig(actor_threads=2, total_learner_steps=10,
                    checkpoint_dir=ckpt_dir, checkpoint_every_s=30.0,
                    resume=True, max_seconds=120, seed=11),
        preset.env, preset.agent, preset.make_optimizer())
    assert res2.stats["resumed_from_step"] == 6
    assert res2.stats["learner_steps"] == 10
    # the learner slice continued, not restarted
    assert int(res2.learner.learner_step) == 10
    # param versions stay monotone across the resume
    assert res2.stats["param_version"] > res1.stats["param_version"]
    # the end-of-run snapshot now reflects the resumed run
    assert ckpt_lib.latest(ckpt_dir).endswith("ckpt_10.npz")


def test_resume_from_empty_dir_is_cold_start(tmp_path):
    preset = tiny_preset()
    res = run_async(
        preset.apex,
        AsyncConfig(actor_threads=1, total_learner_steps=2,
                    checkpoint_dir=str(tmp_path / "none"), resume=True,
                    checkpoint_every_s=60.0, max_seconds=120),
        preset.env, preset.agent, preset.make_optimizer())
    assert res.stats["resumed_from_step"] == 0
    assert res.stats["learner_steps"] == 2


def test_async_config_rejects_incoherent_checkpointing():
    preset = tiny_preset()
    opt = preset.make_optimizer()
    with pytest.raises(ValueError, match="resume needs checkpoint_dir"):
        run_async(preset.apex, AsyncConfig(resume=True),
                  preset.env, preset.agent, opt)
    with pytest.raises(ValueError, match="both must be local"):
        run_async(preset.apex,
                  AsyncConfig(actor_threads=0, learner_remote="h:1",
                              checkpoint_dir="/tmp/x"),
                  preset.env, preset.agent, opt)
    with pytest.raises(ValueError, match="checkpoint_every_s"):
        run_async(preset.apex,
                  AsyncConfig(checkpoint_dir="/tmp/x",
                              checkpoint_every_s=0.0),
                  preset.env, preset.agent, opt)


# --- reconnecting transports ----------------------------------------------

def test_remote_actor_loop_reconnects_after_severed_transport():
    """Cut the gateway side of a streaming actor's connection: the loop
    must dial back in, re-handshake (counted by the gateway), and keep
    streaming — an explicit STOP still exits cleanly."""
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    fabric = ReplayFabric(cfg, item_example(env), num_shards=1).start()
    params = agent.init(jax.random.key(0), item_example(env)["obs"][None])
    gw = ReplayGateway(fabric, ParamStore(params)).start()
    loop = RemoteActorLoop(RemoteActorSpec(
        cfg=cfg, env=env, agent=agent, host=gw.host, port=gw.port,
        actor_id=0, transport="tcp", reconnect_timeout_s=20.0))
    out = {}
    th = threading.Thread(target=lambda: out.update(stats=loop.run()),
                          daemon=True)
    th.start()
    try:
        deadline = time.monotonic() + 30.0
        while gw.snapshot().blocks_in < 2:          # streaming for real
            assert time.monotonic() < deadline
            time.sleep(0.01)
        with gw._lock:
            conns = list(gw._conns.values())
        assert conns and any(chaos._sever(c) for c in conns)
        before = gw.snapshot().blocks_in
        deadline = time.monotonic() + 30.0
        while not (loop.stats["reconnects"] >= 1
                   and gw.snapshot().blocks_in > before):
            assert time.monotonic() < deadline, loop.stats
            time.sleep(0.01)
    finally:
        gw.stop()                                   # STOP → clean exit
        th.join(timeout=30.0)
        fabric.stop()
    assert not th.is_alive()
    stats = out["stats"]
    assert stats["reconnects"] >= 1
    assert gw.snapshot().client_reconnects >= 1
    assert fabric.error is None


def test_remote_actor_reconnect_disabled_exits_on_sever():
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    fabric = ReplayFabric(cfg, item_example(env), num_shards=1).start()
    params = agent.init(jax.random.key(0), item_example(env)["obs"][None])
    gw = ReplayGateway(fabric, ParamStore(params)).start()
    loop = RemoteActorLoop(RemoteActorSpec(
        cfg=cfg, env=env, agent=agent, host=gw.host, port=gw.port,
        actor_id=0, transport="tcp", reconnect=False))
    th = threading.Thread(target=loop.run, daemon=True)
    th.start()
    try:
        deadline = time.monotonic() + 30.0
        while gw.snapshot().blocks_in < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        with gw._lock:
            for c in list(gw._conns.values()):
                chaos._sever(c)
        th.join(timeout=30.0)                       # old behavior: quiet exit
        assert not th.is_alive()
        assert loop.stats["reconnects"] == 0
    finally:
        gw.stop()
        fabric.stop()


def test_remote_learner_source_reconnects_midrun():
    """Serve + remote-learner loopback with the learner's transport severed
    mid-run: the ``RemoteFabricSource`` must reconnect (counted in run
    stats) and the run still completes — priorities are idempotent LWW, so
    replayed write-backs after the reconnect are harmless."""
    preset = tiny_preset()
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    steps = 400
    serve_out = {}

    def serve():
        serve_out["res"] = run_async(
            preset.apex,
            AsyncConfig(actor_threads=1, serve_sampling=True,
                        gateway_port=port, total_learner_steps=steps,
                        transport="tcp", max_seconds=180),
            preset.env, preset.agent, preset.make_optimizer())

    th = threading.Thread(target=serve, daemon=True)
    th.start()
    severed = {}

    # Deterministic trigger (no wall-clock race with a fast learner): cut
    # the socket once 50 of the 400 write-backs are through.
    def on_handles(h):
        def cut():
            src = getattr(h.source, "_inner", h.source)
            while src.stats.writebacks < 50 and not h.stop.is_set():
                time.sleep(0.001)
            if not h.stop.is_set():
                severed["ok"] = chaos._sever(src._conn)
        threading.Thread(target=cut, daemon=True).start()

    res = run_async(
        preset.apex,
        AsyncConfig(actor_threads=0, learner_remote=f"127.0.0.1:{port}",
                    total_learner_steps=steps, transport="tcp",
                    max_seconds=180),
        preset.env, preset.agent, preset.make_optimizer(),
        on_handles=on_handles)
    th.join(timeout=180)
    assert not th.is_alive()
    assert severed.get("ok"), "fault never fired"
    assert res.stats["learner_steps"] == steps
    assert res.stats["source_reconnects"] >= 1
    assert res.source_stats.reconnects >= 1
    # The serve side may observe slightly fewer rounds than the learner
    # ran: priority frames in flight when the socket died are lost (the
    # tolerated-loss mode — the learner's BYE ends the serve run).
    assert serve_out["res"].stats["learner_steps"] >= steps - 50


# --- supervised actor processes -------------------------------------------

def test_supervisor_detects_and_respawns_with_thread_actors_running():
    """Kill an actor process while thread-actors keep the learner fed: the
    supervisor must still see the death (the old monitor looked only when
    actor_threads == 0 — the blind spot) and respawn the slot."""
    preset = tiny_preset()
    # The freeze holds the run open deterministically (learner starved
    # behind the paused shard owner) while the supervisor's detect →
    # backoff → respawn cycle (~0.5s) plays out; sorted() is stable, so
    # the kill fires first.
    monkey = chaos.ChaosMonkey([
        chaos.kill_actor_proc(0.0, slot=0),
        chaos.freeze_shard(0.0, shard=0, for_s=2.0),
    ])
    res = run_async(
        preset.apex,
        AsyncConfig(actor_threads=1, actor_procs=1,
                    total_learner_steps=12, max_seconds=180, seed=4),
        preset.env, preset.agent, preset.make_optimizer(),
        on_handles=monkey.on_handles)
    monkey.join()
    assert monkey.applied == ["kill_actor_proc[0]",
                              "freeze_shard[0]"], monkey.errors
    assert res.stats["learner_steps"] == 12
    assert res.stats["actor_proc_exits"] >= 1     # death detected
    assert res.stats["actor_restarts"] >= 1       # slot respawned
