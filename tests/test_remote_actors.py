"""Remote actor ingest: RemoteActorLoop against a live gateway (in-thread),
the acceptance 2-actor-process run through ``AsyncConfig.actor_procs``, and
the lax.scan learner-batching satellite."""

import dataclasses
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _apex_helpers import item_example, tiny_preset

from repro.net import (RemoteActorLoop, RemoteActorSpec, ReplayGateway,
                       initial_slice)
from repro.runtime import (AsyncConfig, ParamStore, ReplayFabric, phases,
                           run_async)


# --- client loop (in-thread: fast, no subprocess) ----------------------------

def test_remote_loop_streams_blocks_and_pulls_params():
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    fabric = ReplayFabric(cfg, item_example(env), num_shards=2).start()
    params = agent.init(jax.random.key(0), item_example(env)["obs"][None])
    store = ParamStore(params)
    gw = ReplayGateway(fabric, store).start()
    try:
        spec = RemoteActorSpec(cfg=cfg, env=env, agent=agent, host=gw.host,
                               port=gw.port, actor_id=0, seed=3,
                               max_rollouts=6)
        stats = RemoteActorLoop(spec).run()
        assert stats["rollouts"] == 6
        assert stats["pushed"] == 6
        assert stats["param_version"] == 0      # pulled the initial snapshot
        # cfg.param_sync_period=2: pulls at rollouts 0 (initial), 2, 4
        assert stats["param_pulls"] == 3
        deadline = time.monotonic() + 10.0
        while (fabric.snapshot().blocks_added < 6
               and time.monotonic() < deadline):
            time.sleep(0.01)
        snap = fabric.snapshot()
        assert snap.blocks_added == 6
        assert snap.transitions_added == stats["transitions"]
        per_shard = [s.blocks_added for s in fabric.shard_snapshots()]
        assert per_shard == [3, 3]              # round robin reached both
    finally:
        gw.stop()
        fabric.stop()
    assert gw.error is None and fabric.error is None
    gsnap = gw.snapshot()
    assert gsnap.client_rollouts == 6           # BYE counters merged


def test_remote_loop_blocks_on_full_inflight_window():
    """A stalled fabric holds ACKs back; the client's bounded window must
    make it wait (the socket analogue of actor_blocked), then drain once
    the fabric recovers."""
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent

    class StallFabric:
        def __init__(self):
            self.release = threading.Event()
            self.blocks = []

        def add(self, block, timeout=None, trace_id=0):
            if not self.release.is_set():
                time.sleep(0.01)
                return False
            self.blocks.append(block)
            return True

    fabric = StallFabric()
    params = agent.init(jax.random.key(0), item_example(env)["obs"][None])
    gw = ReplayGateway(fabric, ParamStore(params),
                       add_timeout_s=0.001).start()
    try:
        spec = RemoteActorSpec(cfg=cfg, env=env, agent=agent, host=gw.host,
                               port=gw.port, actor_id=0, seed=0,
                               max_inflight=2, max_rollouts=5, poll_s=0.01,
                               param_sync_period=1000)  # isolate the window
        loop = RemoteActorLoop(spec)
        box = {}

        def run():
            box["stats"] = loop.run()

        th = threading.Thread(target=run, daemon=True)
        th.start()
        deadline = time.monotonic() + 60.0
        while loop.stats["blocked"] == 0 and time.monotonic() < deadline:
            time.sleep(0.01)     # client compiling, then filling the window
        assert loop.stats["blocked"] > 0   # parked: 2 in flight, no ACKs
        assert th.is_alive()
        assert loop.stats["pushed"] == 2   # window held the third block back
        fabric.release.set()
        th.join(timeout=30.0)
        assert not th.is_alive()
        assert box["stats"]["rollouts"] == 5
        assert len(fabric.blocks) == 5
    finally:
        gw.stop()
    assert gw.error is None


def test_initial_slice_matches_runner_derivation():
    """Thread actor t and remote actor with actor_id=t must start from the
    same slice — one exploration ladder across the process boundary."""
    preset = tiny_preset()
    cfg = dataclasses.replace(preset.apex, num_shards=3)
    seed = 11
    _, e_rng = jax.random.split(jax.random.key(seed))
    for t in range(3):
        a_rng = jax.random.fold_in(e_rng, t)
        from repro.envs.synthetic import batch_reset
        env_state, obs = batch_reset(preset.env, a_rng, cfg.lanes_per_shard)
        want = phases.ActorSlice(
            env_state=env_state, obs=obs,
            ep_return=jnp.zeros((cfg.lanes_per_shard,), jnp.float32),
            rng=jax.random.fold_in(a_rng, 1),
            frames=jnp.zeros((), jnp.int32))
        got = initial_slice(cfg, preset.env, seed, t)

        def cmp(a, b):
            if jax.dtypes.issubdtype(jnp.asarray(a).dtype,
                                     jax.dtypes.prng_key):
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        jax.tree.map(cmp, want, got)


# --- acceptance: 2 actor processes through run_async -------------------------

def test_run_async_two_actor_procs_end_to_end():
    """Acceptance: a 2-actor-process run via actor_procs reaches the replay
    min-fill gate and completes learner steps, with priority write-backs
    landing on the correct shard. The CI matrix sets REPRO_TEST_TRANSPORT
    to pin the byte path (strict shm — no silent tcp fallback) instead of
    the default auto negotiation."""
    preset = tiny_preset()
    transport = os.environ.get("REPRO_TEST_TRANSPORT") or "auto"
    acfg = AsyncConfig(actor_threads=0, actor_procs=2, replay_shards=2,
                       total_learner_steps=8, max_seconds=240.0, seed=3,
                       transport=transport)
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    s = res.stats
    assert s["learner_steps"] == 8
    assert int(res.learner.learner_step) == 8
    assert s["actor_transitions"] > 0           # min-fill was reached
    assert s["replay_size"] > 0
    assert res.gateway_stats is not None
    assert res.gateway_stats.connections == 2
    if transport == "shm":
        assert res.gateway_stats.shm_connections == 2
    assert res.gateway_stats.blocks_in > 0
    assert res.gateway_stats.transitions_in == s["actor_transitions"]
    assert len(res.shard_stats) == 2
    for shard in res.shard_stats:
        assert shard.blocks_added > 0           # round robin reached both
        assert shard.updates_applied == 8       # write-backs hit each owner
    assert res.service_stats.transitions_added == s["actor_transitions"]
    assert s["param_version"] >= 1


def test_async_config_rejects_zero_actors():
    preset = tiny_preset()
    with pytest.raises(ValueError, match="at least one actor"):
        run_async(preset.apex, AsyncConfig(actor_threads=0, actor_procs=0),
                  preset.env, preset.agent, preset.make_optimizer())


# --- learner batching (lax.scan satellite) -----------------------------------

def test_learner_batching_consumes_k_per_jitted_call():
    preset = tiny_preset()
    acfg = AsyncConfig(actor_threads=2, total_learner_steps=8,
                       learn_batches_per_step=3, max_seconds=120.0, seed=5)
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    s = res.stats
    assert s["learner_steps"] == 9              # first multiple of 3 >= 8
    assert int(res.learner.learner_step) == 9
    # one write-back application per consumed batch: the eviction clock is
    # unchanged by k-batching
    assert res.service_stats.updates_applied == 9
    assert s["learner_transitions"] == 9 * preset.apex.batch_size
    assert s["param_version"] >= 1


def test_learner_batching_matches_single_batch_numerics():
    """k updates through the scanned learner == k sequential learn_phase
    calls on the same batches."""
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    opt = preset.make_optimizer()
    params = agent.init(jax.random.key(1), item_example(env)["obs"][None])
    lslice = phases.LearnerSlice(
        params=params, target_params=jax.tree.map(jnp.copy, params),
        opt_state=opt.init(params), learner_step=jnp.zeros((), jnp.int32))

    from _apex_helpers import make_block
    k, bsz = 3, cfg.batch_size
    blocks = [make_block(cfg, env, agent, seed=s) for s in range(k)]
    items = [jax.tree.map(lambda x: x[:bsz], b.items) for b in blocks]
    weights = [jnp.linspace(0.5, 1.0, bsz) for _ in range(k)]

    ref = lslice
    ref_prios = []
    for i in range(k):
        ref, prios, _ = phases.learn_phase(cfg, agent, opt, ref, items[i],
                                           weights[i])
        ref_prios.append(prios)

    def scan_fn(lsl, items_k, w_k):
        def body(l, xw):
            l, prios, _ = phases.learn_phase(cfg, agent, opt, l, xw[0], xw[1])
            return l, prios
        return jax.lax.scan(body, lsl, (items_k, w_k))

    items_k = jax.tree.map(lambda *xs: jnp.stack(xs), *items)
    got, got_prios = jax.jit(scan_fn)(lslice, items_k, jnp.stack(weights))
    assert int(got.learner_step) == k
    np.testing.assert_allclose(np.asarray(got_prios),
                               np.asarray(jnp.stack(ref_prios)),
                               rtol=1e-5, atol=1e-6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        got.params, ref.params)
