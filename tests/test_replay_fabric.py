"""Sharded replay fabric: IS-weight equivalence across the sync collective
path / the async host-merge path / the single-shard formula, round-robin
routing, (shard, slot) key write-back scatter, thread-safe stats snapshots,
and batched actor inference."""

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _apex_helpers import item_example, make_block, tiny_preset
from _hypothesis_fallback import given, settings, st

from repro.core import apex, priority as prio, replay as replay_lib
from repro.core import sampling, sumtree
from repro.envs.synthetic import batch_reset
from repro.runtime import (AsyncConfig, InferenceServer, ParamStore,
                           ReplayFabric, ReplayShard, phases, run_async,
                           shard_replay_config)


def fill_fabric(fabric, cfg, env, agent, n_blocks, timeout=5.0):
    block = make_block(cfg, env, agent)
    for _ in range(n_blocks):
        assert fabric.add(block, timeout=1.0)
    deadline = time.monotonic() + timeout
    while (fabric.snapshot().blocks_added < n_blocks
           and time.monotonic() < deadline):
        time.sleep(0.005)
    assert fabric.snapshot().blocks_added >= n_blocks
    return int(block.priorities.shape[0])


# --- IS-weight equivalence ---------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(shards=st.integers(2, 4), sub_batch=st.integers(1, 16),
       seed=st.integers(0, 10_000))
def test_merged_weights_equal_collective_weights(shards, sub_batch, seed):
    """The fabric's host-side merge and the sync driver's psum/pmax
    collective path are the same formula: on identical per-shard sampled
    leaf masses / totals / sizes they must agree to float exactness."""
    rng = np.random.RandomState(seed)
    leaf = jnp.asarray(rng.uniform(1e-4, 5.0, (shards, sub_batch)),
                       jnp.float32)
    totals = jnp.asarray(rng.uniform(1.0, 100.0, shards), jnp.float32)
    sizes = jnp.asarray(rng.randint(1, 300, shards), jnp.int32)
    beta = 0.4

    merged = sampling.merged_is_weights(leaf, totals, sizes, beta)
    collective = jax.vmap(
        lambda l, t, s: sampling.collective_is_weights(
            l, t, s, shards, beta, "data"),
        axis_name="data")(leaf, totals, sizes)
    np.testing.assert_array_equal(np.asarray(merged),
                                  np.asarray(collective))


@settings(max_examples=25, deadline=None)
@given(shards=st.integers(1, 4), sub_batch=st.integers(1, 16),
       seed=st.integers(0, 10_000))
def test_merged_weights_equal_single_buffer_weights(shards, sub_batch, seed):
    """With equal per-shard priority masses (the regime equal sampling
    quotas assume), the N-shard merged weights equal the weights a single
    global buffer would assign the same leaves — i.e. sharding the memory
    does not change the learner's correction."""
    rng = np.random.RandomState(seed)
    leaf = rng.uniform(1e-4, 5.0, (shards, sub_batch)).astype(np.float32)
    # normalize each shard to the same total mass
    leaf = leaf / leaf.sum(axis=1, keepdims=True) * 37.5
    extra = rng.uniform(0.0, 50.0, shards).astype(np.float32)
    extra[:] = extra[0]  # same unsampled mass per shard
    totals = jnp.asarray(leaf.sum(axis=1) + extra)
    sizes = jnp.asarray(rng.randint(1, 300, shards), jnp.int32)

    merged = sampling.merged_is_weights(jnp.asarray(leaf), totals, sizes,
                                        prio.IS_EXPONENT)
    single = prio.importance_weights(
        jnp.asarray(leaf).reshape(-1), jnp.sum(totals), jnp.sum(sizes),
        prio.IS_EXPONENT)
    np.testing.assert_allclose(np.asarray(merged).reshape(-1),
                               np.asarray(single), rtol=1e-6)


def test_sync_collective_path_matches_fabric_formula():
    """End-to-end formula check against the *actual* sync driver helper:
    apex._global_is_weights under a named axis == sampling.merged on the
    same sampled sub-batches."""
    preset = tiny_preset()
    cfg = dataclasses.replace(preset.apex, num_shards=2)
    item = item_example(preset.env)
    rcfg = cfg.replay
    states, batches = [], []
    for k in range(2):
        st_k = replay_lib.init(rcfg, item)
        n = 40 + 10 * k
        items = jax.tree.map(
            lambda a: jnp.stack([jnp.asarray(a)] * n), item)
        pr = jax.random.uniform(jax.random.key(k), (n,)) * 3 + 0.1
        st_k = replay_lib.add_fifo(rcfg, st_k, items, pr)
        states.append(st_k)
        batches.append(replay_lib.sample(rcfg, st_k, jax.random.key(10 + k),
                                         cfg.batch_size // 2))

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    sizes = jnp.stack([s.size for s in states])
    via_apex = jax.vmap(
        lambda b, s: apex._global_is_weights(cfg, b, s, "data"),
        axis_name="data")(stacked, sizes)
    via_fabric = sampling.merged_is_weights(
        stacked.leaf_mass, stacked.total_mass, sizes, rcfg.beta)
    np.testing.assert_array_equal(np.asarray(via_apex),
                                  np.asarray(via_fabric))


# --- fabric routing ----------------------------------------------------------

def test_fabric_round_robin_coverage():
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    fabric = ReplayFabric(cfg, item_example(env), num_shards=4,
                          batch_size=16).start()
    try:
        fill_fabric(fabric, cfg, env, agent, n_blocks=12)
        per_shard = [s.blocks_added for s in fabric.shard_snapshots()]
        assert per_shard == [3, 3, 3, 3]
    finally:
        fabric.stop()
    assert fabric.error is None


def test_fabric_merged_batch_and_writeback_owning_shard():
    preset = tiny_preset(min_fill=48, batch_size=16)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    fabric = ReplayFabric(cfg, item_example(env), num_shards=2).start()
    try:
        fill_fabric(fabric, cfg, env, agent, n_blocks=6)  # 3 blocks/shard
        batch = None
        deadline = time.monotonic() + 5.0
        while batch is None and time.monotonic() < deadline:
            batch = fabric.get_batch(timeout=0.1)
        assert batch is not None, "fabric never served once min-fill passed"
        assert batch.items["obs"].shape[0] == cfg.batch_size
        idx = np.asarray(batch.indices)
        cap = fabric.shard_capacity
        # layout invariant: first half shard 0's keys, second half shard 1's
        assert (idx[:8] < cap).all() and (idx[8:] >= cap).all()
        w = np.asarray(batch.is_weights)
        assert (w > 0).all() and (w <= 1.0 + 1e-6).all()

        # distinct per-shard priorities: the scatter must land on the owner
        prios = jnp.concatenate([jnp.full((8,), 3.0), jnp.full((8,), 9.0)])
        fabric.write_back(batch.indices, prios)
    finally:
        fabric.stop()
    assert fabric.error is None
    for k, (state, val) in enumerate(zip(fabric.replay_states(), (3.0, 9.0))):
        slots = np.asarray(batch.indices)[k * 8:(k + 1) * 8] - k * cap
        leaves = np.asarray(sumtree.leaves(state.tree))
        np.testing.assert_allclose(
            leaves[slots], float(prio.to_leaf(jnp.asarray(val))), rtol=1e-6)
        assert fabric.shards[k].snapshot().updates_applied == 1


def test_shard_replay_config_partition():
    rcfg = replay_lib.ReplayConfig(capacity=1024, soft_capacity=896,
                                   min_fill=100)
    sub = shard_replay_config(rcfg, 4)
    assert sub.capacity == 256
    assert sub.soft_capacity == 224
    assert sub.min_fill == 25
    assert shard_replay_config(rcfg, 1) is rcfg
    # shard counts that cannot split the capacity into power-of-two slices
    # are rejected rather than silently inflating/shrinking the memory
    with pytest.raises(ValueError, match="power-of-two"):
        shard_replay_config(rcfg, 3)


def test_fabric_scales_eviction_quota_per_shard():
    """Prioritized eviction fires on every shard per learner step; the
    victim count must scale down with the per-shard buffer."""
    preset = tiny_preset()
    cfg = dataclasses.replace(preset.apex, eviction="prioritized",
                              evict_num=12)
    fabric = ReplayFabric(cfg, item_example(preset.env), num_shards=2)
    assert fabric._cfg.evict_num == 6
    # evict_num=0 falls back to batch_size in priority_writeback: scale that
    cfg = dataclasses.replace(cfg, evict_num=0)
    fabric = ReplayFabric(cfg, item_example(preset.env), num_shards=2)
    assert fabric._cfg.evict_num == cfg.batch_size // 2
    # single-shard fabrics keep the config untouched
    assert ReplayFabric(cfg, item_example(preset.env),
                        num_shards=1)._cfg is cfg


def test_fabric_rejects_indivisible_batch():
    preset = tiny_preset()
    with pytest.raises(ValueError, match="divisible"):
        ReplayFabric(preset.apex, item_example(preset.env), num_shards=4,
                     batch_size=18)


# --- stats observability -----------------------------------------------------

def test_service_stats_snapshot_while_running():
    """snapshot() is safe and consistent from another thread mid-run, and
    replay_size becomes visible while the shard is still running (it was
    only valid after stop() before)."""
    preset = tiny_preset(capacity=8192)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    shard = ReplayShard(cfg, replay_lib.init(cfg.replay, item_example(env)),
                        add_queue_depth=8).start()
    block = make_block(cfg, env, agent)
    n_blocks = 48  # > _SIZE_REFRESH_OPS so the live size refresh triggers
    stop = threading.Event()
    snaps = []

    def watcher():
        while not stop.is_set():
            snaps.append(shard.snapshot())
            time.sleep(0.001)  # don't starve the owner thread of the GIL

    w = threading.Thread(target=watcher, daemon=True)
    w.start()
    try:
        for _ in range(n_blocks):
            assert shard.add(block, timeout=5.0)
        deadline = time.monotonic() + 30.0
        while (shard.snapshot().blocks_added < n_blocks
               and time.monotonic() < deadline):
            time.sleep(0.002)
        live = shard.snapshot()  # before stop(): must already be populated
    finally:
        stop.set()
        w.join()
        shard.stop()
    assert live.blocks_added == n_blocks
    assert live.replay_size > 0
    blocks_seen = [s.blocks_added for s in snaps]
    assert blocks_seen == sorted(blocks_seen)  # monotonic, never torn
    for s in snaps:
        assert s.transitions_added == s.blocks_added * int(
            block.priorities.shape[0])


def test_shard_poll_default_configurable():
    """The poll interval that used to be a hardcoded 0.05 is configurable:
    per-shard via the ``poll_s`` constructor arg (direct API users), and in
    the runner via AsyncConfig.add_poll_s / starve_timeout_s."""
    preset = tiny_preset()
    cfg, env, agent = preset.apex, preset.env, preset.agent
    shard = ReplayShard(cfg, replay_lib.init(cfg.replay, item_example(env)),
                        add_queue_depth=1, poll_s=0.01)  # never started
    block = make_block(cfg, env, agent)
    assert shard.add(block)
    t0 = time.monotonic()
    assert not shard.add(block)          # full queue, default (0.01s) poll
    assert time.monotonic() - t0 < 0.5   # a 0.05 default would also pass,
    assert shard.get_batch() is None     # but the wiring is what's under test
    acfg = AsyncConfig(add_poll_s=0.01, starve_timeout_s=0.03)
    assert acfg.add_poll_s == 0.01 and acfg.starve_timeout_s == 0.03


# --- batched inference -------------------------------------------------------

def test_inference_server_matches_direct_act():
    """K actors through one batched dispatch get the same rollout results
    as direct per-actor act_phase calls with the same params/slices."""
    preset = tiny_preset()
    cfg, env, agent = dataclasses.replace(preset.apex, num_shards=2), \
        preset.env, preset.agent
    slices = []
    for t in range(2):
        env_state, obs = batch_reset(env, jax.random.key(t),
                                     cfg.lanes_per_shard)
        slices.append(phases.ActorSlice(
            env_state=env_state, obs=obs,
            ep_return=jnp.zeros((cfg.lanes_per_shard,), jnp.float32),
            rng=jax.random.fold_in(jax.random.key(t), 1),
            frames=jnp.zeros((), jnp.int32)))
    params = agent.init(jax.random.key(7), slices[0].obs[:1])
    store = ParamStore(params)
    server = InferenceServer(cfg, env, agent, store, max_batch=2).start()
    try:
        results = [None, None]

        def worker(t):
            results[t] = server.act(slices[t], t)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(2)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    finally:
        server.stop()
    assert server.error is None
    stats = server.snapshot()
    assert stats.requests == 2
    assert stats.dispatches <= 2  # coalesced (1 in the common case)
    for t in range(2):
        assert results[t] is not None
        _, block, _ = results[t]
        _, ref_block, _ = phases.act_phase(cfg, env, agent, params,
                                           slices[t], t)
        np.testing.assert_allclose(np.asarray(block.priorities),
                                   np.asarray(ref_block.priorities),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(block.items["obs"]),
                                   np.asarray(ref_block.items["obs"]),
                                   rtol=1e-5, atol=1e-6)


# --- end to end --------------------------------------------------------------

def test_run_async_two_shards_end_to_end():
    preset = tiny_preset()
    acfg = AsyncConfig(actor_threads=2, replay_shards=2,
                       total_learner_steps=8, max_seconds=120.0, seed=3)
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    s = res.stats
    assert s["learner_steps"] == 8
    assert int(res.learner.learner_step) == 8
    assert s["actor_transitions"] > 0
    assert s["replay_size"] > 0
    assert len(res.shard_stats) == 2
    for shard in res.shard_stats:
        assert shard.blocks_added > 0        # round robin reached both
        assert shard.updates_applied == 8    # every step scattered to both
    assert res.service_stats.transitions_added == s["actor_transitions"]


def test_run_async_inference_batching_end_to_end():
    preset = tiny_preset()
    acfg = AsyncConfig(actor_threads=2, replay_shards=2,
                       inference_batching=True, total_learner_steps=6,
                       max_seconds=120.0, seed=5)
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    assert res.stats["learner_steps"] == 6
    assert res.inference_stats is not None
    assert res.inference_stats.requests >= res.inference_stats.dispatches
    assert res.inference_stats.dispatches > 0


# --- hot-path satellites (PR 4) ---------------------------------------------

def test_write_back_filtered_reordered_subset():
    """The device-side partition must honor the documented contract: any
    subset/ordering of keys from batches this fabric assembled scatters to
    the owning shard (uneven per-shard counts, including an empty one)."""
    preset = tiny_preset(min_fill=48, batch_size=16)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    fabric = ReplayFabric(cfg, item_example(env), num_shards=2).start()
    try:
        fill_fabric(fabric, cfg, env, agent, n_blocks=6)
        batch = None
        deadline = time.monotonic() + 5.0
        while batch is None and time.monotonic() < deadline:
            batch = fabric.get_batch(timeout=0.1)
        assert batch is not None
        cap = fabric.shard_capacity
        idx = np.asarray(batch.indices)
        # keep only shard 0's keys (first half of the merged layout),
        # reversed — shard 1's update queue must stay untouched
        keep = jnp.asarray(idx[:8][::-1].copy())
        fabric.write_back(keep, jnp.full((8,), 4.0, jnp.float32))
    finally:
        fabric.stop()
    assert fabric.error is None
    assert fabric.shards[0].snapshot().updates_applied == 1
    assert fabric.shards[1].snapshot().updates_applied == 0
    leaves0 = np.asarray(sumtree.leaves(fabric.replay_states()[0].tree))
    np.testing.assert_allclose(
        leaves0[idx[:8]], float(prio.to_leaf(jnp.asarray(4.0))), rtol=1e-6)


def test_latency_emas_populate():
    """After enough owner-loop ops the sampled per-op latency EMAs must be
    nonzero and aggregate as averages (not sums) across shards."""
    preset = tiny_preset(min_fill=8)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    fabric = ReplayFabric(cfg, item_example(env), num_shards=2).start()
    try:
        # push >= 8 blocks per shard, tolerating backpressure retries (the
        # shards start prefetching mid-fill, which stalls their add queues
        # while `sample` compiles)
        block = make_block(cfg, env, agent)
        pushed = 0
        deadline = time.monotonic() + 60.0
        while pushed < 20 and time.monotonic() < deadline:
            if fabric.add(block, timeout=0.2):
                pushed += 1
        assert pushed == 20, "fabric never absorbed the fill blocks"
        deadline = time.monotonic() + 10.0
        while (fabric.snapshot().add_us == 0.0
               and time.monotonic() < deadline):
            time.sleep(0.01)
    finally:
        fabric.stop()
    assert fabric.error is None
    agg = fabric.snapshot()
    per_shard = [s.add_us for s in fabric.shard_snapshots() if s.add_us > 0]
    assert agg.add_us > 0.0
    assert agg.add_us <= max(per_shard) + 1e-9  # an average, not a sum


def test_caller_state_survives_donated_ops():
    """The shard copies the incoming ReplayState before donating it into
    jit, so the caller's reference (and a state template reused across
    shards) stays readable after ops ran."""
    preset = tiny_preset(min_fill=8)
    cfg, env, agent = preset.apex, preset.env, preset.agent
    template = replay_lib.init(cfg.replay, item_example(env))
    shards = [ReplayShard(cfg, template, shard_id=k).start()
              for k in range(2)]
    block = make_block(cfg, env, agent)
    try:
        for sh in shards:
            assert sh.add(block, timeout=5.0)
        deadline = time.monotonic() + 10.0
        while (any(sh.snapshot().blocks_added < 1 for sh in shards)
               and time.monotonic() < deadline):
            time.sleep(0.01)
    finally:
        for sh in shards:
            sh.stop()
    assert all(sh.error is None for sh in shards)
    # the template was never donated: still fully readable, still empty
    assert float(sumtree.total(template.tree)) == 0.0
    assert int(template.size) == 0
    for sh in shards:
        assert int(sh.replay_state.size) > 0
