"""Pallas kernel validation (interpret mode): shape/dtype sweeps, allclose vs
the pure-jnp oracles in each kernel's ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sumtree
from repro.core.nstep import from_trajectory
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.nstep_return.ops import nstep_return
from repro.kernels.sumtree_sample.ops import (sumtree_sample,
                                              sumtree_sample_with_mass)
from repro.kernels.sumtree_update.ops import sumtree_update


FLASH_CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window, off, bq, bk
    (2, 256, 256, 4, 2, 64, True, None, 0, 128, 128),
    (1, 128, 128, 4, 4, 32, True, None, 0, 64, 64),
    (1, 200, 200, 4, 2, 32, True, 64, 0, 64, 64),    # SWA, ragged blocks
    (2, 1, 384, 8, 2, 64, True, None, 255, 1, 128),  # decode shape
    (1, 128, 128, 2, 1, 128, False, None, 0, 64, 64),  # encoder
    (1, 96, 96, 2, 2, 80, True, None, 0, 32, 32),    # non-128 head_dim (danube)
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, Hq, Hkv, D, causal, window, off, bq, bk = case
    rng = jax.random.split(jax.random.key(Sq + Sk + off), 3)
    q = jax.random.normal(rng[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(rng[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(rng[2], (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, q_offset=off,
                          block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window, q_offset=off)
    ref = jnp.swapaxes(ref, 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("cap,B,block", [(64, 32, 32), (256, 100, 64),
                                         (1024, 512, 256), (32, 7, 8)])
def test_sumtree_sample_matches_ref(cap, B, block):
    leaves = jax.random.uniform(jax.random.key(cap), (cap,))
    tree = sumtree.rebuild(leaves)
    u = jax.random.uniform(jax.random.key(B), (B,)) * sumtree.total(tree)
    ref = sumtree.sample(tree, u)
    got = sumtree_sample(tree, u, block_b=block, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # fused variant: identical indices plus bitwise leaf masses
    got_idx, got_mass = sumtree_sample_with_mass(tree, u, block_b=block,
                                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(got_mass),
                                  np.asarray(sumtree.leaves(tree)[ref]))


@pytest.mark.parametrize("cap,B,block", [(64, 32, 32), (256, 100, 64),
                                         (1024, 512, 128), (32, 7, 8),
                                         (64, 64, 16)])
def test_sumtree_update_matches_ref(cap, B, block):
    """Incremental Pallas update == XLA incremental == scatter + rebuild,
    bit-for-bit, with duplicate writers resolved last-writer-wins."""
    rng = np.random.RandomState(cap + B)
    leaves = jnp.asarray(rng.uniform(0, 10, cap).astype(np.float32))
    tree = sumtree.rebuild(leaves)
    idx = jnp.asarray(rng.randint(0, cap, B).astype(np.int32))
    if B >= 4:  # force duplicate writers with different values
        idx = idx.at[1].set(idx[0]).at[3].set(idx[0])
    vals = jnp.asarray(rng.uniform(0, 5, B).astype(np.float32))
    ref = sumtree.write_rebuild(tree, idx, vals)
    np.testing.assert_array_equal(
        np.asarray(sumtree.update(tree, idx, vals)), np.asarray(ref))
    got = sumtree_update(tree, idx, vals, block_b=block, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_sumtree_update_kernel_index_handling():
    """Scatter-faithful index handling (and the block padding path): -1
    wraps to C-1, >= C (and < -C) drops — bitwise equal to the oracle."""
    tree = sumtree.rebuild(jnp.array([1.0, 2.0, 3.0, 4.0]))
    idx = jnp.array([-1, 4, 2], jnp.int32)   # block_b=2: exercises padding
    vals = jnp.array([9.0, 8.0, 7.0])
    got = sumtree_update(tree, idx, vals, block_b=2, interpret=True)
    ref = sumtree.write_rebuild(tree, idx, vals)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert float(sumtree.leaves(got)[3]) == 9.0  # -1 wrapped, 4 dropped


def test_sumtree_update_kernel_cross_block_last_writer_wins():
    """Duplicate writers split across grid blocks: the later block's lane
    must win, matching the XLA scatter's in-order resolution."""
    tree = sumtree.rebuild(jnp.ones((8,), jnp.float32))
    idx = jnp.array([5, 1, 5, 5], jnp.int32)   # block_b=2: dup spans blocks
    vals = jnp.array([2.0, 3.0, 4.0, 6.0], jnp.float32)
    got = sumtree_update(tree, idx, vals, block_b=2, interpret=True)
    ref = sumtree.write_rebuild(tree, idx, vals)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert float(sumtree.leaves(got)[5]) == 6.0


@pytest.mark.parametrize("lanes,T,n,block", [(8, 20, 3, 8), (100, 16, 5, 32),
                                             (3, 7, 1, 4), (17, 33, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nstep_return_matches_ref(lanes, T, n, block, dtype):
    r = jax.random.normal(jax.random.key(lanes), (lanes, T), dtype)
    g = ((jax.random.uniform(jax.random.key(T), (lanes, T)) > 0.1) * 0.99
         ).astype(dtype)
    ret_ref, disc_ref = from_trajectory(r.astype(jnp.float32),
                                        g.astype(jnp.float32), n)
    ret, disc = nstep_return(r, g, n, block_lanes=block, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ret), np.asarray(ret_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(disc), np.asarray(disc_ref),
                               rtol=tol, atol=tol)


def test_flash_attention_is_differentiable():
    """The chunked/flash path participates in training — grads must flow."""
    rng = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(rng[0], (1, 64, 2, 32))
    k = jax.random.normal(rng[1], (1, 64, 1, 32))
    v = jax.random.normal(rng[2], (1, 64, 1, 32))

    def f(q):
        return flash_attention(q, k, v, interpret=True, block_q=32,
                               block_k=32).sum()

    g = jax.grad(f)(q)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.abs(g).sum()) > 0
