"""Pallas kernel validation (interpret mode): shape/dtype sweeps, allclose vs
the pure-jnp oracles in each kernel's ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import priority as prio, sumtree
from repro.core.nstep import from_trajectory
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.nstep_return.ops import nstep_return
from repro.kernels.replay_ingest.ops import replay_ingest
from repro.kernels.replay_ingest.ref import replay_ingest_ref
from repro.kernels.sumtree_sample.ops import (sumtree_sample,
                                              sumtree_sample_with_mass)
from repro.kernels.sumtree_update.ops import sumtree_update


FLASH_CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window, off, bq, bk
    (2, 256, 256, 4, 2, 64, True, None, 0, 128, 128),
    (1, 128, 128, 4, 4, 32, True, None, 0, 64, 64),
    (1, 200, 200, 4, 2, 32, True, 64, 0, 64, 64),    # SWA, ragged blocks
    (2, 1, 384, 8, 2, 64, True, None, 255, 1, 128),  # decode shape
    (1, 128, 128, 2, 1, 128, False, None, 0, 64, 64),  # encoder
    (1, 96, 96, 2, 2, 80, True, None, 0, 32, 32),    # non-128 head_dim (danube)
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, Hq, Hkv, D, causal, window, off, bq, bk = case
    rng = jax.random.split(jax.random.key(Sq + Sk + off), 3)
    q = jax.random.normal(rng[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(rng[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(rng[2], (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, q_offset=off,
                          block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window, q_offset=off)
    ref = jnp.swapaxes(ref, 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("cap,B,block", [(64, 32, 32), (256, 100, 64),
                                         (1024, 512, 256), (32, 7, 8)])
def test_sumtree_sample_matches_ref(cap, B, block):
    leaves = jax.random.uniform(jax.random.key(cap), (cap,))
    tree = sumtree.rebuild(leaves)
    u = jax.random.uniform(jax.random.key(B), (B,)) * sumtree.total(tree)
    ref = sumtree.sample(tree, u)
    got = sumtree_sample(tree, u, block_b=block, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # fused variant: identical indices plus bitwise leaf masses
    got_idx, got_mass = sumtree_sample_with_mass(tree, u, block_b=block,
                                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(got_mass),
                                  np.asarray(sumtree.leaves(tree)[ref]))


@pytest.mark.parametrize("cap,B,block", [(64, 32, 32), (256, 100, 64),
                                         (1024, 512, 128), (32, 7, 8),
                                         (64, 64, 16)])
def test_sumtree_update_matches_ref(cap, B, block):
    """Incremental Pallas update == XLA incremental == scatter + rebuild,
    bit-for-bit, with duplicate writers resolved last-writer-wins."""
    rng = np.random.RandomState(cap + B)
    leaves = jnp.asarray(rng.uniform(0, 10, cap).astype(np.float32))
    tree = sumtree.rebuild(leaves)
    idx = jnp.asarray(rng.randint(0, cap, B).astype(np.int32))
    if B >= 4:  # force duplicate writers with different values
        idx = idx.at[1].set(idx[0]).at[3].set(idx[0])
    vals = jnp.asarray(rng.uniform(0, 5, B).astype(np.float32))
    ref = sumtree.write_rebuild(tree, idx, vals)
    np.testing.assert_array_equal(
        np.asarray(sumtree.update(tree, idx, vals)), np.asarray(ref))
    got = sumtree_update(tree, idx, vals, block_b=block, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_sumtree_update_kernel_index_handling():
    """Scatter-faithful index handling (and the block padding path): -1
    wraps to C-1, >= C (and < -C) drops — bitwise equal to the oracle."""
    tree = sumtree.rebuild(jnp.array([1.0, 2.0, 3.0, 4.0]))
    idx = jnp.array([-1, 4, 2], jnp.int32)   # block_b=2: exercises padding
    vals = jnp.array([9.0, 8.0, 7.0])
    got = sumtree_update(tree, idx, vals, block_b=2, interpret=True)
    ref = sumtree.write_rebuild(tree, idx, vals)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert float(sumtree.leaves(got)[3]) == 9.0  # -1 wrapped, 4 dropped


def test_sumtree_update_kernel_cross_block_last_writer_wins():
    """Duplicate writers split across grid blocks: the later block's lane
    must win, matching the XLA scatter's in-order resolution."""
    tree = sumtree.rebuild(jnp.ones((8,), jnp.float32))
    idx = jnp.array([5, 1, 5, 5], jnp.int32)   # block_b=2: dup spans blocks
    vals = jnp.array([2.0, 3.0, 4.0, 6.0], jnp.float32)
    got = sumtree_update(tree, idx, vals, block_b=2, interpret=True)
    ref = sumtree.write_rebuild(tree, idx, vals)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert float(sumtree.leaves(got)[5]) == 6.0


def _ingest_case(cap, B, seed):
    """Random fused-ingest inputs: a partially-filled tree, a mixed-dtype
    storage pytree (matrix, int32 vector, scalar leaf), duplicate slots,
    overflow lanes (idx == C, the alloc path's drop sentinel) and a mixed
    applied mask."""
    rng = np.random.RandomState(seed)
    leaves = (rng.uniform(0, 10, cap) * (rng.uniform(size=cap) > 0.3))
    tree = sumtree.rebuild(jnp.asarray(leaves.astype(np.float32)))
    storage = {"obs": jnp.asarray(rng.normal(size=(cap, 5)).astype(np.float32)),
               "act": jnp.asarray(rng.randint(0, 7, cap).astype(np.int32)),
               "ret": jnp.asarray(rng.normal(size=cap).astype(np.float32))}
    items = {"obs": jnp.asarray(rng.normal(size=(B, 5)).astype(np.float32)),
             "act": jnp.asarray(rng.randint(0, 7, B).astype(np.int32)),
             "ret": jnp.asarray(rng.normal(size=B).astype(np.float32))}
    idx = rng.randint(0, cap + 1, B)           # cap == dropped overflow lane
    if B >= 4:                                 # force duplicate writers
        idx[1] = idx[0]
        idx[3] = idx[0]
    prios = jnp.asarray(rng.uniform(-3.0, 3.0, B).astype(np.float32))
    applied = jnp.asarray(rng.uniform(size=B) > 0.3)
    return tree, storage, jnp.asarray(idx.astype(np.int32)), prios, applied, items


def _assert_ingest_equal(got, want):
    got_tree, got_storage = got
    want_tree, want_storage = want
    np.testing.assert_array_equal(np.asarray(got_tree), np.asarray(want_tree))
    for k in want_storage:
        assert got_storage[k].dtype == want_storage[k].dtype
        assert got_storage[k].shape == want_storage[k].shape
        np.testing.assert_array_equal(np.asarray(got_storage[k]),
                                      np.asarray(want_storage[k]), err_msg=k)


@pytest.mark.parametrize("cap,B,block", [(64, 32, 32), (256, 100, 64),
                                         (32, 7, 8), (64, 64, 16),
                                         (16, 16, 1)])
def test_replay_ingest_matches_ref(cap, B, block):
    """Fused ingest (priority init + storage scatter + tree repair) ==
    the three-dispatch oracle, bit-for-bit, across block geometries."""
    tree, storage, idx, prios, applied, items = _ingest_case(cap, B, cap + B)
    want = replay_ingest_ref(tree, storage, idx, prios, applied, items)
    got = replay_ingest(tree, storage, idx, prios, applied, items,
                        block_b=block, interpret=True)
    _assert_ingest_equal(got, want)


def test_replay_ingest_index_handling():
    """Scatter-faithful index handling (and the block padding path): -1
    wraps to C-1, idx == C (the alloc overflow sentinel) drops without
    touching slot 0."""
    cap = 8
    tree, storage, _, _, _, items = _ingest_case(cap, 3, 7)
    idx = jnp.array([-1, cap, 2], jnp.int32)   # block_b=2: exercises padding
    prios = jnp.array([9.0, 8.0, 7.0], jnp.float32)
    applied = jnp.array([True, True, True])
    want = replay_ingest_ref(tree, storage, idx, prios, applied, items)
    got = replay_ingest(tree, storage, idx, prios, applied, items,
                        block_b=2, interpret=True)
    _assert_ingest_equal(got, want)
    got_tree, got_storage = got
    # -1 wrapped: slot C-1 carries lane 0's item; the overflow lane changed
    # nothing (in particular slot 0 kept its original row).
    np.testing.assert_array_equal(np.asarray(got_storage["obs"][cap - 1]),
                                  np.asarray(items["obs"][0]))
    np.testing.assert_array_equal(np.asarray(got_storage["obs"][0]),
                                  np.asarray(storage["obs"][0]))


def test_replay_ingest_cross_block_last_writer_wins():
    """Duplicate slots split across grid blocks resolve like the XLA
    scatter: the later lane wins — and a masked later duplicate re-writes
    the *original* row/leaf (gather-all-then-scatter), not the earlier
    lane's value."""
    cap = 8
    tree, storage, _, _, _, items = _ingest_case(cap, 4, 11)
    idx = jnp.array([5, 1, 5, 5], jnp.int32)   # block_b=2: dup spans blocks
    prios = jnp.array([2.0, 3.0, 4.0, 6.0], jnp.float32)
    applied = jnp.array([True, True, True, True])
    want = replay_ingest_ref(tree, storage, idx, prios, applied, items)
    got = replay_ingest(tree, storage, idx, prios, applied, items,
                        block_b=2, interpret=True)
    _assert_ingest_equal(got, want)
    got_tree, got_storage = got
    assert float(sumtree.leaves(got_tree)[5]) == float(
        prio.to_leaf(jnp.float32(6.0)))
    np.testing.assert_array_equal(np.asarray(got_storage["obs"][5]),
                                  np.asarray(items["obs"][3]))
    # masked later duplicate: lane 1 is not applied, so slot 5 must end up
    # with the ORIGINAL row/leaf (the mask re-writes old state, last).
    applied2 = jnp.array([True, False])
    idx2 = jnp.array([5, 5], jnp.int32)
    items2 = jax.tree.map(lambda x: x[:2], items)
    want2 = replay_ingest_ref(tree, storage, idx2, prios[:2], applied2, items2)
    got2 = replay_ingest(tree, storage, idx2, prios[:2], applied2, items2,
                         block_b=1, interpret=True)
    _assert_ingest_equal(got2, want2)
    np.testing.assert_array_equal(np.asarray(got2[1]["obs"][5]),
                                  np.asarray(storage["obs"][5]))
    np.testing.assert_array_equal(np.asarray(sumtree.leaves(got2[0])[5]),
                                  np.asarray(sumtree.leaves(tree)[5]))


@pytest.mark.parametrize("lanes,T,n,block", [(8, 20, 3, 8), (100, 16, 5, 32),
                                             (3, 7, 1, 4), (17, 33, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nstep_return_matches_ref(lanes, T, n, block, dtype):
    r = jax.random.normal(jax.random.key(lanes), (lanes, T), dtype)
    g = ((jax.random.uniform(jax.random.key(T), (lanes, T)) > 0.1) * 0.99
         ).astype(dtype)
    ret_ref, disc_ref = from_trajectory(r.astype(jnp.float32),
                                        g.astype(jnp.float32), n)
    ret, disc = nstep_return(r, g, n, block_lanes=block, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ret), np.asarray(ret_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(disc), np.asarray(disc_ref),
                               rtol=tol, atol=tol)


def test_flash_attention_is_differentiable():
    """The chunked/flash path participates in training — grads must flow."""
    rng = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(rng[0], (1, 64, 2, 32))
    k = jax.random.normal(rng[1], (1, 64, 1, 32))
    v = jax.random.normal(rng[2], (1, 64, 1, 32))

    def f(q):
        return flash_attention(q, k, v, interpret=True, block_q=32,
                               block_k=32).sum()

    g = jax.grad(f)(q)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.abs(g).sum()) > 0
