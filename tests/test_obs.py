"""Telemetry plane: histogram percentile math (property-tested against
numpy order statistics), deterministic trace sampling, trace-id
propagation across a loopback gateway round trip (tcp AND shm), the
JSONL sink, the structured log emitter, and the run report end to end."""

import json
import math
import threading

import numpy as np
import pytest
from _apex_helpers import make_block, tiny_preset
from _hypothesis_fallback import given, settings, st

from repro.net import transport, wire
from repro.net.gateway import ReplayGateway
from repro.net.learner_client import RemoteFabricSource
from repro.obs import MetricsRegistry, Telemetry, Tracer, log as obslog
from repro.obs.metrics import (_BUCKET_EDGES, _BUCKET_FACTOR, _NUM_BUCKETS,
                               Histogram, bucket_index)
from repro.obs import report as report_lib
from repro.obs.sink import METRICS_FILE, SPANS_FILE, JsonlSink
from repro.runtime import AsyncConfig, ParamStore, run_async


# --- histogram ---------------------------------------------------------------

def test_bucket_index_edges_and_clamps():
    for i in (0, 1, 17, _NUM_BUCKETS - 1):
        lo, hi = _BUCKET_EDGES[i], _BUCKET_EDGES[i + 1]
        assert bucket_index(lo) == i
        assert bucket_index(hi * 0.999999) == i
    assert bucket_index(0.0) == 0
    assert bucket_index(1e-9) == 0
    assert bucket_index(1e30) == _NUM_BUCKETS - 1


def test_single_value_histogram_is_honest():
    """Clamping to observed min/max: one sample must come back exactly,
    not smeared across its bucket."""
    h = Histogram("t")
    h.record(42.0)
    for q in (0.0, 50.0, 95.0, 99.0, 100.0):
        assert h.percentile(q) == pytest.approx(42.0)
    assert h.mean == pytest.approx(42.0)


@settings(max_examples=60, deadline=None)
@given(values=st.lists(st.floats(min_value=1.0, max_value=1e8,
                                 allow_nan=False, allow_infinity=False),
                       min_size=1, max_size=200),
       q=st.floats(min_value=0.0, max_value=100.0))
def test_histogram_percentile_tracks_numpy_order_stats(values, q):
    """Property (acceptance): the interpolated percentile lies within one
    geometric bucket ratio of the order statistic numpy's 'linear'
    convention anchors on — i.e. the histogram is exact up to its
    documented quantization, for any data shape (uniform, bimodal, spiky).
    """
    h = Histogram("t")
    for v in values:
        h.record(v)
    got = h.percentile(q)
    rank = (q / 100.0) * (len(values) - 1)
    v_sorted = np.sort(np.asarray(values))
    v_floor = v_sorted[int(math.floor(rank))]
    v_ceil = v_sorted[int(math.ceil(rank))]
    # the true numpy quantile lies in [v_floor, v_ceil]; ours lives in
    # v_floor's bucket (clamped to the observed range)
    tol = _BUCKET_FACTOR * 1.0001
    assert got >= v_floor / tol
    assert got <= max(v_floor * tol, v_ceil)
    assert np.quantile(v_sorted, q / 100.0) <= v_ceil * tol


def test_registry_create_or_get_and_snapshot():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.histogram("h") is reg.histogram("h")
    reg.counter("a").inc(3)
    reg.gauge("g").set(7.5)
    reg.histogram("h").record(100.0)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3
    assert snap["gauges"]["g"] == 7.5
    assert snap["histograms"]["h"]["count"] == 1
    assert snap["histograms"]["h"]["p50"] == pytest.approx(100.0)


def test_histogram_concurrent_records_lose_nothing():
    h = Histogram("t")
    n, threads = 2000, 8

    def work():
        for i in range(n):
            h.record(10.0 + (i % 50))

    ts = [threading.Thread(target=work) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert h.count == n * threads


# --- tracer ------------------------------------------------------------------

def test_tracer_rate_validation_and_determinism():
    with pytest.raises(ValueError, match="sample rate"):
        Tracer(1.5)
    with pytest.raises(ValueError, match="sample rate"):
        Tracer(-0.1)
    off = Tracer(0.0)
    assert not off.enabled
    assert all(off.sample() == 0 for _ in range(10))
    full = Tracer(1.0)
    ids = [full.sample() for _ in range(10)]
    assert all(ids) and len(set(ids)) == 10  # every call, all distinct
    half = Tracer(0.5)
    assert [bool(half.sample()) for i in range(8)] == [True, False] * 4


def test_tracer_record_drops_untraced_and_drains_in_order():
    tr = Tracer(1.0)
    tr.record("actor", 0, 123.0)          # untraced: must no-op
    assert tr.peek() == []
    tid = tr.new_id()
    tr.record("actor", tid, 10.0, actor=3)
    tr.record("add", tid, 20.0, shard=0)
    spans = tr.drain()
    assert [s["stage"] for s in spans] == ["actor", "add"]
    assert all(s["trace_id"] == tid for s in spans)
    assert spans[0]["actor"] == 3 and spans[1]["shard"] == 0
    assert tr.drain() == []               # drained means drained


# --- sink + log --------------------------------------------------------------

def test_jsonl_sink_writes_metrics_and_spans(tmp_path):
    reg = MetricsRegistry()
    tr = Tracer(1.0)
    reg.counter("c").inc(5)
    tr.record("actor", tr.new_id(), 11.0)
    sink = JsonlSink(str(tmp_path), reg, tr, flush_s=30.0)  # manual flushes
    sink.start()
    sink.stop()  # final flush on stop even if the interval never fired
    metrics = [json.loads(line) for line in
               (tmp_path / METRICS_FILE).read_text().splitlines()]
    spans = [json.loads(line) for line in
             (tmp_path / SPANS_FILE).read_text().splitlines()]
    assert metrics[-1]["counters"]["c"] == 5
    assert metrics[-1]["ts"] > 0
    assert spans[0]["stage"] == "actor" and spans[0]["dur_us"] == 11.0


def test_log_format_line_is_machine_parseable():
    line = obslog.format_line("async", t=12.34, generated=4096,
                              note="two words")
    assert line == "[async] t=12.3 generated=4096 note=two_words"
    fields = dict(tok.split("=", 1) for tok in line.split()[1:])
    assert fields["generated"] == "4096"


# --- trace-id propagation over the wire --------------------------------------

@pytest.mark.parametrize("kind", ["tcp", "shm"])
def test_trace_id_rides_the_frame_header(kind):
    """The id survives both byte paths: the shm ring (bulk data frames)
    and the socket (small/control frames), and absent ids read back 0."""
    lst = transport.listen("127.0.0.1", 0, accept_shm=True,
                           ring_bytes=1 << 16)
    box = {}

    def srv():
        conn = lst.accept(timeout=10.0)
        box["server"] = conn
        if kind != "tcp":
            conn.recv(timeout=1.0)  # serve the shm upgrade handshake

    th = threading.Thread(target=srv, daemon=True)
    th.start()
    client = transport.connect("127.0.0.1", lst.port, kind,
                               ring_bytes=1 << 16)
    th.join(timeout=10.0)
    server = box["server"]
    try:
        assert client.kind == kind
        rng = np.random.default_rng(0)
        big = wire.encode_tree({"x": rng.random(8000).astype(np.float32)})
        client.send(wire.ADD_BLOCK, big, trace_id=0xABC1)   # ring on shm
        assert server.recv(timeout=5.0)[0] == wire.ADD_BLOCK
        assert server.last_trace_id == 0xABC1
        client.send(wire.HELLO, wire.encode_json({"hi": 1}))  # untraced
        assert server.recv(timeout=5.0)[0] == wire.HELLO
        assert server.last_trace_id == 0
        small = wire.encode_tree({"y": np.arange(4, dtype=np.int32)})
        client.send(wire.PRIORITY_UPDATE, small, trace_id=0xABC2)  # socket
        assert server.recv(timeout=5.0)[0] == wire.PRIORITY_UPDATE
        assert server.last_trace_id == 0xABC2
    finally:
        for c in (client, server, lst):
            try:
                c.close()
            except Exception:
                pass


class _TraceRecordingFabric:
    """SampleSource-shaped fake that records the trace ids the gateway
    hands to add/write_back."""

    def __init__(self, batch=None):
        self.add_tids = []
        self.writeback_tids = []
        self._batch = batch

    def add(self, block, timeout=None, trace_id=0):
        self.add_tids.append(trace_id)
        return True

    def get_batch(self, timeout=None):
        return self._batch

    def write_back(self, indices, priorities, trace_id=0):
        self.writeback_tids.append(trace_id)


@pytest.mark.parametrize("kind", ["tcp", "shm"])
def test_trace_id_propagates_through_gateway_round_trip(kind):
    """Acceptance (satellite): a traced block's id crosses the wire into
    the gateway's span and the fabric's add; a traced learner round's id
    crosses back inside the coalesced PRIORITY_UPDATE into write_back —
    over tcp AND shm."""
    preset = tiny_preset()
    block = make_block(preset.apex, preset.env, preset.agent)
    from repro.core.sampling import LearnerBatch
    rng = np.random.default_rng(0)
    batch = LearnerBatch(rng.integers(0, 99, 8).astype(np.int32),
                         {"obs": rng.random((8, 4)).astype(np.float32)},
                         np.ones(8, np.float32))
    fabric = _TraceRecordingFabric(batch)
    gw_tel = Telemetry(tracer=Tracer(0.0))  # gateway records, never samples
    gw = ReplayGateway(fabric, ParamStore({}), telemetry=gw_tel).start()

    # ingest plane: actor-side frame header -> gateway span -> fabric.add
    conn = transport.connect(gw.host, gw.port, kind)
    try:
        assert conn.kind == kind
        conn.send(wire.HELLO, wire.encode_json(
            {"actor_id": 0, "protocol": wire.PROTOCOL_VERSION}))
        conn.send(wire.ADD_BLOCK, wire.encode_block_iov(block),
                  trace_id=0xBEEF)
        assert conn.recv(timeout=10.0)[0] == wire.ADD_ACK
    finally:
        conn.close()
    assert fabric.add_tids == [0xBEEF]
    gw_spans = gw_tel.tracer.peek()
    assert [s["stage"] for s in gw_spans] == ["gateway"]
    assert gw_spans[0]["trace_id"] == 0xBEEF

    # consume plane: client samples its own id; the coalesced
    # PRIORITY_UPDATE carries it back to the fabric's write_back
    src_tel = Telemetry(tracer=Tracer(1.0))
    src = RemoteFabricSource(gw.host, gw.port, transport=kind,
                             telemetry=src_tel).start()
    try:
        got = src.get_batch(timeout=5.0)
        assert got is not None
        tid = src.last_trace_id
        assert tid != 0
        src.write_back(got.indices, np.ones(8, np.float32), trace_id=tid)
        src.get_batch(timeout=5.0)  # flushes the parked round
        deadline = [None] * 100
        for _ in deadline:
            if fabric.writeback_tids:
                break
            threading.Event().wait(0.05)
        assert fabric.writeback_tids == [tid]
        sample_spans = [s for s in src_tel.tracer.peek()
                        if s["stage"] == "sample"]
        assert sample_spans and sample_spans[0]["trace_id"] == tid
        assert sample_spans[0]["transport"] == kind
    finally:
        src.stop()
        gw.stop()
    assert gw.error is None


# --- end to end: traced run + report (acceptance) ----------------------------

def test_traced_run_report_shows_every_stage(tmp_path):
    """A tiny traced async run must yield a report where all five local
    pipeline stages (actor/add/sample/learn/writeback) show nonzero
    counts, rates, and latency percentiles, plus queue-depth gauges and
    the derived *_us views still feeding ServiceStats."""
    preset = tiny_preset()
    acfg = AsyncConfig(actor_threads=2, total_learner_steps=6,
                       max_seconds=60.0, seed=3,
                       metrics_dir=str(tmp_path), trace_sample_rate=1.0)
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    assert res.stats["learner_steps"] >= 6
    assert res.service_stats.add_us > 0.0  # derived view still populated

    rep = report_lib.load_report(str(tmp_path))
    for stage in ("actor", "add", "sample", "learn", "writeback"):
        row = rep["stages"][stage]
        assert row["count"] > 0, stage
        assert row["rate_hz"] > 0.0, stage
        assert row["p50_us"] > 0.0, stage
    assert "shard0/replay_size" in rep["gauges"]
    assert rep["histograms"]["shard0/add_us"]["count"] > 0
    # the rendered table carries every stage row
    text = report_lib.render(rep)
    for stage in ("actor", "add", "sample", "learn", "writeback"):
        assert stage in text
    # the CLI entry point renders the same directory (exit code 0)
    assert report_lib.main([str(tmp_path)]) == 0
    assert report_lib.main([str(tmp_path / "nope")]) == 2


def test_trace_sample_rate_validated_by_async_config():
    preset = tiny_preset()
    acfg = AsyncConfig(trace_sample_rate=1.5)
    with pytest.raises(ValueError, match="trace_sample_rate"):
        run_async(preset.apex, acfg, preset.env, preset.agent,
                  preset.make_optimizer())
