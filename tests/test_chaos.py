"""Chaos harness: mechanics units (always on) and the full crash-recovery
acceptance scenarios (gated behind ``REPRO_TEST_CHAOS=1`` — a CI matrix
leg runs them and uploads the recovered runs' telemetry JSONL).

Scenarios, each gating on *full recovery* (the run still reaches
``total_learner_steps``):

a. an actor process is SIGKILLed mid-stream → the supervisor respawns it;
b. the remote learner's transport is severed mid-frame → the source
   reconnects and the serve+learn pair completes;
c. a checkpointing learner process is SIGKILLed → a ``resume=True`` run
   continues from its latest snapshot to completion.

Plus the one fault the plane must NOT absorb: a dead replay shard owner
fails the run loudly (replay is state — losing it silently would corrupt
the experiment).
"""

import multiprocessing
import os
import socket
import threading
import time

import pytest
from _apex_helpers import tiny_preset

from repro.checkpoint import checkpoint as ckpt_lib
from repro.runtime import AsyncConfig, run_async
from repro.testing import chaos

CHAOS = bool(os.environ.get("REPRO_TEST_CHAOS"))
needs_chaos = pytest.mark.skipif(
    not CHAOS, reason="chaos scenarios run on the REPRO_TEST_CHAOS CI leg")
# The chaos CI leg points this at a directory it uploads as an artifact:
# the *recovered* runs write their metrics/spans JSONL here.
METRICS_ROOT = os.environ.get("REPRO_TEST_CHAOS_METRICS_DIR") or None


def _metrics_dir(scenario: str) -> str | None:
    if METRICS_ROOT is None:
        return None
    d = os.path.join(METRICS_ROOT, scenario)
    os.makedirs(d, exist_ok=True)
    return d


# Trace every block/step in the recovered runs so the uploaded artifact
# carries spans.jsonl alongside metrics.jsonl (tiny runs — cheap).
_TRACE_RATE = 1.0 if METRICS_ROOT else 0.0


# --- mechanics (ungated) ---------------------------------------------------

class _FakeHandles:
    def __init__(self):
        self.stop = threading.Event()


def test_monkey_applies_plan_in_order_and_records_errors():
    h = _FakeHandles()
    order = []
    plan = [
        chaos.Fault(0.02, "second", lambda _: order.append("second")),
        chaos.Fault(0.0, "first", lambda _: order.append("first")),
        chaos.Fault(0.03, "boom",
                    lambda _: (_ for _ in ()).throw(OSError("nope"))),
    ]
    monkey = chaos.ChaosMonkey(plan)
    monkey.on_handles(h)
    monkey.join()
    assert order == ["first", "second"]
    assert monkey.applied == ["first", "second"]
    assert [name for name, _ in monkey.errors] == ["boom"]


def test_monkey_stops_with_the_run():
    h = _FakeHandles()
    fired = []
    monkey = chaos.ChaosMonkey(
        [chaos.Fault(30.0, "late", lambda _: fired.append(1))])
    monkey.on_handles(h)
    h.stop.set()                       # run ended before the fault's time
    monkey.join()
    assert not monkey._thread.is_alive()
    assert fired == [] and monkey.applied == []


def test_dead_shard_owner_fails_the_run_loudly():
    """Actors and transports are expendable; replay state is not. A poisoned
    shard owner must surface as a runtime error, never a silent hang or a
    quietly-wrong result."""
    preset = tiny_preset()
    monkey = chaos.ChaosMonkey([chaos.kill_shard_owner(0.05, shard=0)])
    with pytest.raises(RuntimeError, match="worker died"):
        run_async(
            preset.apex,
            AsyncConfig(actor_threads=1, total_learner_steps=1_000_000,
                        max_seconds=60, seed=2),
            preset.env, preset.agent, preset.make_optimizer(),
            on_handles=monkey.on_handles)
    monkey.join()
    assert monkey.applied == ["kill_shard_owner[0]"], monkey.errors


# --- scenario (a): killed actor process, supervised respawn ---------------

@needs_chaos
def test_chaos_killed_actor_proc_run_recovers():
    preset = tiny_preset()
    monkey = chaos.ChaosMonkey([chaos.kill_actor_proc(0.5, slot=0)])
    res = run_async(
        preset.apex,
        AsyncConfig(actor_threads=0, actor_procs=2, total_learner_steps=20,
                    max_seconds=300, seed=21,
                    metrics_dir=_metrics_dir("killed-actor"),
                    trace_sample_rate=_TRACE_RATE),
        preset.env, preset.agent, preset.make_optimizer(),
        on_handles=monkey.on_handles)
    monkey.join()
    assert monkey.applied == ["kill_actor_proc[0]"], monkey.errors
    assert res.stats["learner_steps"] == 20       # full recovery
    assert res.stats["actor_proc_exits"] >= 1
    assert res.stats["actor_restarts"] >= 1


# --- scenario (b): severed learner transport, reconnect -------------------

@needs_chaos
def test_chaos_severed_learner_transport_run_recovers():
    preset = tiny_preset()
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    steps = 400
    serve_out = {}

    # Gateway-side sever, triggered deterministically once 50 of the
    # learner's write-backs are through (no wall-clock race).
    def serve_handles(h):
        def cut():
            while (h.gateway.snapshot().priority_updates < 50
                   and not h.stop.is_set()):
                time.sleep(0.001)
            if not h.stop.is_set():
                chaos.sever_gateway_transports(0.0).apply(h)
        threading.Thread(target=cut, daemon=True).start()

    def serve():
        serve_out["res"] = run_async(
            preset.apex,
            AsyncConfig(actor_threads=1, serve_sampling=True,
                        gateway_port=port, total_learner_steps=steps,
                        transport="tcp", max_seconds=300),
            preset.env, preset.agent, preset.make_optimizer(),
            on_handles=serve_handles)

    th = threading.Thread(target=serve, daemon=True)
    th.start()
    res = run_async(
        preset.apex,
        AsyncConfig(actor_threads=0, learner_remote=f"127.0.0.1:{port}",
                    total_learner_steps=steps, transport="tcp",
                    max_seconds=300,
                    metrics_dir=_metrics_dir("severed-learner"),
                    trace_sample_rate=_TRACE_RATE),
        preset.env, preset.agent, preset.make_optimizer())
    th.join(timeout=300)
    assert not th.is_alive()
    assert res.stats["learner_steps"] == steps    # full recovery
    assert res.stats["source_reconnects"] >= 1
    # Gateway-side sever can swallow in-flight priority frames; the
    # learner's BYE ends the serve run even so (tolerated-loss mode).
    assert serve_out["res"].stats["learner_steps"] >= steps - 50


# --- scenario (c): SIGKILLed checkpointing run, resumed -------------------

def _ckpt_run_child(ckpt_dir: str) -> None:
    """Spawn target: a checkpointing run that never finishes on its own —
    the parent SIGKILLs it mid-stride."""
    preset = tiny_preset()
    run_async(
        preset.apex,
        AsyncConfig(actor_threads=2, total_learner_steps=1_000_000,
                    checkpoint_dir=ckpt_dir, checkpoint_every_s=0.2,
                    max_seconds=300, seed=7),
        preset.env, preset.agent, preset.make_optimizer())


@needs_chaos
def test_chaos_sigkilled_learner_resumes_from_snapshot(tmp_path):
    preset = tiny_preset()
    ckpt_dir = str(tmp_path / "snaps")
    ctx = multiprocessing.get_context("spawn")
    p = ctx.Process(target=_ckpt_run_child, args=(ckpt_dir,), daemon=True)
    p.start()
    def _latest_step():
        newest = ckpt_lib.latest(ckpt_dir)
        if newest is None:
            return -1
        return int(os.path.basename(newest)[len("ckpt_"):-len(".npz")])

    try:
        # Wait for a snapshot of real progress (step >= 1), not just the
        # early ones taken while the learner was still behind min-fill.
        deadline = time.monotonic() + 240.0
        while _latest_step() < 1:
            assert time.monotonic() < deadline, "no snapshot ever landed"
            assert p.is_alive(), "checkpointing run died on its own"
            time.sleep(0.05)
    finally:
        p.kill()                 # SIGKILL: no finally blocks, no final save
        p.join(timeout=30.0)
    step = _latest_step()
    assert step >= 1

    res = run_async(
        preset.apex,
        AsyncConfig(actor_threads=2, total_learner_steps=step + 20,
                    checkpoint_dir=ckpt_dir, checkpoint_every_s=30.0,
                    resume=True, max_seconds=300, seed=7,
                    metrics_dir=_metrics_dir("resumed-learner"),
                    trace_sample_rate=_TRACE_RATE),
        preset.env, preset.agent, preset.make_optimizer())
    assert res.stats["resumed_from_step"] == step
    assert res.stats["learner_steps"] == step + 20      # full recovery
    assert int(res.learner.learner_step) == step + 20
    assert res.stats["snapshots"] >= 1
