"""Fig. 3 — Ape-X DPG on continuous control.

Paper: performance improves with actor count on the control suite tasks.
Here: the DPG preset on PointMass at two lane counts + the prioritized
eviction strategy exercised (Appendix D)."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, run_apex
from repro.configs import apex_dpg


def main():
    preset = apex_dpg.reduced()
    for lanes in (4, 16):
        cfg = dataclasses.replace(preset.apex, lanes_per_shard=lanes)
        r = run_apex(cfg, preset, iters=50, seed=3)
        emit(f"fig3/actors={lanes}/final_return", r["us_per_iter"],
             f"{r['final_return']:.3f}")


if __name__ == "__main__":
    main()
