"""Fig. 4 + Fig. 11 — scaling the number of actors.

Fig. 11's claim: data-generation speed scales linearly with actor count.
Fig. 4's claim: with the learner update rate held fixed, more actors give
better returns. Evaluation follows the paper: the *greedy* policy is scored
on held-out episodes (the training-lane mean would be polluted by the
high-eps exploration lanes that grow with actor count). A harder chain than
the smoke preset is used so exploration actually matters.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import apex_dqn
from repro.core import apex
from repro.envs.synthetic import ChainWorld, batch_reset, batch_step


def greedy_eval(preset, params, episodes=16, seed=123):
    env, agent = preset.env, preset.agent
    states, obs = batch_reset(env, jax.random.key(seed), episodes)
    total = jax.numpy.zeros((episodes,))
    done = jax.numpy.zeros((episodes,), bool)
    eps = jax.numpy.zeros((episodes,))
    rng = jax.random.key(seed + 1)
    for _ in range(env.max_steps + 1):
        rng, a_rng = jax.random.split(rng)
        a, _ = agent.act(params, a_rng, obs, eps)
        states, out = batch_step(env, states, a)
        total = total + out.reward * (~done)
        done = done | (out.discount == 0)
        obs = out.obs
    return float(total.mean())


def hard_preset():
    preset = apex_dqn.reduced()
    env = ChainWorld(length=16, max_steps=64)
    return dataclasses.replace(preset, env=env)


def main():
    preset = hard_preset()
    base = preset.apex
    rates, finals = {}, {}
    for lanes in (4, 8, 16, 32):
        cfg = dataclasses.replace(base, lanes_per_shard=lanes)
        optimizer = preset.make_optimizer()
        init_fn, step_fn = apex.make_train_fn(cfg, preset.env, preset.agent,
                                              optimizer)
        iters, us, scores = 80, 0.0, []
        for seed in (2, 3, 4):   # greedy eval is seed-averaged (toy scale)
            state = init_fn(jax.random.key(seed))
            state, m = step_fn(state)  # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                state, m = step_fn(state)
            jax.block_until_ready(m["loss"])
            dt = time.perf_counter() - t0
            us = 1e6 * dt / iters
            rates[lanes] = lanes * cfg.rollout_len / (dt / iters)
            scores.append(greedy_eval(preset, state.params, seed=seed))
        finals[lanes] = float(np.mean(scores))
        emit(f"fig4/actors={lanes}/greedy_eval", us, f"{finals[lanes]:.3f}")
        emit(f"fig11/actors={lanes}/transitions_per_s", us,
             f"{rates[lanes]:.0f}")
    emit("fig11/scaling_efficiency_4_to_32", 0.0,
         f"{rates[32] / rates[4] / 8.0:.2f}")
    ordered = [finals[k] for k in (4, 8, 16, 32)]
    emit("fig4/return_monotonicity", 0.0,
         f"{np.sign(np.diff(ordered)).sum():.0f}")


if __name__ == "__main__":
    main()
