"""§Roofline — aggregate the dry-run artifacts into the per-(arch x shape x
mesh) roofline table (compute/memory/collective terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs useful-compute ratio).

Reads benchmarks/artifacts/dryrun_*.json (written by repro.launch.dryrun) and
prints a markdown table + emits CSV rows. Use --write-experiments to refresh
the table block in EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

COLUMNS = ("arch", "shape", "mesh", "status", "compute_s", "memory_s",
           "collective_s", "bottleneck", "useful_flops_ratio")


def load(mesh_filter: str | None = None, variants: bool = False):
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "dryrun_*.json"))):
        is_variant = "__" in os.path.basename(path)
        if is_variant != variants:
            continue
        with open(path) as f:
            rec = json.load(f)
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        recs.append(rec)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    return recs


def fmt(v, spec=".4f"):
    if v is None:
        return "-"
    if isinstance(v, float):
        return format(v, spec)
    return str(v)


def markdown_table(recs) -> str:
    lines = ["| arch | shape | mesh | status | compute (s) | memory (s) | "
             "collective (s) | bottleneck | useful-FLOPs ratio |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']}: {r.get('reason', r.get('error', ''))[:60]} "
                f"| - | - | - | - | - |")
            continue
        variant = r.get("variant", "baseline")
        tag = "" if variant == "baseline" else f" ({variant})"
        lines.append(
            f"| {r['arch']}{tag} | {r['shape']} | {r['mesh']} | ok | "
            f"{fmt(r['compute_s'])} | {fmt(r['memory_s'])} | "
            f"{fmt(r['collective_s'])} | {r['bottleneck']} | "
            f"{fmt(r.get('useful_flops_ratio'), '.3f')} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("16x16", "2x16x16"))
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--variants", action="store_true",
                    help="show §Perf variant runs instead of baselines")
    args = ap.parse_args()
    recs = load(args.mesh, variants=args.variants)
    if args.markdown:
        print(markdown_table(recs))
        return
    for r in recs:
        if r["status"] == "ok":
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
                  f"bottleneck={r['bottleneck']};compute={r['compute_s']:.4f}"
                  f";memory={r['memory_s']:.4f}"
                  f";collective={r['collective_s']:.4f}")
        else:
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
                  f"{r['status']}")


if __name__ == "__main__":
    main()
