"""Kernel microbenchmarks (§Contention / Appendix F): the replay's batched
sampling op and the n-step builder, XLA path vs Pallas-interpret oracle-check
timing. Wall numbers are CPU artifacts; the row exists to track relative
regressions."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import sumtree
from repro.core.nstep import from_trajectory


def timeit(fn, *args, iters=20):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / iters


def main():
    cap, batch = 1 << 15, 512
    leaves = jax.random.uniform(jax.random.key(0), (cap,))
    tree = sumtree.rebuild(leaves)
    u = jax.random.uniform(jax.random.key(1), (batch,)) * sumtree.total(tree)

    sample = jax.jit(sumtree.sample)
    us = timeit(sample, tree, u)
    emit(f"replay/sumtree_sample_xla/cap={cap}/b={batch}", us,
         f"{batch / us:.1f}samples_per_us")

    wr = jax.jit(sumtree.write)
    idx = jnp.arange(batch, dtype=jnp.int32)
    us = timeit(wr, tree, idx, u)
    emit(f"replay/sumtree_write/cap={cap}/b={batch}", us, "rebuild")

    r = jax.random.normal(jax.random.key(2), (256, 64))
    g = jnp.full((256, 64), 0.99)
    ns = jax.jit(lambda r, g: from_trajectory(r, g, 3))
    us = timeit(ns, r, g)
    emit("replay/nstep_from_trajectory/lanes=256/T=64", us, "n=3")


if __name__ == "__main__":
    main()
