"""Kernel microbenchmarks (§Contention / Appendix F): the replay's batched
sampling descent and incremental tree update — XLA paths vs the Pallas
kernels (interpret mode off-TPU) — plus the n-step builder. Wall numbers are
CPU artifacts; the rows exist to track relative regressions, and the full
result set lands in ``BENCH_kernels.json`` (committed repo-root twin) so the
kernel numbers join the perf trajectory."""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.common import emit, write_artifact  # noqa: E402
from repro.core import sumtree  # noqa: E402
from repro.core.nstep import from_trajectory  # noqa: E402
from repro.kernels.sumtree_sample.ops import (  # noqa: E402
    sumtree_sample_with_mass)
from repro.kernels.sumtree_update.ops import sumtree_update  # noqa: E402


def timeit(fn, *args, iters=20):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / iters


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cap", type=int, default=1 << 15)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--json", default=None,
                    help="stable artifact path for the JSON result set")
    args = ap.parse_args()
    cap, batch = args.cap, args.batch

    # Pallas compiles natively on TPU; elsewhere the kernels run under the
    # interpreter — orders of magnitude slower, but the row proves the
    # kernel path stays runnable and tracks its own trend.
    pallas_mode = "compiled" if jax.default_backend() == "tpu" else "interpret"
    interpret = pallas_mode == "interpret"

    leaves = jax.random.uniform(jax.random.key(0), (cap,))
    tree = sumtree.rebuild(leaves)
    u = jax.random.uniform(jax.random.key(1), (batch,)) * sumtree.total(tree)
    idx = jax.random.randint(jax.random.key(2), (batch,), 0, cap)
    vals = jax.random.uniform(jax.random.key(3), (batch,))

    rows = {}

    def row(name, us, derived):
        emit(f"replay/{name}/cap={cap}/b={batch}", us, derived)
        rows[name] = {"us": us, "derived": str(derived)}

    sample_xla = jax.jit(sumtree.sample_with_mass)
    us = timeit(sample_xla, tree, u, iters=args.iters)
    row("sumtree_sample_xla", us, f"{batch / us:.1f}samples_per_us")
    us = timeit(lambda t, v: sumtree_sample_with_mass(t, v,
                                                      interpret=interpret),
                tree, u, iters=max(2, args.iters // (10 if interpret else 1)))
    row(f"sumtree_sample_pallas_{pallas_mode}", us,
        f"{batch / us:.2f}samples_per_us")

    wr_rebuild = jax.jit(sumtree.write_rebuild)
    us = timeit(wr_rebuild, tree, idx, vals, iters=args.iters)
    row("sumtree_write_rebuild_xla", us, "full_rebuild")
    wr_incr = jax.jit(sumtree.update)
    us_incr = timeit(wr_incr, tree, idx, vals, iters=args.iters)
    row("sumtree_update_incremental_xla", us_incr, "o_b_logc")
    us = timeit(lambda t, i, v: sumtree_update(t, i, v, interpret=interpret),
                tree, idx, vals,
                iters=max(2, args.iters // (10 if interpret else 1)))
    row(f"sumtree_update_pallas_{pallas_mode}", us, "o_b_logc")

    r = jax.random.normal(jax.random.key(4), (256, 64))
    g = jnp.full((256, 64), 0.99)
    ns = jax.jit(lambda r, g: from_trajectory(r, g, 3))
    us = timeit(ns, r, g, iters=args.iters)
    emit("replay/nstep_from_trajectory/lanes=256/T=64", us, "n=3")
    rows["nstep_from_trajectory"] = {"us": us, "derived": "n=3"}

    write_artifact("kernels", {
        "bench": "kernels",
        "unix_time": time.time(),
        "cpu_count": os.cpu_count(),
        "backend": jax.default_backend(),
        "pallas_mode": pallas_mode,
        "cap": cap,
        "batch": batch,
        "rows": rows,
    }, args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
