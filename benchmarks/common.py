"""Shared benchmark machinery: timed Ape-X runs at reduced scale + CSV rows.

Every benchmark maps to a paper table/figure and prints
``name,us_per_call,derived`` rows (derived = the figure's headline quantity).
Wall-clock absolute numbers are CPU-container artifacts; the *relative*
structure (scaling slopes, orderings) is what reproduces the paper's claims.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.core import apex

_BENCH_DIR = os.path.dirname(os.path.abspath(__file__))
_REPO_ROOT = os.path.dirname(_BENCH_DIR)
ARTIFACT_DIR = os.path.join(_BENCH_DIR, "artifacts")


def artifact_path(bench_name: str) -> str:
    """Default (stable) JSON artifact path for a benchmark."""
    return os.path.join(ARTIFACT_DIR, f"BENCH_{bench_name}.json")


def write_artifact(bench_name: str, payload: dict,
                   json_path: str | None = None) -> list[str]:
    """Write a benchmark's JSON result set to its artifact path(s).

    Always writes a repo-root ``BENCH_<name>.json`` twin alongside the
    ``benchmarks/artifacts/`` copy (or an explicit ``json_path``): the root
    copy is committed, so the perf trajectory accumulates in git history
    across PRs instead of evaporating with each CI run."""
    paths = [json_path or artifact_path(bench_name)]
    root_twin = os.path.join(_REPO_ROOT, f"BENCH_{bench_name}.json")
    if os.path.abspath(paths[0]) != root_twin:
        paths.append(root_twin)
    for path in paths:
        parent = os.path.dirname(path)
        if parent:  # bare filenames write to the cwd
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path}")
    return paths


def run_apex(cfg, preset, iters: int, seed: int = 0, warmup: int = 2):
    """Run a preset; returns dict of aggregates + us/iteration."""
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(cfg, preset.env, preset.agent,
                                          optimizer)
    state = init_fn(jax.random.key(seed))
    for _ in range(warmup):
        state, m = step_fn(state)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    returns = []
    for _ in range(iters):
        state, m = step_fn(state)
        r = float(m["mean_ep_return"])
        if not np.isnan(r):
            returns.append(r)
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    frames = float(state.frames)
    transitions_trained = int(state.learner_step) * cfg.batch_size
    return {
        "us_per_iter": 1e6 * dt / iters,
        "fps": frames / dt if dt > 0 else 0.0,   # approx: counts warmup frames too
        "frames": frames,
        "transitions_trained": transitions_trained,
        "final_return": float(np.mean(returns[-15:])) if returns else float("nan"),
        "mean_return": float(np.mean(returns)) if returns else float("nan"),
        "learner_steps": int(state.learner_step),
        "seconds": dt,
    }


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
