"""Benchmark suite entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring for
the claim it reproduces). The roofline rows are read from the dry-run
artifacts if present (run ``python -m repro.launch.dryrun --all`` first for
the full table).

  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1,...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

SUITES = {
    "table1": "benchmarks.bench_throughput",
    "fig3": "benchmarks.bench_dpg",
    "fig4": "benchmarks.bench_actor_scaling",
    "fig5": "benchmarks.bench_replay_capacity",
    "fig6": "benchmarks.bench_recency",
    "fig7": "benchmarks.bench_epsilon",
    "fig12": "benchmarks.bench_prioritization",
    "kernels": "benchmarks.bench_kernels",
    "roofline": "benchmarks.roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="comma-separated suite names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        mod_name = SUITES[name]
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
