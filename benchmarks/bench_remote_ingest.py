"""Remote ingest scaling — transitions/s into the replay gateway vs actor
*process* count.

The paper's premise (§3, after Gorila) is that experience generation scales
with actor count because actors are independent processes on independent
CPUs; the piece that must not become the new bottleneck is the actor→replay
ingest path (cf. Furukawa & Matsutani, In-Network Experience Sampling).
This bench measures that path end to end: N real actor processes (each
CPU-pinned, one-actor-per-core) run jitted ``act_phase`` rollouts and
stream ``ADD_BLOCK`` frames into a ``ReplayGateway`` → ``ReplayFabric``
(2 shards), with sampling gated off (min-fill unreachable) so the measured
quantity is pure ingest — serialize + transport + decode + shard-apply.
The proc sweep runs over TCP (``--transport``); a separate single-proc leg
repeats the measurement over the same-host shm ring transport and gates
that the ring path sustains the same offered load (>= 0.95x tcp 1-proc).

Methodology: *offered load*, not a machine race. Each actor paces itself
to a fixed block rate (``--actor-rate``, chosen well below one core's act
capacity and well below the gateway's single-connection ceiling), so N
actors offer exactly N times the load, and the measured *applied* rate
shows whether the ingest path sustains it. If the gateway serialized
connections, dropped into backpressure, or the shard owners couldn't keep
up, the applied rate would fall below the offer — that is the failure the
gate detects. Racing unpaced actors instead would gate on container speed:
on a noisy 2-core box the same workload's wall-clock rate varies >2x
between runs, drowning the scaling signal.

Per process-count, the windows open only after *every* actor has pushed a
warm threshold of blocks (child JAX compile excluded), and rates are read
from thread-safe fabric snapshots while hot. The acceptance bar: 2 actor
processes sustain >= 1.3x the applied transitions/s of 1 actor process
(``--check``).

Two single-proc comparison legs ride along: the same-host shm ring
transport (gate: >= 0.95x tcp 1-proc) and the pipelined ingest-staging
drain, where shard owners stage block k+1's H2D put while block k's add
runs (gate: >= --min-staged-ratio x the unstaged 1-proc rate — the
pipeline must sustain the same offered load).

Emitted rows (benchmarks/common.py CSV convention):
  remote_ingest/tps_procs{N}
  remote_ingest/speedup_2proc_vs_1proc
  remote_ingest/wire_mbps_procs{N}
  remote_ingest/tps_procs1_shm
  remote_ingest/tps_procs1_staged

JSON result set: ``benchmarks/artifacts/BENCH_remote_ingest.json`` plus the
committed repo-root twin ``BENCH_remote_ingest.json`` (perf trajectory).
"""

from __future__ import annotations

import argparse
import dataclasses
import multiprocessing
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

from benchmarks.common import emit, write_artifact  # noqa: E402
from repro.configs import apex_dqn  # noqa: E402
from repro.core import apex, replay as replay_lib  # noqa: E402
from repro.core.agents import DQNAgent  # noqa: E402
from repro.envs.synthetic import ChainWorld, batch_reset  # noqa: E402
from repro.models.qnetworks import DuelingDQN  # noqa: E402
from repro.net import ReplayGateway, RemoteActorSpec  # noqa: E402
from repro.net.actor_client import run_remote_actor  # noqa: E402
from repro.runtime import ParamStore, ReplayFabric, phases  # noqa: E402


def bench_preset(lanes: int = 64, rollout: int = 32,
                 hidden: int = 256) -> apex_dqn.ApexDQNPreset:
    """Realistic actor geometry: a mid-size policy net (real work per
    rollout, so pacing slack is genuine headroom, not idle spin) and
    ~2k-transition blocks of ~100 KB on the wire."""
    env = ChainWorld(length=16, max_steps=64)
    agent = DQNAgent(net=DuelingDQN(num_actions=env.num_actions,
                                    mlp_hidden=(hidden, hidden),
                                    head_hidden=hidden),
                     grad_clip=40.0)
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=16384, min_fill=512),
        lanes_per_shard=lanes, num_shards=1, rollout_len=rollout, n_step=3,
        batch_size=128, learner_steps_per_iter=1, param_sync_period=2,
        target_update_period=100, evict_interval=50,
        eps_base=0.4, eps_alpha=7.0)
    return apex_dqn.ApexDQNPreset(apex=cfg, env=env, agent=agent,
                                  learning_rate=1e-3)


def ingest_rate(preset, procs: int, seconds: float, warm_blocks: int = 3,
                shards: int = 2, quantize_obs: bool = False,
                warm_timeout: float = 300.0, windows: int = 3,
                gap_s: float = 0.5, actor_rate: float = 5.0,
                transport: str = "tcp", ingest_staging: bool = False) -> dict:
    """One measurement: spawn ``procs`` actor processes, wait until each
    has landed ``warm_blocks`` blocks (compile + connect excluded from the
    clock), then read applied transitions/s from fabric snapshots over
    ``windows`` back-to-back windows. Several windows per spawn amortize
    the child-compile cost and let the caller median away scheduler
    outliers (a 2-core container can starve one child for seconds)."""
    cfg = preset.apex
    # min-fill unreachable => shards never prefetch; pure ingest path. The
    # gate must stay unreachable for the *host-side counter* too: once
    # lifetime transitions_added crosses min_fill, every owner-loop pass
    # runs the jitted can_sample check (a device sync) — a parasitic,
    # ingest-proportional tax that would skew the windows. 2**40 lifetime
    # transitions cannot be ingested in any bench run.
    cfg = dataclasses.replace(
        cfg, num_shards=procs,
        replay=dataclasses.replace(cfg.replay, min_fill=1 << 40))
    _, obs = batch_reset(preset.env, jax.random.key(9), 1)
    item = phases.item_example(preset.env, obs, cfg.compress_obs)
    params = preset.agent.init(jax.random.key(0), obs[:1])

    fabric = ReplayFabric(cfg, item, num_shards=shards,
                          ingest_staging=ingest_staging).start()
    gateway = ReplayGateway(fabric, ParamStore(params)).start()
    ctx = multiprocessing.get_context("spawn")
    workers = []
    try:
        for j in range(procs):
            spec = RemoteActorSpec(
                cfg=cfg, env=preset.env, agent=preset.agent,
                host=gateway.host, port=gateway.port, actor_id=j, seed=7,
                quantize_obs=quantize_obs, transport=transport,
                # one actor = one CPU core (paper §3): unpinned, a single
                # actor's XLA intra-op pool can swallow every core and the
                # 1-proc baseline measures the machine, not an actor
                pin_cpu=j,
                # offered-load pacing (see module docstring)
                target_blocks_per_s=actor_rate,
                param_sync_period=1_000_000)  # ingest only: no pull traffic
            p = ctx.Process(target=run_remote_actor, args=(spec,),
                            daemon=True, name=f"bench-actor-{j}")
            p.start()
            workers.append(p)

        # The window opens only once EVERY actor is hot (per-connection
        # counts, not the total: one fast actor must not start the clock
        # while another is still compiling its jitted rollout).
        def all_warm():
            counts = gateway.connection_block_counts()
            return (len(counts) == procs
                    and min(counts, default=0) >= warm_blocks)

        deadline = time.monotonic() + warm_timeout
        while not all_warm() and time.monotonic() < deadline:
            time.sleep(0.05)
        if not all_warm():
            raise RuntimeError(
                "actors never warmed up (per-connection blocks: "
                f"{gateway.connection_block_counts()})")

        window_tps, window_mbps = [], []
        for w in range(windows):
            if w:
                time.sleep(gap_s)
            snap0, g0 = fabric.snapshot(), gateway.snapshot()
            t0 = time.perf_counter()
            time.sleep(seconds)
            snap1, g1 = fabric.snapshot(), gateway.snapshot()
            dt = time.perf_counter() - t0
            applied = snap1.transitions_added - snap0.transitions_added
            window_tps.append(applied / dt if dt > 0 else 0.0)
            window_mbps.append((g1.bytes_in - g0.bytes_in) / dt / 1e6
                               if dt > 0 else 0.0)
        end_snap = fabric.snapshot()
    finally:
        gateway.stop()
        for p in workers:
            p.join(timeout=20.0)
        for p in workers:
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)
        fabric.stop()
    if gateway.error is not None:
        raise RuntimeError("gateway died mid-bench") from gateway.error
    if fabric.error is not None:
        raise RuntimeError("fabric died mid-bench") from fabric.error
    return {"mode": "ingest", "procs": procs, "actor_rate": actor_rate,
            "transport": transport,
            "shm_connections": gateway.snapshot().shm_connections,
            "seconds": seconds * len(window_tps),
            "window_tps": window_tps, "window_mbps": window_mbps,
            "tps": statistics.median(window_tps),
            "wire_mbps": statistics.median(window_mbps),
            "quantize_obs": quantize_obs,
            "ingest_staging": ingest_staging,
            "blocks_staged": end_snap.blocks_staged,
            "h2d_us": end_snap.h2d_us}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: one round, short windows")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless 2-proc tps >= 1.3x 1-proc")
    ap.add_argument("--procs", default="1,2",
                    help="comma-separated actor-process counts")
    ap.add_argument("--seconds", type=float, default=None,
                    help="seconds per measurement window")
    ap.add_argument("--windows", type=int, default=3,
                    help="back-to-back windows per spawned actor set")
    ap.add_argument("--rounds", type=int, default=None,
                    help="interleaved spawn rounds per proc count")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--actor-rate", type=float, default=5.0,
                    help="offered load per actor, blocks/s (each block is "
                         "lanes * (rollout - n_step + 1) transitions)")
    ap.add_argument("--quantize-obs", action="store_true",
                    help="actors ship obs via the replay codec")
    ap.add_argument("--transport", choices=("tcp", "shm", "auto"),
                    default="tcp",
                    help="transport for the proc-sweep rows (tcp keeps the "
                         "sweep measuring the socket path; the shm leg is "
                         "measured separately)")
    ap.add_argument("--skip-shm-leg", action="store_true",
                    help="skip the single-proc shm comparison row")
    ap.add_argument("--skip-staged-leg", action="store_true",
                    help="skip the single-proc ingest-staging row")
    ap.add_argument("--min-staged-ratio", type=float, default=0.99,
                    help="gate: staged ingest tps vs the unstaged 1-proc "
                         "row (>= 1.0x at measurement resolution — the "
                         "pipeline must not cost throughput)")
    ap.add_argument("--json", default=None,
                    help="override the artifact path")
    args = ap.parse_args()

    proc_counts = [int(s) for s in args.procs.split(",") if s]
    seconds = args.seconds or (4.0 if args.smoke else 6.0)
    rounds = args.rounds or (1 if args.smoke else 2)
    preset = bench_preset()

    # Interleaved spawn rounds (1-proc set, 2-proc set, 1-proc, ...): CPU
    # containers drift over tens of seconds, so back-to-back blocks per
    # config would compare different machine states. The reported number is
    # the per-config median over every window of every round.
    all_tps: dict[int, list[float]] = {n: [] for n in proc_counts}
    all_mbps: dict[int, list[float]] = {n: [] for n in proc_counts}
    rows = []
    for r in range(rounds):
        for n in proc_counts:
            row = ingest_rate(preset, n, seconds, shards=args.shards,
                              quantize_obs=args.quantize_obs,
                              windows=args.windows,
                              actor_rate=args.actor_rate,
                              transport=args.transport)
            rows.append(row)
            all_tps[n].extend(row["window_tps"])
            all_mbps[n].extend(row["window_mbps"])
            emit(f"remote_ingest/tps_procs{n}_round{r}",
                 row["seconds"] * 1e6, f"{row['tps']:.0f}")

    # Same-host ring-arena leg: one paced actor over --transport shm. At
    # offered load the applied rate should match the socket path's (the gate
    # below); a shm-path backpressure or teardown bug shows up as applied <
    # offered, exactly like a gateway stall would on the tcp rows.
    shm_tps = None
    if not args.skip_shm_leg:
        row = ingest_rate(preset, 1, seconds, shards=args.shards,
                          quantize_obs=args.quantize_obs,
                          windows=args.windows,
                          actor_rate=args.actor_rate, transport="shm")
        rows.append(row)
        shm_tps = row["tps"]
        emit("remote_ingest/tps_procs1_shm", row["seconds"] * 1e6,
             f"{shm_tps:.0f}")
        emit("remote_ingest/wire_mbps_procs1_shm", row["seconds"] * 1e6,
             f"{row['wire_mbps']:.1f}")

    # Pipelined ingest-staging leg: one paced actor, shard owners staging
    # block k+1's H2D put while block k's add runs. At offered load the
    # applied rate must match the unstaged 1-proc row (the pipeline adds no
    # serial work; on a CPU host the stager passes through, so this leg
    # gates the stage-ahead *ordering* — a pipelining bug that held or
    # dropped a block would show up as applied < offered). On accelerator
    # hosts the same leg records h2d_us/blocks_staged for the overlap.
    staged_tps = None
    if not args.skip_staged_leg:
        row = ingest_rate(preset, 1, seconds, shards=args.shards,
                          quantize_obs=args.quantize_obs,
                          windows=args.windows,
                          actor_rate=args.actor_rate,
                          transport=args.transport, ingest_staging=True)
        rows.append(row)
        staged_tps = row["tps"]
        emit("remote_ingest/tps_procs1_staged", row["seconds"] * 1e6,
             f"{staged_tps:.0f}")

    medians = {n: statistics.median(all_tps[n]) for n in proc_counts}
    for n in proc_counts:
        emit(f"remote_ingest/tps_procs{n}",
             seconds * rounds * args.windows * 1e6, f"{medians[n]:.0f}")
        emit(f"remote_ingest/wire_mbps_procs{n}",
             seconds * rounds * args.windows * 1e6,
             f"{statistics.median(all_mbps[n]):.1f}")

    speedup = None
    if 1 in medians and 2 in medians:
        speedup = medians[2] / max(medians[1], 1e-9)
        emit("remote_ingest/speedup_2proc_vs_1proc", seconds * 1e6,
             f"{speedup:.2f}")

    write_artifact("remote_ingest", {
        "bench": "remote_ingest",
        "unix_time": time.time(),
        "cpu_count": os.cpu_count(),
        "smoke": args.smoke,
        "shards": args.shards,
        "seconds_per_window": seconds,
        "windows_per_round": args.windows,
        "rounds": rounds,
        "actor_rate_blocks_per_s": args.actor_rate,
        "quantize_obs": args.quantize_obs,
        "transport": args.transport,
        "speedup_2proc_vs_1proc": speedup,
        "shm_tps_procs1": shm_tps,
        "staged_tps_procs1": staged_tps,
        "staged_ratio": (staged_tps / max(medians[1], 1e-9)
                         if staged_tps is not None and 1 in medians
                         else None),
        "min_staged_ratio": args.min_staged_ratio,
        "median_tps": {str(n): medians[n] for n in proc_counts},
        "rows": rows,
    }, args.json)

    if args.check:
        if speedup is None:
            print("FAIL: --check needs proc counts 1 and 2", file=sys.stderr)
            return 1
        if speedup < 1.3:
            print(f"FAIL: 2 actor processes only {speedup:.2f}x the 1-proc "
                  f"ingest rate (need >= 1.3x)", file=sys.stderr)
            return 1
        if shm_tps is not None and 1 in medians:
            shm_ratio = shm_tps / max(medians[1], 1e-9)
            if shm_ratio < 0.95:
                print(f"FAIL: shm ingest only {shm_ratio:.2f}x the tcp "
                      f"1-proc rate (need >= 0.95x — the ring path must "
                      f"sustain the same offered load)", file=sys.stderr)
                return 1
        if staged_tps is not None and 1 in medians:
            staged_ratio = staged_tps / max(medians[1], 1e-9)
            if staged_ratio < args.min_staged_ratio:
                print(f"FAIL: staged ingest only {staged_ratio:.2f}x the "
                      f"unstaged 1-proc rate (need >= "
                      f"{args.min_staged_ratio:.2f}x — the pipelined drain "
                      f"must sustain the same offered load)",
                      file=sys.stderr)
                return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
