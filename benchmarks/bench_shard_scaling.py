"""Replay-fabric shard scaling — generate-side transitions/s vs shard count.

The paper scales by sharding the central replay memory (§3): ingest
bandwidth grows with the number of replay shards because each shard's owner
thread applies its own adds. This bench measures that axis directly:

* ``gen`` rows — P actor threads push prebuilt (realistic, ``act_phase``
  -shaped) ``TransitionBlock``s into a ``ReplayFabric`` for a fixed window,
  with sampling gated off (min-fill unreachable), so the measured rate is the
  fabric's pure ingest bandwidth. A single shard serializes every add behind
  one owner thread; N shards apply adds concurrently — the scaling headroom
  the acceptance bar targets (2 shards >= 1.15x one shard at >= 4 actors).
* ``e2e`` rows (skipped in ``--smoke``) — full ``run_async`` training at each
  shard count, reporting the paper's §4.1 generate/consume split.

Emitted rows (benchmarks/common.py CSV convention):
  shard_scaling/gen_tps_shards{N}_actors{P}
  shard_scaling/gen_speedup_2shard_vs_1shard
  shard_scaling/e2e_{actor,learner}_tps_shards{N}   (not in --smoke)

The full result set is also written as JSON to a *stable* artifact path
(``--json``, default ``benchmarks/artifacts/BENCH_shard_scaling.json``) plus
a repo-root ``BENCH_shard_scaling.json`` twin that is committed, so the perf
trajectory accumulates in git history across PRs. ``--check`` exits nonzero
when the 2-shard generate rate does not reach 1.15x the 1-shard fabric.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import statistics
import sys
import threading
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.common import emit, write_artifact  # noqa: E402
from repro.configs import apex_dqn  # noqa: E402
from repro.core import apex, replay as replay_lib  # noqa: E402
from repro.core.agents import DQNAgent  # noqa: E402
from repro.envs.synthetic import ChainWorld, batch_reset  # noqa: E402
from repro.models.qnetworks import DuelingDQN  # noqa: E402
from repro.runtime import (AsyncConfig, ReplayFabric, phases,  # noqa: E402
                           run_async)

DEFAULT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "artifacts", "BENCH_shard_scaling.json")


def bench_preset(lanes: int = 128, rollout: int = 32) -> apex_dqn.ApexDQNPreset:
    """Ingest-heavy geometry: small net (cheap acting), big blocks (the
    per-transition cost is dominated by the replay-side sum-tree/storage
    writes the fabric is supposed to parallelize)."""
    env = ChainWorld(length=16, max_steps=64)
    agent = DQNAgent(net=DuelingDQN(num_actions=env.num_actions,
                                    mlp_hidden=(32,), head_hidden=32),
                     grad_clip=40.0)
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=8192, min_fill=512),
        lanes_per_shard=lanes, num_shards=1, rollout_len=rollout, n_step=3,
        batch_size=128, learner_steps_per_iter=1, param_sync_period=2,
        target_update_period=100, evict_interval=50,
        eps_base=0.4, eps_alpha=7.0)
    return apex_dqn.ApexDQNPreset(apex=cfg, env=env, agent=agent,
                                  learning_rate=1e-3)


def make_block(cfg, env, agent, seed: int = 0) -> phases.TransitionBlock:
    """One realistic act_phase output block (shapes/dtypes as in training)."""
    env_state, obs = batch_reset(env, jax.random.key(seed),
                                 cfg.lanes_per_shard)
    aslice = phases.ActorSlice(
        env_state=env_state, obs=obs,
        ep_return=jnp.zeros((cfg.lanes_per_shard,), jnp.float32),
        rng=jax.random.fold_in(jax.random.key(seed), 1),
        frames=jnp.zeros((), jnp.int32))
    params = agent.init(jax.random.key(seed + 1), obs[:1])
    _, block, _ = jax.jit(lambda p, sl: phases.act_phase(
        cfg, env, agent, p, sl, 0))(params, aslice)
    return jax.block_until_ready(block)


def _ingest_window(fabric, block, pushers: int, seconds: float) -> float:
    """One measurement window: saturate the fabric with pusher threads for
    ``seconds`` and return the applied transitions/s (read via thread-safe
    fabric snapshots while hot)."""
    stop = threading.Event()

    def push() -> None:
        while not stop.is_set():
            fabric.add(block, timeout=0.05)

    threads = [threading.Thread(target=push, daemon=True,
                                name=f"pusher-{i}") for i in range(pushers)]
    for th in threads:
        th.start()
    snap0 = fabric.snapshot()
    t0 = time.perf_counter()
    time.sleep(seconds)
    snap1 = fabric.snapshot()
    dt = time.perf_counter() - t0
    stop.set()
    for th in threads:
        th.join()
    applied = snap1.transitions_added - snap0.transitions_added
    return applied / dt if dt > 0 else 0.0


def gen_rates(preset, shard_counts: list[int], pushers: int, seconds: float,
              rounds: int = 5) -> list[dict]:
    """Pure ingest bandwidth per shard count: sampling is gated off
    (min-fill unreachable) so every owner-thread cycle is an add apply.

    Shard counts are measured in *interleaved rounds* (1-shard window,
    2-shard window, 1-shard window, ...) and reported as the per-config
    median: CPU containers drift over tens of seconds (frequency scaling,
    noisy neighbours), so back-to-back blocks of windows per config would
    compare different machine states, and a max would reward the burstier
    configuration. Each round builds a fresh fabric but reuses the
    per-config compiled ``ShardFns``, so rebuilds cost threads, not XLA
    compiles."""
    cfg = preset.apex
    # min-fill unreachable => shards never prefetch; pure add path.
    cfg = dataclasses.replace(
        cfg, replay=dataclasses.replace(cfg.replay,
                                        min_fill=cfg.replay.capacity * 4))
    block = make_block(cfg, preset.env, preset.agent)
    _, obs = batch_reset(preset.env, jax.random.key(9), 1)
    item = phases.item_example(preset.env, obs, cfg.compress_obs)

    def fresh_fabric(n, fns, seed):
        fabric = ReplayFabric(cfg, item, num_shards=n, add_queue_depth=4,
                              seed=seed, fns=fns).start()
        for _ in range(n * 2):  # pre-fill so the window is steady-state
            fabric.add(block, timeout=1.0)
        deadline = time.monotonic() + 2.0
        while (fabric.snapshot().blocks_added < n * 2
               and time.monotonic() < deadline):
            time.sleep(0.005)
        return fabric

    fns = {}
    for n in shard_counts:  # compile each geometry once before the clock
        fabric = fresh_fabric(n, None, seed=7)
        fns[n] = fabric.fns
        fabric.stop()

    windows: dict[int, list[float]] = {n: [] for n in shard_counts}
    for r in range(rounds):
        for n in shard_counts:
            fabric = fresh_fabric(n, fns[n], seed=100 + r)
            windows[n].append(_ingest_window(fabric, block, pushers, seconds))
            fabric.stop()
            if fabric.error is not None:
                raise RuntimeError("fabric died mid-bench") from fabric.error
    return [{"mode": "gen", "shards": n, "actors": pushers,
             "seconds": seconds * rounds, "window_tps": windows[n],
             "tps": statistics.median(windows[n])}
            for n in shard_counts]


def e2e_rate(preset, shards: int, actors: int, learner_steps: int) -> dict:
    acfg = AsyncConfig(actor_threads=actors, replay_shards=shards,
                       total_learner_steps=learner_steps, max_seconds=600.0)
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    s = res.stats
    return {"mode": "e2e", "shards": shards, "actors": actors,
            "seconds": s["seconds"], "actor_tps": s["actor_tps"],
            "learner_tps": s["learner_tps"],
            "ratio": s["generate_consume_ratio"]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: short ingest windows, no e2e rows")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless 2-shard gen tps >= 1.15x 1-shard")
    ap.add_argument("--shards", default="1,2",
                    help="comma-separated shard counts")
    ap.add_argument("--actors", type=int, default=4,
                    help="pusher/actor threads (acceptance bar: >= 4)")
    ap.add_argument("--seconds", type=float, default=None,
                    help="ingest measurement window per shard count")
    ap.add_argument("--json", default=DEFAULT_JSON,
                    help="stable artifact path for the JSON result set")
    args = ap.parse_args()

    shard_counts = [int(s) for s in args.shards.split(",") if s]
    seconds = args.seconds or (1.5 if args.smoke else 3.0)
    rounds = 5 if args.smoke else 9
    preset = bench_preset()

    rows = gen_rates(preset, shard_counts, args.actors, seconds,
                     rounds=rounds)
    for r in rows:
        emit(f"shard_scaling/gen_tps_shards{r['shards']}_actors"
             f"{args.actors}", r["seconds"] * 1e6, f"{r['tps']:.0f}")

    by_shards = {r["shards"]: r for r in rows if r["mode"] == "gen"}
    speedup = None
    if 1 in by_shards and 2 in by_shards:
        speedup = by_shards[2]["tps"] / max(by_shards[1]["tps"], 1e-9)
        emit("shard_scaling/gen_speedup_2shard_vs_1shard",
             seconds * 1e6, f"{speedup:.2f}")

    if not args.smoke:
        for n in shard_counts:
            r = e2e_rate(preset, n, args.actors, learner_steps=60)
            rows.append(r)
            emit(f"shard_scaling/e2e_actor_tps_shards{n}",
                 r["seconds"] * 1e6, f"{r['actor_tps']:.0f}")
            emit(f"shard_scaling/e2e_learner_tps_shards{n}",
                 r["seconds"] * 1e6, f"{r['learner_tps']:.0f}")

    payload = {
        "bench": "shard_scaling",
        "unix_time": time.time(),
        "cpu_count": os.cpu_count(),
        "smoke": args.smoke,
        "actors": args.actors,
        "seconds_per_window": seconds,
        "gen_speedup_2shard_vs_1shard": speedup,
        "rows": rows,
    }
    write_artifact("shard_scaling", payload, args.json)

    if args.check:
        if speedup is None:
            print("FAIL: --check needs shard counts 1 and 2", file=sys.stderr)
            return 1
        if speedup < 1.15:
            print(f"FAIL: 2-shard gen tps only {speedup:.2f}x the 1-shard "
                  f"fabric (need >= 1.15x)", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
