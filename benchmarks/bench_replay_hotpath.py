"""Replay mutation hot path — incremental sum-tree updates vs full rebuilds.

The paper fixed the replay server's contention by batching all request types
(§Contention / Alg. 2); our TPU-native analogue is making each batched
mutation cheap. Schaul et al.'s prioritized replay is O(log C) per update by
design, and this PR's ``sumtree.update`` restores that bound for batched
writes: O(B * log C) incremental propagation instead of the O(C) full
level-rebuild ``sumtree.write`` used to pay. This bench gates the win and
tracks the satellites around it:

* ``write_speedup_incremental_vs_rebuild`` — THE GATE (``--check``): at the
  acceptance geometry (capacity 2^17, B = 64 write-back lanes) the
  incremental write must be >= 3x faster than the rebuild-based write.
* ``ingest_fused`` rows — THE SECOND GATE (``--check``): one dispatch for
  the whole add (priority init + storage scatter + tree repair, the fused
  Pallas ingest op on TPU / one fused XLA graph elsewhere) must be >=
  1.3x the three-dispatch alloc→store→``sumtree.write`` chain it replaced.
* ``sample_fused`` rows — ``sample_with_mass`` is backend-dispatched per
  path: on XLA it *is* the descent + leaf gather (bitwise, and within
  noise of it — the earlier committed 0.69x row was the fused lowering
  running on the wrong backend), on the Pallas backends the descent emits
  the mass for free.
* ``add_alloc`` row — free-slot compaction via masked cumsum (the O(C log C)
  argsort is timed inline as the reference it replaced).
* ``evict_fifo`` row — direct kill-mask + rebuild (the permuted index
  materialization it replaced is timed inline as reference).
* ``writeback_donated`` rows — a ShardFns-style jitted priority write-back
  with and without ``ReplayState`` donation (donation lets XLA update the
  storage pytree in place instead of copying it every call).

Absolute wall numbers are CPU-container artifacts; the ratios are the
reproducible claims. Results land in ``BENCH_replay_hotpath.json``
(``benchmarks/artifacts/`` + committed repo-root twin).
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.common import emit, write_artifact  # noqa: E402
from repro.core import priority as prio  # noqa: E402
from repro.core import replay as replay_lib, sumtree  # noqa: E402
from repro.runtime import make_shard_fns, phases  # noqa: E402
from repro.core import apex  # noqa: E402


def timeit(fn, *args, iters=50, warmup=2):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / iters


def _alloc_argsort_idx(leaves_live: jax.Array, batch: int) -> jax.Array:
    """The free-slot selection ``add_alloc`` used before this PR: a full
    O(C log C) argsort pulling free slots to the front. Kept here as the
    timing reference for the masked-cumsum compaction."""
    return jnp.argsort(leaves_live, stable=True)[:batch]


# the compaction inside add_alloc (O(C)) — the live code, not a copy
_alloc_cumsum_idx = replay_lib.free_slot_idx


def _evict_permuted(tree: jax.Array, write_pos, size, soft_cap: int):
    """Pre-PR evict_fifo body: materialize the FIFO-ordered index permutation
    and push all C lanes through a tree write."""
    cap = sumtree.capacity(tree)
    excess = jnp.maximum(size - soft_cap, 0)
    oldest = (write_pos - size) % cap
    offs = jnp.arange(cap, dtype=jnp.int32)
    idx = (oldest + offs) % cap
    kill = offs < excess
    old = sumtree.leaves(tree)[idx]
    return sumtree.write_rebuild(tree, idx, jnp.where(kill, 0.0, old))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer timing iterations")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless incremental write >= --min-speedup "
                         "x the rebuild write at the acceptance geometry")
    ap.add_argument("--cap", type=int, default=1 << 17,
                    help="sum-tree capacity (acceptance: 2^17)")
    ap.add_argument("--batch", type=int, default=64,
                    help="write-back batch B (acceptance: 64)")
    ap.add_argument("--min-speedup", type=float, default=3.0)
    ap.add_argument("--min-ingest-speedup", type=float, default=1.3,
                    help="gate: fused one-dispatch ingest vs the "
                         "three-dispatch alloc→store→write chain")
    ap.add_argument("--json", default=None,
                    help="stable artifact path for the JSON result set")
    args = ap.parse_args()
    cap, batch = args.cap, args.batch
    iters = 20 if args.smoke else 100

    leaves = jax.random.uniform(jax.random.key(0), (cap,)) + 0.01
    tree = sumtree.rebuild(leaves)
    idx = jax.random.randint(jax.random.key(1), (batch,), 0, cap)
    vals = jax.random.uniform(jax.random.key(2), (batch,)) + 0.01
    u = jax.random.uniform(jax.random.key(3), (batch,)) * sumtree.total(tree)

    rows = {}

    def row(name, us, derived):
        emit(f"replay_hotpath/{name}", us, derived)
        rows[name] = {"us": us, "derived": str(derived)}

    # -- the gate: incremental vs rebuild write ---------------------------
    # Timed as the replay shard actually runs them: a chain of writes
    # threading the tree through. The incremental path donates the incoming
    # tree (as ``ShardFns`` donates the whole ``ReplayState``), so each of
    # the log2(C) levels updates in place; the rebuild reference is the
    # pre-PR hot path — no donation, full level reconstruction per call.
    wr_rebuild = jax.jit(sumtree.write_rebuild)
    wr_incr = jax.jit(sumtree.update, donate_argnums=(0,))

    def chain(fn, iters):
        t = jnp.array(tree)  # private copy: the chain may donate it away
        for _ in range(2):
            t = fn(t, idx, vals)
        jax.block_until_ready(t)
        t0 = time.perf_counter()
        for _ in range(iters):
            t = fn(t, idx, vals)
        jax.block_until_ready(t)
        return 1e6 * (time.perf_counter() - t0) / iters

    us_rebuild = chain(wr_rebuild, iters)
    us_incr = chain(wr_incr, iters)
    speedup = us_rebuild / max(us_incr, 1e-9)
    row(f"write_rebuild_cap{cap}_b{batch}", us_rebuild, "o_c")
    row(f"write_incremental_cap{cap}_b{batch}", us_incr, "o_b_logc_donated")
    row("write_speedup_incremental_vs_rebuild", us_incr, f"{speedup:.2f}")

    # -- sample+mass: backend-dispatched per path -------------------------
    # ``sample_with_mass`` now picks its form per backend: the explicit
    # two-gather graph on XLA (CPU/GPU — the fused lowering regressed to
    # 0.69x there), the mass-emitting descent kernel on pallas/interpret.
    # Both rows therefore time the *dispatched* op against the explicit
    # two-gather reference; on CPU they are the same graph and the ratio
    # must sit at ~1.0x. Interleaved min-of-rounds keeps the rows stable
    # against CPU frequency drift.
    two_gather = jax.jit(
        lambda t, v: (sumtree.sample(t, v),
                      sumtree.leaves(t)[sumtree.sample(t, v)]))
    fused = jax.jit(sumtree.sample_with_mass)
    pairs = [(timeit(two_gather, tree, u, iters=iters),
              timeit(fused, tree, u, iters=iters)) for _ in range(5)]
    us_two = min(p[0] for p in pairs)
    us_fused = min(p[1] for p in pairs)
    row(f"sample_two_gather_cap{cap}_b{batch}", us_two, "descent+gather")
    row(f"sample_dispatched_cap{cap}_b{batch}", us_fused,
        f"{us_two / max(us_fused, 1e-9):.2f}x_{sumtree.backend()}")

    # -- add_alloc free-slot compaction -----------------------------------
    live = leaves > jnp.median(leaves)  # ~half the slots free
    argsort_idx = jax.jit(_alloc_argsort_idx, static_argnums=1)
    cumsum_idx = jax.jit(_alloc_cumsum_idx, static_argnums=1)
    us_sort = timeit(lambda lv: argsort_idx(lv, batch), live, iters=iters)
    us_cs = timeit(lambda lv: cumsum_idx(lv, batch), live, iters=iters)
    row(f"alloc_argsort_cap{cap}", us_sort, "o_c_logc_reference")
    row(f"alloc_cumsum_cap{cap}", us_cs,
        f"{us_sort / max(us_cs, 1e-9):.2f}x")

    # -- evict_fifo: kill mask vs permuted index write --------------------
    soft = (cap // 8) * 7
    rcfg = replay_lib.ReplayConfig(capacity=cap, min_fill=1)
    state = replay_lib.ReplayState(
        storage={}, tree=tree,
        write_pos=jnp.asarray(0, jnp.int32),
        size=jnp.asarray(cap, jnp.int32),
        total_added=jnp.asarray(cap, jnp.int32))
    ev_new = jax.jit(lambda st: replay_lib.evict_fifo(rcfg, st).tree)
    ev_old = jax.jit(lambda t: _evict_permuted(
        t, jnp.asarray(0, jnp.int32), jnp.asarray(cap, jnp.int32), soft))
    us_ev_new = timeit(ev_new, state, iters=max(4, iters // 4))
    us_ev_old = timeit(ev_old, tree, iters=max(4, iters // 4))
    row(f"evict_fifo_permuted_cap{cap}", us_ev_old, "reference")
    row(f"evict_fifo_masked_cap{cap}", us_ev_new,
        f"{us_ev_old / max(us_ev_new, 1e-9):.2f}x")

    # -- ShardFns add: donated vs copying ---------------------------------
    # The add op scatters a transition block into the storage pytree; with
    # the ``ReplayState`` donated, XLA updates the (multi-MB) storage
    # buffers in place, while the non-donated reference must copy every
    # buffer it writes each call. (Priority write-back leaves storage
    # untouched — unchanged pytree leaves alias through jit — so ``add`` is
    # where donation pays.)
    add_cap, obs_dim, add_lanes = 4096, 64, 128
    wcfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=add_cap, min_fill=1),
        lanes_per_shard=8, rollout_len=8, n_step=3, batch_size=batch,
        evict_interval=10_000)
    item = {"obs": jnp.zeros((obs_dim,), jnp.float32),
            "action": jnp.zeros((), jnp.int32),
            "returns": jnp.zeros(()), "discount_n": jnp.zeros(()),
            "next_obs": jnp.zeros((obs_dim,), jnp.float32)}
    block = phases.TransitionBlock(
        items={"obs": jnp.ones((add_lanes, obs_dim), jnp.float32),
               "action": jnp.zeros((add_lanes,), jnp.int32),
               "returns": jnp.ones((add_lanes,)),
               "discount_n": jnp.full((add_lanes,), 0.99),
               "next_obs": jnp.ones((add_lanes, obs_dim), jnp.float32)},
        priorities=jax.random.uniform(jax.random.key(4), (add_lanes,)) + 0.01)

    fns = make_shard_fns(wcfg, batch)  # donated state (this PR)
    plain_add = jax.jit(lambda st, b: phases.replay_add(wcfg, st, b))

    def run_add(fn):
        st = replay_lib.init(wcfg.replay, item)
        for _ in range(iters):
            st = fn(st, block)
        return jax.block_until_ready(st.tree)

    run_add(fns.add), run_add(plain_add)  # compile both before the clock
    t0 = time.perf_counter(); run_add(fns.add)
    us_don = 1e6 * (time.perf_counter() - t0) / iters
    t0 = time.perf_counter(); run_add(plain_add)
    us_cp = 1e6 * (time.perf_counter() - t0) / iters
    row(f"add_copying_cap{add_cap}_obs{obs_dim}", us_cp, "reference")
    row(f"add_donated_cap{add_cap}_obs{obs_dim}", us_don,
        f"{us_cp / max(us_don, 1e-9):.2f}x")

    # -- fused ingest: one dispatch vs the alloc→store→write chain --------
    # The second gate. Reference is the replaced chain *as it ran*: three
    # separate device dispatches — (1) index/mask/leaf prep, (2) storage
    # scatter, (3) tree write — composed eagerly like every other
    # reference row here (no cross-call donation: a chain of independent
    # jits cannot update the storage pytree in place, so each scatter
    # copies the buffers it touches). The fused side is the live code:
    # ``add_fifo`` routed through ``_ingest`` — the single Pallas ingest
    # kernel on TPU (one VMEM round-trip), one fused XLA graph with the
    # state donated elsewhere. One dispatch + in-place storage is
    # precisely the fused op's claim; the donation-only share of the win
    # is tracked separately by the ``add_donated`` row above.
    rcfg_add = wcfg.replay
    offs = jnp.arange(add_lanes, dtype=jnp.int32)

    @jax.jit
    def ing_prep(tr, pos, pr):
        idx = (pos + offs) % add_cap
        applied = offs < add_lanes
        leaf = jnp.where(applied, prio.to_leaf(pr, rcfg_add.alpha),
                         sumtree.leaves(tr)[idx])
        return idx, applied, leaf

    @jax.jit
    def ing_store(storage, items, idx, applied):
        def scat(buf, x):
            m = applied.reshape(applied.shape + (1,) * (buf.ndim - 1))
            return buf.at[idx].set(jnp.where(m, x.astype(buf.dtype),
                                             buf[idx]))
        return jax.tree.map(scat, storage, items)

    ing_write = jax.jit(sumtree.update)
    ing_fused = jax.jit(
        lambda st, it, pr: replay_lib.add_fifo(rcfg_add, st, it, pr),
        donate_argnums=(0,))

    def run_three(n):
        st = replay_lib.init(rcfg_add, item)
        storage, tr = st.storage, st.tree
        pos = jnp.asarray(0, jnp.int32)
        t0 = time.perf_counter()
        for _ in range(n):
            idx2, app2, leaf2 = ing_prep(tr, pos, block.priorities)
            storage = ing_store(storage, block.items, idx2, app2)
            tr = ing_write(tr, idx2, leaf2)
        jax.block_until_ready(tr)
        return 1e6 * (time.perf_counter() - t0) / n

    def run_fused(n):
        st = replay_lib.init(rcfg_add, item)
        t0 = time.perf_counter()
        for _ in range(n):
            st = ing_fused(st, block.items, block.priorities)
        jax.block_until_ready(st.tree)
        return 1e6 * (time.perf_counter() - t0) / n

    run_three(2), run_fused(2)  # compile both before the clock
    ing_pairs = [(run_three(iters), run_fused(iters)) for _ in range(3)]
    us_three = min(p[0] for p in ing_pairs)
    us_fused_add = min(p[1] for p in ing_pairs)
    ingest_speedup = us_three / max(us_fused_add, 1e-9)
    row(f"ingest_three_dispatch_cap{add_cap}_lanes{add_lanes}", us_three,
        "reference")
    row(f"ingest_fused_cap{add_cap}_lanes{add_lanes}", us_fused_add,
        f"{ingest_speedup:.2f}x_{sumtree.hot_backend(add_cap)}")
    row("ingest_speedup_fused_vs_three_dispatch", us_fused_add,
        f"{ingest_speedup:.2f}")

    write_artifact("replay_hotpath", {
        "bench": "replay_hotpath",
        "unix_time": time.time(),
        "cpu_count": os.cpu_count(),
        "backend": jax.default_backend(),
        "smoke": args.smoke,
        "cap": cap,
        "batch": batch,
        "write_speedup_incremental_vs_rebuild": speedup,
        "min_speedup": args.min_speedup,
        "ingest_speedup_fused_vs_three_dispatch": ingest_speedup,
        "min_ingest_speedup": args.min_ingest_speedup,
        "rows": rows,
    }, args.json)

    if args.check:
        failed = False
        if speedup < args.min_speedup:
            print(f"FAIL: incremental write only {speedup:.2f}x the "
                  f"full-rebuild write at cap={cap} B={batch} (need >= "
                  f"{args.min_speedup:.1f}x)", file=sys.stderr)
            failed = True
        if ingest_speedup < args.min_ingest_speedup:
            print(f"FAIL: fused ingest only {ingest_speedup:.2f}x the "
                  f"three-dispatch chain at cap={add_cap} "
                  f"lanes={add_lanes} (need >= "
                  f"{args.min_ingest_speedup:.1f}x)", file=sys.stderr)
            failed = True
        if failed:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
