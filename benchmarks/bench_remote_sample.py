"""Sample-plane throughput — learner consume rate by transport and staging.

The paper's learner "samples, computes, and updates priorities" against a
replay memory that §3 allows to live on other machines; this bench measures
that learner↔replay link end to end through the ``SampleSource`` protocol
(``repro.runtime.sources``), at a compute-bound geometry (mid-size net, fat
fp32 observations) where the question is how much of the sample path a
transport lets the learn step hide:

* ``local``         — ``LocalFabricSource``: pop a prefetched device batch.
* ``remote``        — ``RemoteFabricSource`` over a loopback
  ``ReplayGateway`` with ``transport="tcp"``: strict request/reply per
  batch, so the socket round trip, frame encode/decode, and the batch's
  host→device move are *serial* with learner compute. This is the honest
  cost of cutting the learner↔replay boundary at the wire.
* ``remote_shm``    — the same request/reply protocol with
  ``transport="shm"``: batches travel through the mmap'd ring arena
  (one write into the ring, one copy out), only control frames touch the
  socket. The same-host fast path ``--transport auto`` picks.
* ``remote_staged`` — the same remote source wrapped in ``StagedSource``:
  a stager thread runs the request/decode and issues the async device put
  for batch k+1 while the learner computes on batch k, hiding the whole
  transport path behind compute.
* ``local_staged``  — staging over the already-prefetched local fabric
  (reported for completeness; the local pop has almost nothing to hide, so
  expect ~1x — the decorator must at least not cost anything).

Methodology (cf. the offered-load design in ``bench_remote_ingest``): the
*gated* rows model the learn step as a fixed wall-clock occupancy window
(default 14 ms — an accelerator-resident learner occupies the device, not
the host CPUs the transport plane runs on), so the staged-vs-unstaged
contrast measures transport overlap deterministically. Racing real CPU
matmuls instead makes the learner compete for the very cores the
gateway/stager need, and the measured delta becomes scheduler noise
(observed swinging 0.9x-1.25x run to run on a 2-core container). One
real-``learn_phase`` round per mode is still measured and reported as
informational ``*_real_learn`` rows, with write-backs of real |TD|
priorities, so the full numeric path stays exercised.

Acceptance gates (``--check``), on the occupancy rows:
  * staged remote >= 0.98x local (double buffering must hide what remains
    of the transport path — the historical 1.15x-vs-unstaged form of this
    gate became unreachable once the unstaged tcp path itself cleared 0.9x
    local, which caps the staged speedup at ~1.1x by construction);
  * unstaged tcp remote >= 0.9x local (scatter-gather sendmsg + recv_into
    leave the wire boundary a <=10% tax on the learner);
  * unstaged shm remote >= 0.95x local (the ring arena makes same-host
    remote nearly free).

Emitted rows (benchmarks/common.py CSV convention):
  remote_sample/tps_<mode>
  remote_sample/speedup_staged_vs_unstaged_remote
  remote_sample/ratio_remote_vs_local
  remote_sample/ratio_remote_shm_vs_local

JSON result set: ``benchmarks/artifacts/BENCH_remote_sample.json`` plus the
committed repo-root twin ``BENCH_remote_sample.json`` (perf trajectory).
"""

from __future__ import annotations

import argparse
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit, write_artifact  # noqa: E402
from repro.core import apex, replay as replay_lib  # noqa: E402
from repro.core.agents import DQNAgent  # noqa: E402
from repro.models.qnetworks import DuelingDQN  # noqa: E402
from repro.net import ReplayGateway, RemoteFabricSource  # noqa: E402
from repro.optim import optimizers as optim  # noqa: E402
from repro.runtime import (LocalFabricSource, ParamStore,  # noqa: E402
                           ReplayFabric, StagedSource, phases)
from repro.runtime.phases import LearnerSlice, TransitionBlock  # noqa: E402

MODES = ("local", "local_staged", "remote", "remote_shm", "remote_staged")


def bench_geometry(batch: int = 256, obs_dim: int = 384, hidden: int = 320):
    """Compute-bound: a mid-size dueling MLP with fp32 observations fat
    enough that the wire/decode/H2D path is a real (but sub-dominant)
    fraction of a learn step — the regime staging is supposed to win in.
    The replay geometry stays small (2^11 slots) so the shard's own sample/
    write-back ops do not compete with learner compute for the bench host's
    cores — the measured contrast must be the transport, not tree math."""
    agent = DQNAgent(net=DuelingDQN(num_actions=4,
                                    mlp_hidden=(hidden, hidden),
                                    head_hidden=hidden),
                     grad_clip=40.0)
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=2048, min_fill=1024),
        lanes_per_shard=1, num_shards=1, rollout_len=8, n_step=3,
        batch_size=batch, learner_steps_per_iter=1, param_sync_period=1000,
        target_update_period=1000, evict_interval=1 << 30,
        eps_base=0.4, eps_alpha=7.0)
    item = {"obs": jnp.zeros((obs_dim,), jnp.float32),
            "action": jnp.zeros((), jnp.int32),
            "returns": jnp.zeros((), jnp.float32),
            "discount_n": jnp.zeros((), jnp.float32),
            "next_obs": jnp.zeros((obs_dim,), jnp.float32)}
    return cfg, agent, item


def random_block(rng: np.random.Generator, n: int, obs_dim: int,
                 ) -> TransitionBlock:
    items = {
        "obs": rng.standard_normal((n, obs_dim)).astype(np.float32),
        "action": rng.integers(0, 4, size=n).astype(np.int32),
        "returns": rng.standard_normal(n).astype(np.float32),
        "discount_n": np.full((n,), 0.97, np.float32),
        "next_obs": rng.standard_normal((n, obs_dim)).astype(np.float32),
    }
    prios = rng.uniform(0.5, 1.5, size=n).astype(np.float32)
    return TransitionBlock(items=items, priorities=prios)


def filled_fabric(cfg, item, obs_dim: int, fns=None) -> ReplayFabric:
    fabric = ReplayFabric(cfg, item, fns=fns, add_queue_depth=8)
    rng = np.random.default_rng(11)
    total, block_n = 0, 256
    while total < cfg.replay.min_fill:
        fabric.add(random_block(rng, block_n, obs_dim), timeout=5.0)
        total += block_n
    return fabric.start()


def make_learner(cfg, agent, item, optimizer):
    obs0 = jnp.zeros((1,) + item["obs"].shape, jnp.float32)
    params = agent.init(jax.random.key(0), obs0)
    lslice = LearnerSlice(params=params,
                         target_params=jax.tree.map(jnp.copy, params),
                         opt_state=optimizer.init(params),
                         learner_step=jnp.zeros((), jnp.int32))
    learn_fn = jax.jit(lambda lsl, items, w: phases.learn_phase(
        cfg, agent, optimizer, lsl, items, w, None))
    items_ex, w_ex = phases.learner_batch_example(cfg, item)
    jax.block_until_ready(learn_fn(lslice, items_ex, w_ex))  # warm compile
    return learn_fn, lslice


def consume_rate(mode: str, cfg, agent, item, obs_dim: int, learn_fn,
                 lslice, steps: int, warmup: int, fns=None,
                 occupancy_s: float | None = None) -> dict:
    """One measurement: build the transport topology for ``mode``, fill the
    fabric, run ``warmup`` unmeasured learner steps, then time ``steps``
    consume→learn→write-back iterations.

    Two learner models (cf. the offered-load methodology in
    ``bench_remote_ingest``):

    * ``occupancy_s`` set — the *gated* configuration: the learn step is a
      fixed wall-clock occupancy window (``time.sleep``), modeling the
      paper's accelerator-resident learner, whose compute occupies the
      device but not the host CPUs the transport plane runs on. This is
      what makes the staged-vs-unstaged contrast measurable on a small CPU
      host: with real CPU matmuls as the learn step, the learner competes
      for the very cores the gateway/stager need, and the measured delta is
      scheduler noise (observed swinging 0.9x-1.25x run to run), not
      transport overlap.
    * ``occupancy_s=None`` — real jitted ``learn_phase`` numerics, blocking
      on the fresh priorities each step (reported as informational rows;
      everything — learner, shard ops, transport — races for the host's
      cores, so absolute numbers carry the machine's noise).

    Write-backs flow through the source either way, so the full protocol
    path is exercised in both models.
    """
    fabric = filled_fabric(cfg, item, obs_dim, fns=fns)
    gateway = None
    source = None
    try:
        if mode.startswith("remote"):
            transport = "shm" if "shm" in mode else "tcp"
            gateway = ReplayGateway(fabric, ParamStore({}),
                                    sample_timeout_s=0.2).start()
            source = RemoteFabricSource(gateway.host, gateway.port,
                                        transport=transport)
        else:
            source = LocalFabricSource(fabric)
        if mode.endswith("staged"):
            source = StagedSource(source)
        source.start()

        lsl = lslice
        done = 0
        t0 = None
        deadline = time.monotonic() + 300.0
        while done < warmup + steps:
            if time.monotonic() > deadline:
                raise RuntimeError(f"{mode}: consume loop stalled at "
                                   f"{done}/{warmup + steps}")
            batch = source.get_batch(timeout=0.2)
            if batch is None:
                continue
            if occupancy_s is not None:
                time.sleep(occupancy_s)  # accelerator occupancy window
                prios = np.asarray(batch.is_weights) * 0.5 + 0.1
            else:
                lsl, prios, _ = learn_fn(lsl, batch.items, batch.is_weights)
                jax.block_until_ready(prios)
            source.write_back(batch.indices, prios)
            done += 1
            if done == warmup:
                t0 = time.perf_counter()
        dt = time.perf_counter() - t0
        tps = steps * cfg.batch_size / dt if dt > 0 else 0.0
        return {"mode": mode, "steps": steps, "seconds": dt, "tps": tps,
                "us_per_step": 1e6 * dt / steps,
                "occupancy_ms": (None if occupancy_s is None
                                 else 1e3 * occupancy_s),
                "fabric_fns": fabric.fns}
    finally:
        if source is not None:
            source.stop()
        if gateway is not None:
            gateway.stop()
        fabric.stop()
        if fabric.error is not None:
            raise RuntimeError(f"fabric died in {mode}") from fabric.error
        if gateway is not None and gateway.error is not None:
            raise RuntimeError(f"gateway died in {mode}") from gateway.error


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer steps/rounds")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless staged remote >= 0.98x local, tcp "
                         "remote >= 0.9x local, and shm remote >= 0.95x "
                         "local")
    ap.add_argument("--steps", type=int, default=None,
                    help="timed learner steps per measurement")
    ap.add_argument("--rounds", type=int, default=None,
                    help="interleaved measurement rounds")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--obs-dim", type=int, default=384)
    ap.add_argument("--hidden", type=int, default=320)
    ap.add_argument("--occupancy-ms", type=float, default=14.0,
                    help="learner occupancy window for the gated "
                         "measurement (models an accelerator-resident "
                         "learn step; see consume_rate)")
    ap.add_argument("--json", default=None,
                    help="override the artifact path")
    args = ap.parse_args()

    steps = args.steps or (30 if args.smoke else 60)
    rounds = args.rounds or (2 if args.smoke else 3)
    warmup = 5
    occupancy_s = args.occupancy_ms / 1e3

    cfg, agent, item = bench_geometry(args.batch, args.obs_dim, args.hidden)
    optimizer = optim.centered_rmsprop(0.00025 / 4, decay=0.95, eps=1.5e-7)
    learn_fn, lslice = make_learner(cfg, agent, item, optimizer)

    # Interleaved rounds (local, staged, remote, ... per round): CPU
    # containers drift over tens of seconds, so per-mode blocks would
    # compare different machine states. Shard fns are shared across every
    # fabric build, so compilation happens once. Gated rows use the
    # fixed-occupancy learner model; one real-learn_phase round per mode is
    # appended as informational rows.
    all_tps: dict[str, list[float]] = {m: [] for m in MODES}
    rows = []
    fns = None
    for r in range(rounds):
        for mode in MODES:
            row = consume_rate(mode, cfg, agent, item, args.obs_dim,
                               learn_fn, lslice, steps, warmup, fns=fns,
                               occupancy_s=occupancy_s)
            fns = row.pop("fabric_fns")
            rows.append(row)
            all_tps[mode].append(row["tps"])
            emit(f"remote_sample/tps_{mode}_round{r}", row["us_per_step"],
                 f"{row['tps']:.0f}")

    real_tps: dict[str, float] = {}
    for mode in MODES:
        row = consume_rate(mode, cfg, agent, item, args.obs_dim,
                           learn_fn, lslice, max(steps // 2, 10), warmup,
                           fns=fns)
        fns = row.pop("fabric_fns")
        row["mode"] = f"{mode}_real_learn"
        rows.append(row)
        real_tps[mode] = row["tps"]
        emit(f"remote_sample/tps_{mode}_real_learn", row["us_per_step"],
             f"{row['tps']:.0f}")

    medians = {m: statistics.median(all_tps[m]) for m in MODES}
    for m in MODES:
        emit(f"remote_sample/tps_{m}", 0.0, f"{medians[m]:.0f}")
    staged_speedup = medians["remote_staged"] / max(medians["remote"], 1e-9)
    staged_ratio = medians["remote_staged"] / max(medians["local"], 1e-9)
    remote_ratio = medians["remote"] / max(medians["local"], 1e-9)
    shm_ratio = medians["remote_shm"] / max(medians["local"], 1e-9)
    emit("remote_sample/speedup_staged_vs_unstaged_remote", 0.0,
         f"{staged_speedup:.2f}")
    emit("remote_sample/ratio_remote_staged_vs_local", 0.0,
         f"{staged_ratio:.2f}")
    emit("remote_sample/ratio_remote_vs_local", 0.0, f"{remote_ratio:.2f}")
    emit("remote_sample/ratio_remote_shm_vs_local", 0.0, f"{shm_ratio:.2f}")

    write_artifact("remote_sample", {
        "bench": "remote_sample",
        "unix_time": time.time(),
        "cpu_count": os.cpu_count(),
        "smoke": args.smoke,
        "batch": args.batch,
        "obs_dim": args.obs_dim,
        "hidden": args.hidden,
        "occupancy_ms": args.occupancy_ms,
        "steps_per_round": steps,
        "rounds": rounds,
        "median_tps": medians,
        "real_learn_tps": real_tps,
        "speedup_staged_vs_unstaged_remote": staged_speedup,
        "ratio_remote_staged_vs_local": staged_ratio,
        "ratio_remote_vs_local": remote_ratio,
        "ratio_remote_shm_vs_local": shm_ratio,
        "rows": rows,
    }, args.json)

    if args.check:
        failed = False
        if staged_ratio < 0.98:
            print(f"FAIL: staged remote only {staged_ratio:.2f}x the local "
                  f"consume rate (need >= 0.98x — staging must hide the "
                  f"residual transport path)", file=sys.stderr)
            failed = True
        if remote_ratio < 0.9:
            print(f"FAIL: loopback tcp remote learner only "
                  f"{remote_ratio:.2f}x the local consume rate "
                  f"(need >= 0.9x)", file=sys.stderr)
            failed = True
        if shm_ratio < 0.95:
            print(f"FAIL: same-host shm remote learner only "
                  f"{shm_ratio:.2f}x the local consume rate "
                  f"(need >= 0.95x)", file=sys.stderr)
            failed = True
        if failed:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
