"""Fig. 7 (Appendix B) — varying the data-generating policies.

Paper: the full eps-ladder is slightly better overall than a fixed set of 6
eps values, but the fixed set still works. Both variants benchmarked."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, run_apex
from repro.configs import apex_dqn


def main():
    preset = apex_dqn.reduced()
    for mode in ("ladder", "fixed_set"):
        cfg = dataclasses.replace(preset.apex, eps_mode=mode)
        r = run_apex(cfg, preset, iters=80, seed=8)
        emit(f"fig7/eps={mode}/final_return", r["us_per_iter"],
             f"{r['final_return']:.3f}")


if __name__ == "__main__":
    main()
