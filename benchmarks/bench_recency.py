"""Fig. 6 (Appendix A) — recency vs diversity.

Paper: 32 actors adding each transition 8x matches the *recency* of 256
actors but not their *diversity*, and does not recover the performance.
Here: (lanes=4, k=4) vs (lanes=16, k=1) — same ingest volume and memory
turnover, different diversity."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, run_apex
from repro.configs import apex_dqn


def main():
    preset = apex_dqn.reduced()
    base = preset.apex
    variants = {
        "duplicated_4x4": dataclasses.replace(base, lanes_per_shard=4,
                                              replicate_k=4),
        "diverse_16x1": dataclasses.replace(base, lanes_per_shard=16,
                                            replicate_k=1),
    }
    results = {}
    for name, cfg in variants.items():
        r = run_apex(cfg, preset, iters=80, seed=6)
        results[name] = r
        emit(f"fig6/{name}/final_return", r["us_per_iter"],
             f"{r['final_return']:.3f}")
    emit("fig6/diversity_advantage", 0.0,
         f"{results['diverse_16x1']['final_return'] - results['duplicated_4x4']['final_return']:.3f}")


if __name__ == "__main__":
    main()
