"""Serving-plane latency/throughput — continuous batching vs wave
coalescing, and open-loop fixed-QPS policy serving through the gateway.

The inference plane's claim (ROADMAP item 4, after the paper's §4.1 FPS
economics) is that slot scheduling removes two taxes the wave path pays
under ragged request streams: the batch-wide barrier (every wave quantizes
to its slowest member) and the coalesce-window admission delay. This bench
measures both halves of the plane end to end.

Leg 1 — ragged-stream token throughput (deterministic): the same ragged
request set (ragged prompt lengths x ragged per-request new-token budgets)
through ``ContinuousBatcher`` and the ``WaveBatcher`` baseline. Both run
the identical compiled step, chunked prefill, and masked resets — only the
admission policy differs — and both emit the *identical tokens* (asserted),
so tokens/step is a pure scheduling measurement immune to container noise.
The headline ratio is steps_wave / steps_continuous == relative token
throughput at equal work; the gate (``--check``) requires >= 1.2x. Wall
tokens/s for both schedulers ride along for the perf trajectory.

Leg 2 — open-loop policy serving (offered load, not a machine race,
exactly like ``bench_remote_ingest``): K ``PolicyClient`` threads dial a
policy-only ``ReplayGateway`` backed by a slots-mode ``InferenceServer``
and submit rollout requests on a *fixed schedule* (offered QPS chosen at
~0.6x the measured closed-loop capacity, so the gate detects serving
stalls, not container speed). Latency is measured from each request's
*scheduled* send time, so queueing delay from a stalled engine lands in
p99 instead of silently shifting the schedule. Gates: achieved/offered
>= 0.9, and p99 is recorded (the trajectory number) at the gated QPS.

Emitted rows (benchmarks/common.py CSV convention):
  serve_latency/cont_steps, serve_latency/wave_steps
  serve_latency/cont_vs_wave_ratio
  serve_latency/closed_loop_qps
  serve_latency/offered_qps, serve_latency/achieved_qps
  serve_latency/p50_ms, serve_latency/p99_ms

JSON result set: ``benchmarks/artifacts/BENCH_serve_latency.json`` plus the
committed repo-root twin ``BENCH_serve_latency.json``.
"""

from __future__ import annotations

import argparse
import os
import statistics
import sys
import threading
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit, write_artifact  # noqa: E402
from repro.configs import apex_dqn  # noqa: E402
from repro.core import apex, replay as replay_lib  # noqa: E402
from repro.core.agents import DQNAgent  # noqa: E402
from repro.envs.synthetic import ChainWorld  # noqa: E402
from repro.launch.serve import ContinuousBatcher, WaveBatcher  # noqa: E402
from repro.models import registry, transformer  # noqa: E402
from repro.models.qnetworks import DuelingDQN  # noqa: E402
from repro.net import PolicyClient, ReplayGateway  # noqa: E402
from repro.runtime import InferenceServer, ParamStore, phases  # noqa: E402


# --------------------------------------------------------------------------
# Leg 1: continuous vs wave on a ragged stream (deterministic steps)
# --------------------------------------------------------------------------

def ragged_stream(cfg, requests: int, max_new: int, seed: int = 7):
    """Ragged prompts (4..8 tokens) x ragged budgets (1..max_new): the
    workload shape where a batch-wide barrier hurts most — E[max] of a
    wave's budgets vs E[mean] under slot scheduling."""
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab_size, size=rng.randint(2, 5))
               for _ in range(requests)]
    budgets = [int(rng.randint(1, max_new + 1)) for _ in range(requests)]
    return prompts, budgets


def bench_schedulers(arch: str, requests: int, slots: int,
                     max_new: int) -> dict:
    cfg = registry.get_config(arch).reduced()
    params = transformer.init(cfg, jax.random.key(0))
    prompts, budgets = ragged_stream(cfg, requests, max_new)
    max_len = 8 + max_new + 1
    cont = ContinuousBatcher(cfg, params, slots=slots, max_len=max_len,
                             max_new_tokens=max_new)
    wave = WaveBatcher(cfg, params, slots=slots, max_len=max_len,
                       max_new_tokens=max_new)
    # warm run compiles both engines' step/chunk/reset fns off the clock
    warm_p, warm_b = prompts[:slots], budgets[:slots]
    cont.run(warm_p, new_tokens=warm_b)
    wave.run(warm_p, new_tokens=warm_b)
    cont.steps = wave.steps = 0

    t0 = time.perf_counter()
    out_c = cont.run(prompts, new_tokens=budgets)
    dt_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_w = wave.run(prompts, new_tokens=budgets)
    dt_w = time.perf_counter() - t0
    if out_c != out_w:
        raise RuntimeError("schedulers emitted different tokens — the "
                           "throughput ratio would be meaningless")
    tokens = sum(len(v) for v in out_c.values())
    return {
        "mode": "schedulers", "arch": arch, "requests": requests,
        "slots": slots, "max_new_tokens": max_new, "tokens": tokens,
        "cont_steps": cont.steps, "wave_steps": wave.steps,
        # tokens are identical, so relative throughput == inverse step ratio
        "cont_vs_wave_ratio": wave.steps / max(cont.steps, 1),
        "cont_wall_tps": tokens / dt_c if dt_c > 0 else 0.0,
        "wave_wall_tps": tokens / dt_w if dt_w > 0 else 0.0,
    }


# --------------------------------------------------------------------------
# Leg 2: open-loop fixed-QPS serving through the policy gateway
# --------------------------------------------------------------------------

def serve_preset(lanes: int = 4, rollout: int = 4,
                 hidden: int = 32) -> apex_dqn.ApexDQNPreset:
    """Small actor geometry: short rollouts, so the open-loop window
    collects many latency samples in seconds."""
    env = ChainWorld(length=8, max_steps=32)
    agent = DQNAgent(net=DuelingDQN(num_actions=env.num_actions,
                                    mlp_hidden=(hidden, hidden),
                                    head_hidden=hidden),
                     grad_clip=40.0)
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=4096, min_fill=256),
        lanes_per_shard=lanes, num_shards=4, rollout_len=rollout, n_step=2,
        batch_size=32, learner_steps_per_iter=1, param_sync_period=2,
        target_update_period=100, evict_interval=50,
        eps_base=0.4, eps_alpha=7.0)
    return apex_dqn.ApexDQNPreset(apex=cfg, env=env, agent=agent,
                                  learning_rate=1e-3)


class _ServeStack:
    """Slots-mode engine + policy-only gateway + K connected clients."""

    def __init__(self, clients: int):
        preset = serve_preset()
        self.cfg, env, agent = preset.apex, preset.env, preset.agent
        self.slices = [phases.initial_actor_slice(self.cfg, env, seed=7,
                                                  actor_id=t)
                       for t in range(clients)]
        params = agent.init(jax.random.key(0), self.slices[0].obs[:1])
        store = ParamStore(params)
        self.server = InferenceServer(self.cfg, env, agent, store,
                                      max_batch=clients, mode="slots")
        self.server.warm(self.slices[0])
        self.server.start()
        self.gateway = ReplayGateway(None, store, inference=self.server,
                                     act_example=self.slices[0]).start()
        self.clients = [PolicyClient(self.gateway.host, self.gateway.port,
                                     example=self.slices[0], transport="tcp")
                        for _ in range(clients)]
        # one throwaway act per client: the first dispatch through the full
        # wire path pays one-time lazy-compile costs (~seconds) that would
        # otherwise swallow the calibration window
        for t, c in enumerate(self.clients):
            assert c.act(self.slices[t], t) is not None

    def close(self):
        for c in self.clients:
            c.close()
        self.gateway.stop()
        self.server.stop()
        if self.gateway.error is not None:
            raise RuntimeError("gateway died mid-bench") from self.gateway.error
        if self.server.error is not None:
            raise RuntimeError("engine died mid-bench") from self.server.error


def closed_loop_qps(stack: _ServeStack, seconds: float) -> float:
    """Back-to-back clients: the serving plane's capacity on this host."""
    counts = [0] * len(stack.clients)
    stop = time.perf_counter() + seconds

    def worker(t):
        sl = stack.slices[t]
        while time.perf_counter() < stop:
            out = stack.clients[t].act(sl, t)
            assert out is not None
            sl = out[0]
            counts[t] += 1

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(len(stack.clients))]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=seconds + 120.0)
        assert not th.is_alive()
    dt = time.perf_counter() - t0
    return sum(counts) / dt if dt > 0 else 0.0


def open_loop(stack: _ServeStack, offered_qps: float,
              seconds: float) -> dict:
    """Fixed-schedule submission: client t fires at t0 + k*K/offered (its
    1/K share of the offered rate). Latency is measured from the scheduled
    time, so a stalled engine shows up as queueing delay in p99 — and a
    client that falls behind schedule drags achieved below offered, which
    is what the gate detects."""
    K = len(stack.clients)
    interval = K / offered_qps
    latencies_ms: list[float] = []
    done_at = [0.0]
    served = [0] * K
    lock = threading.Lock()
    t0 = time.perf_counter() + 0.2  # common epoch, clients armed first

    def worker(t):
        sl = stack.slices[t]
        k = 0
        while True:
            sched = t0 + (t / K) * interval + k * interval
            if sched > t0 + seconds:
                break
            now = time.perf_counter()
            if sched > now:
                time.sleep(sched - now)
            out = stack.clients[t].act(sl, t)
            assert out is not None
            sl = out[0]
            done = time.perf_counter()
            with lock:
                latencies_ms.append(1e3 * (done - sched))
                done_at[0] = max(done_at[0], done)
            served[t] = k = k + 1

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(K)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=seconds + 120.0)
        assert not th.is_alive()
    # Blocking clients never drop requests, so "achieved" is the rate at
    # which the fixed schedule actually completed: total served over the
    # span from the epoch to the last completion. A plane that keeps up
    # finishes ~one service time after the window; one that stalls
    # stretches the span and drags this ratio down.
    span = max(done_at[0] - t0, 1e-9)
    achieved = sum(served) / span
    lat = sorted(latencies_ms)
    pick = lambda q: lat[min(len(lat) - 1, int(q * len(lat)))]  # noqa: E731
    return {
        "mode": "open_loop", "clients": K, "seconds": seconds,
        "offered_qps": offered_qps, "achieved_qps": achieved,
        "achieved_ratio": achieved / offered_qps,
        "requests": sum(served), "span_s": span,
        "p50_ms": pick(0.50), "p90_ms": pick(0.90), "p99_ms": pick(0.99),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests, short windows")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless continuous >= --min-ratio x wave "
                         "and achieved >= 0.9x offered QPS")
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=None,
                    help="ragged requests for the scheduler leg")
    ap.add_argument("--max-new", type=int, default=24,
                    help="per-request budgets drawn from 1..max-new")
    ap.add_argument("--clients", type=int, default=3,
                    help="PolicyClient threads for the serving leg")
    ap.add_argument("--seconds", type=float, default=None,
                    help="open-loop measurement window")
    ap.add_argument("--load-factor", type=float, default=0.6,
                    help="offered QPS as a fraction of closed-loop capacity")
    ap.add_argument("--min-ratio", type=float, default=1.2,
                    help="gate: continuous/wave token-throughput ratio")
    ap.add_argument("--min-achieved", type=float, default=0.9,
                    help="gate: achieved/offered QPS at the fixed schedule")
    ap.add_argument("--skip-serving-leg", action="store_true",
                    help="scheduler leg only (no sockets)")
    ap.add_argument("--json", default=None,
                    help="override the artifact path")
    args = ap.parse_args()

    requests = args.requests or (16 if args.smoke else 24)
    seconds = args.seconds or (2.0 if args.smoke else 8.0)
    calib_s = 1.0 if args.smoke else 3.0

    sched = bench_schedulers(args.arch, requests, args.slots, args.max_new)
    emit("serve_latency/cont_steps", 0.0, sched["cont_steps"])
    emit("serve_latency/wave_steps", 0.0, sched["wave_steps"])
    emit("serve_latency/cont_vs_wave_ratio", 0.0,
         f"{sched['cont_vs_wave_ratio']:.2f}")
    emit("serve_latency/cont_wall_tps", 0.0,
         f"{sched['cont_wall_tps']:.1f}")
    emit("serve_latency/wave_wall_tps", 0.0,
         f"{sched['wave_wall_tps']:.1f}")

    serving = None
    if not args.skip_serving_leg:
        stack = _ServeStack(args.clients)
        try:
            capacity = closed_loop_qps(stack, calib_s)
            offered = max(args.load_factor * capacity, 1.0)
            serving = open_loop(stack, offered, seconds)
            serving["closed_loop_qps"] = capacity
            serving["load_factor"] = args.load_factor
        finally:
            stack.close()
        emit("serve_latency/closed_loop_qps", calib_s * 1e6,
             f"{capacity:.1f}")
        emit("serve_latency/offered_qps", seconds * 1e6, f"{offered:.1f}")
        emit("serve_latency/achieved_qps", seconds * 1e6,
             f"{serving['achieved_qps']:.1f}")
        emit("serve_latency/p50_ms", seconds * 1e6,
             f"{serving['p50_ms']:.1f}")
        emit("serve_latency/p99_ms", seconds * 1e6,
             f"{serving['p99_ms']:.1f}")

    write_artifact("serve_latency", {
        "bench": "serve_latency",
        "unix_time": time.time(),
        "cpu_count": os.cpu_count(),
        "smoke": args.smoke,
        "min_ratio": args.min_ratio,
        "min_achieved": args.min_achieved,
        "schedulers": sched,
        "serving": serving,
    }, args.json)

    if args.check:
        if sched["cont_vs_wave_ratio"] < args.min_ratio:
            print(f"FAIL: continuous only {sched['cont_vs_wave_ratio']:.2f}x "
                  f"the wave scheduler's token throughput on the ragged "
                  f"stream (need >= {args.min_ratio:.2f}x)", file=sys.stderr)
            return 1
        if serving is not None and (serving["achieved_ratio"]
                                    < args.min_achieved):
            print(f"FAIL: achieved only {serving['achieved_ratio']:.2f}x the "
                  f"offered QPS (need >= {args.min_achieved:.2f}x — the "
                  f"serving plane fell behind its fixed schedule)",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
