"""Table 1 / §4.1 — system throughput accounting.

The paper reports ~50K env FPS from 360 actors (139 FPS each), ~12.5K
transitions/s generated, and ~9.7K transitions/s consumed by the learner
(19 batches of 512 per second). Here we measure the same three rates for the
reduced preset and derive the generate:consume ratio, the paper's key
asynchrony budget (theirs: 12.5K/9.7K ~ 1.29)."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, run_apex
from repro.configs import apex_dqn


def main():
    preset = apex_dqn.reduced()
    cfg = preset.apex
    r = run_apex(cfg, preset, iters=40)
    gen_rate = cfg.lanes_per_shard * cfg.window / (r["us_per_iter"] / 1e6)
    consume_rate = (cfg.learner_steps_per_iter * cfg.batch_size
                    / (r["us_per_iter"] / 1e6))
    emit("table1/env_fps", r["us_per_iter"], f"{r['fps']:.0f}")
    emit("table1/transitions_generated_per_s", r["us_per_iter"],
         f"{gen_rate:.0f}")
    emit("table1/transitions_consumed_per_s", r["us_per_iter"],
         f"{consume_rate:.0f}")
    emit("table1/generate_consume_ratio", r["us_per_iter"],
         f"{gen_rate / max(consume_rate, 1e-9):.2f}")


if __name__ == "__main__":
    main()
