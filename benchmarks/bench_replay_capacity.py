"""Fig. 5 — varying the replay capacity.

Paper: with 256 actors replacing memory contents fast, larger replay
capacities perform somewhat better (keeping rare high-priority experience
alive); too-small capacities can destabilize (Wizard Of Wor divergence).
Here: fixed actor count, capacities swept, final return + a divergence flag
(loss blow-up) reported."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, run_apex
from repro.configs import apex_dqn
from repro.core import replay as replay_lib


def main():
    preset = apex_dqn.reduced()
    for cap in (512, 2048, 8192):
        cfg = dataclasses.replace(
            preset.apex,
            replay=dataclasses.replace(preset.apex.replay, capacity=cap,
                                       soft_capacity=(cap // 8) * 7))
        r = run_apex(cfg, preset, iters=70, seed=4)
        emit(f"fig5/capacity={cap}/final_return", r["us_per_iter"],
             f"{r['final_return']:.3f}")


if __name__ == "__main__":
    main()
