"""§4.1 / Table 1 — decoupled actor/learner throughput.

The paper's actors generate ~12.5K transitions/s while the learner consumes
~9.7K/s (ratio ~1.29) — rates that only *exist* as separate numbers because
acting and learning are decoupled. This bench measures both rates for:

* the synchronous ``core/apex.py`` driver (rates are locked together by the
  alternation: T lanes·window generated and learner_steps·batch consumed per
  iteration — one shared wall clock), and
* the async ``repro.runtime`` (actor threads + replay service + learner
  thread, each on its own clock).

Emitted rows (benchmarks/common.py CSV convention):
  async_throughput/sync_{actor,learner,combined}_tps
  async_throughput/async_{actor,learner,combined}_tps
  async_throughput/async_generate_consume_ratio
  async_throughput/async_vs_sync_combined   <- must be > 1: decoupling wins
  async_throughput/async_{actor_blocked,learner_starved}
  async_throughput/obs_combined_tps         <- metrics sink + tracing on
  async_throughput/obs_vs_plain             <- must be >= 0.98: telemetry
                                               is observably free

``--smoke`` shrinks everything to a CI-sized run (<~1 min on 2 cores);
``--check`` exits nonzero when async does not beat sync (used by CI).
"""

from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit, run_apex, write_artifact  # noqa: E402
from repro.configs import apex_dqn  # noqa: E402
from repro.core import apex, replay as replay_lib  # noqa: E402
from repro.core.agents import DQNAgent  # noqa: E402
from repro.envs.synthetic import ChainWorld  # noqa: E402
from repro.models.qnetworks import DuelingDQN  # noqa: E402
from repro.runtime import AsyncConfig, run_async  # noqa: E402

from dataclasses import replace as dataclasses_replace  # noqa: E402


def bench_preset(hidden: int = 512, lanes: int = 64, rollout: int = 32,
                 batch: int = 512) -> apex_dqn.ApexDQNPreset:
    """Benchmark geometry: heavy enough that XLA kernel time (GIL released)
    dominates Python dispatch. On a dispatch-bound toy config the fused
    synchronous graph wins by construction and the comparison says nothing
    about the architecture — this preset keeps both runtimes compute-bound,
    which is the regime the paper's throughput numbers live in (§4.1)."""
    env = ChainWorld(length=16, max_steps=64)
    agent = DQNAgent(net=DuelingDQN(num_actions=env.num_actions,
                                    mlp_hidden=(hidden, hidden),
                                    head_hidden=hidden),
                     grad_clip=40.0)
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=8192, min_fill=512),
        lanes_per_shard=lanes, num_shards=1, rollout_len=rollout, n_step=3,
        batch_size=batch, learner_steps_per_iter=2, param_sync_period=2,
        target_update_period=100, evict_interval=50,
        eps_base=0.4, eps_alpha=7.0)
    return apex_dqn.ApexDQNPreset(apex=cfg, env=env, agent=agent,
                                  learning_rate=1e-3)


def sync_rates(preset, iters: int) -> dict:
    """Generate/consume transitions-per-second of the lockstep driver."""
    cfg = preset.apex
    r = run_apex(cfg, preset, iters=iters)
    per_iter_s = r["us_per_iter"] / 1e6
    gen = cfg.lanes_per_shard * cfg.window / per_iter_s
    con = cfg.learner_steps_per_iter * cfg.batch_size / per_iter_s
    return {"actor_tps": gen, "learner_tps": con, "combined_tps": gen + con,
            "seconds": r["seconds"]}


def async_rates(preset, acfg: AsyncConfig) -> dict:
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    s = res.stats
    svc = res.service_stats  # thread-safe fabric snapshot (live counters)
    return {"actor_tps": s["actor_tps"], "learner_tps": s["learner_tps"],
            "combined_tps": s["actor_tps"] + s["learner_tps"],
            "ratio": s["generate_consume_ratio"],
            "actor_blocked": s["actor_blocked"],
            "learner_starved": s["learner_starved"],
            "transitions_added": svc.transitions_added,
            "batches_sampled": svc.batches_sampled,
            # per-op applied-latency EMAs from the shard owner loops
            "add_us": svc.add_us, "sample_us": svc.sample_us,
            "writeback_us": svc.writeback_us,
            "seconds": s["seconds"]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU config for CI (seconds, not minutes)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless async combined tps beats sync")
    ap.add_argument("--actor-threads", type=int, default=1,
                    help="1 by default: CI runners have ~2 cores, so one "
                         "actor + one learner + the replay service already "
                         "saturate them")
    args = ap.parse_args()

    preset = bench_preset()
    if args.smoke:
        sync_iters, learner_steps = 6, 30
    else:
        sync_iters, learner_steps = 25, 150

    sync = sync_rates(preset, sync_iters)
    # progress_every_s exercises ServiceStats.snapshot() while the run is
    # hot: the runner's progress thread reads the fabric counters live
    # (under obs it reads the derived histogram-mean *_us views).
    acfg = AsyncConfig(actor_threads=args.actor_threads,
                       total_learner_steps=learner_steps,
                       max_seconds=180.0 if args.smoke else 600.0,
                       progress_every_s=None if args.smoke else 10.0)
    # Telemetry-overhead pair: the same geometry with the obs plane off
    # and on (JSONL sink flushing every second plus 1-in-100 pipeline
    # tracing — the documented operating point; traced ops force a device
    # sync, so rate 1.0 would measure the syncs, not the
    # instrumentation). A single back-to-back pair swings ~10-25% on a
    # busy 1-2 core runner — far more than the effect being gated — so
    # the runs interleave (plain, obs, plain, obs, ...) to correlate any
    # load drift across both sides and the >= 0.98x gate compares the
    # *means* of the interleaved reps, which converge ~sqrt(n) faster
    # than any single draw. The reported async row is the best plain rep
    # (peak capability, for the async-vs-sync comparison); every rep's
    # combined rate is kept in the artifact.
    n_reps = 4 if args.smoke else 3
    obs_dir = tempfile.mkdtemp(prefix="bench_obs_")
    plain_runs, obs_runs = [], []
    try:
        obs_acfg = dataclasses_replace(acfg, metrics_dir=obs_dir,
                                       trace_sample_rate=0.01)
        for _ in range(n_reps):
            plain_runs.append(async_rates(preset, acfg))
            obs_runs.append(async_rates(preset, obs_acfg))
    finally:
        shutil.rmtree(obs_dir, ignore_errors=True)
    asy = max(plain_runs, key=lambda r: r["combined_tps"])
    obs = max(obs_runs, key=lambda r: r["combined_tps"])
    plain_mean = sum(r["combined_tps"] for r in plain_runs) / n_reps
    obs_mean = sum(r["combined_tps"] for r in obs_runs) / n_reps

    us = sync["seconds"] * 1e6 / max(sync_iters, 1)
    emit("async_throughput/sync_actor_tps", us, f"{sync['actor_tps']:.0f}")
    emit("async_throughput/sync_learner_tps", us, f"{sync['learner_tps']:.0f}")
    emit("async_throughput/sync_combined_tps", us,
         f"{sync['combined_tps']:.0f}")
    aus = asy["seconds"] * 1e6 / max(learner_steps, 1)
    emit("async_throughput/async_actor_tps", aus, f"{asy['actor_tps']:.0f}")
    emit("async_throughput/async_learner_tps", aus,
         f"{asy['learner_tps']:.0f}")
    emit("async_throughput/async_combined_tps", aus,
         f"{asy['combined_tps']:.0f}")
    emit("async_throughput/async_generate_consume_ratio", aus,
         f"{asy['ratio']:.2f}")
    emit("async_throughput/async_actor_blocked", aus,
         f"{asy['actor_blocked']:.0f}")
    emit("async_throughput/async_learner_starved", aus,
         f"{asy['learner_starved']:.0f}")
    emit("async_throughput/async_transitions_added", aus,
         f"{asy['transitions_added']:.0f}")
    emit("async_throughput/async_op_latency_ema", aus,
         f"add={asy['add_us']:.0f}us sample={asy['sample_us']:.0f}us "
         f"wb={asy['writeback_us']:.0f}us")
    speedup = asy["combined_tps"] / max(sync["combined_tps"], 1e-9)
    emit("async_throughput/async_vs_sync_combined", aus, f"{speedup:.2f}")
    ous = obs["seconds"] * 1e6 / max(learner_steps, 1)
    obs_ratio = obs_mean / max(plain_mean, 1e-9)
    emit("async_throughput/obs_combined_tps", ous,
         f"{obs['combined_tps']:.0f}")
    emit("async_throughput/obs_vs_plain", ous, f"{obs_ratio:.3f}")

    write_artifact("async_throughput", {
        "bench": "async_throughput",
        "unix_time": time.time(),
        "cpu_count": os.cpu_count(),
        "smoke": args.smoke,
        "actor_threads": args.actor_threads,
        "async_vs_sync_combined": speedup,
        "obs_vs_plain": obs_ratio,
        "obs_plain_combined_tps_mean": plain_mean,
        "obs_combined_tps_mean": obs_mean,
        "obs_trace_sample_rate": 0.01,
        "obs_reps": n_reps,
        "sync": sync,
        "async": asy,
        "async_obs": obs,
        "plain_combined_tps_runs": [r["combined_tps"] for r in plain_runs],
        "obs_combined_tps_runs": [r["combined_tps"] for r in obs_runs],
    })

    if args.check and speedup <= 1.0:
        print(f"FAIL: async combined {asy['combined_tps']:.0f} tps did not "
              f"beat sync {sync['combined_tps']:.0f} tps", file=sys.stderr)
        return 1
    if args.check and obs_ratio < 0.98:
        print(f"FAIL: telemetry-enabled async {obs_mean:.0f} tps (mean of "
              f"{n_reps} interleaved reps) is {obs_ratio:.3f}x the plain "
              f"mean {plain_mean:.0f} (gate: >= 0.98x) — the "
              "metrics/tracing hot path got expensive", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
