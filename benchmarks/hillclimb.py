"""§Perf hillclimb driver: re-run a dry-run combo with a named variant
(config override set) and report the roofline-term deltas vs baseline.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch llama3.2-1b \
      --shape train_4k --variant fsdp_resid

Variants (each is one hypothesis from EXPERIMENTS.md §Perf):
  fsdp_resid   residual stream sharded over data axes only — gather weights
               per layer (small) instead of activations (large)
  seq_resid    sequence parallelism: residual (batch over data, seq over
               model) — sharded activations without hidden-dim gathers
  p_bf16       bf16 softmax-probability matmul inputs (halves quadratic
               score traffic; exp/max/denominator stay f32)
  p_bf16_fsdp  both of the above
  chunk1k      KV chunk 1024 (fewer online-softmax correction passes)
  chunk256     KV chunk 256
  ssd_q128     Mamba2 SSD chunk 128 (bigger intra-chunk matmuls)
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json

VARIANTS = {
    "fsdp_resid": {"act_sharding": "data_only"},
    "seq_resid": {"act_sharding": "seq"},
    "p_bf16": {"attn_p_bf16": True},
    "p_bf16_fsdp": {"attn_p_bf16": True, "act_sharding": "data_only"},
    "chunk1k": {"attn_chunk": 1024},
    "chunk256": {"attn_chunk": 256},
    "moe_local": {"moe_groups": 16},
    "wkv_heads_seq": {"mixer_head_shard": True, "act_sharding": "seq"},
    "moe_local_seq": {"moe_groups": 16, "act_sharding": "seq"},
    "swa_ring": {"swa_ring_cache": True},
}


def main():
    from repro.launch import dryrun
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=tuple(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    overrides = VARIANTS[args.variant]
    rec = dryrun.run_combo(args.arch, args.shape, args.multi_pod,
                           overrides=overrides, tag=args.variant)
    path = dryrun.artifact_path(args.arch, args.shape, args.multi_pod,
                                tag=args.variant)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)

    base_path = dryrun.artifact_path(args.arch, args.shape, args.multi_pod)
    if os.path.exists(base_path):
        base = json.load(open(base_path))
        if base["status"] == "ok" and rec["status"] == "ok":
            print("\n=== delta vs baseline ===")
            for term in ("compute_s", "memory_s", "collective_s"):
                b, n = base[term], rec[term]
                pct = 100 * (n - b) / b if b else float("nan")
                print(f"{term:14s} {b:10.4f} -> {n:10.4f}  ({pct:+.1f}%)")


if __name__ == "__main__":
    main()
