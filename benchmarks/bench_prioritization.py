"""Fig. 12 (Appendix) — prioritized vs uniform replay as actors scale.

Paper: both benefit from more actors, but prioritized exploits the extra
data better. Evaluated like the paper: greedy policy on held-out episodes,
on the hard chain (sparse goal + distractor local optimum), seed-averaged
— prioritization's edge is precisely surfacing the rare goal transitions.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.bench_actor_scaling import greedy_eval, hard_preset
from benchmarks.common import emit
from repro.core import apex


def main():
    preset = hard_preset()
    for lanes in (8, 32):
        for name, alpha in (("prioritized", 0.6), ("uniform", 0.0)):
            cfg = dataclasses.replace(
                preset.apex, lanes_per_shard=lanes,
                replay=dataclasses.replace(
                    preset.apex.replay, alpha=alpha,
                    beta=0.4 if alpha else 0.0))
            scores = []
            optimizer = preset.make_optimizer()
            init_fn, step_fn = apex.make_train_fn(
                cfg, preset.env, preset.agent, optimizer)
            for seed in (5, 6, 7):
                state = init_fn(jax.random.key(seed))
                for _ in range(70):
                    state, m = step_fn(state)
                scores.append(greedy_eval(preset, state.params, seed=seed))
            emit(f"fig12/actors={lanes}/{name}/greedy_eval",
                 0.0, f"{np.mean(scores):.3f}")


if __name__ == "__main__":
    main()
