"""Ape-X DPG — the paper's continuous-control configuration (§4.2, Appendix D),
plus a CPU-scale reduced preset.

Paper values: 64 actors, Gaussian exploration noise sigma=0.3 (explicitly not
OU noise), critic 400-tanh-300, actor 300-tanh-200 with element-wise action
gradient clip to [-1,1], Adam lr 1e-4, n-step critic targets, target nets
copied every 100 batches, replay capacity 1e6 with *prioritized eviction*
(alpha_evict = -0.4), batch 256.
"""

from __future__ import annotations

import dataclasses

from repro.core import apex, replay as replay_lib
from repro.core.agents import DPGAgent
from repro.envs.synthetic import PointMass
from repro.models.qnetworks import DPGActor, DPGCritic
from repro.optim import optimizers as optim


@dataclasses.dataclass(frozen=True)
class ApexDPGPreset:
    apex: apex.ApexConfig
    env: PointMass
    agent: DPGAgent
    learning_rate: float = 1e-4

    def make_optimizer(self):
        return optim.adam(self.learning_rate)


def full(num_shards: int = 16) -> ApexDPGPreset:
    env = PointMass(max_steps=200)
    agent = DPGAgent(actor_net=DPGActor(action_dim=env.action_dim,
                                        hidden=(300, 200)),
                     critic_net=DPGCritic(hidden=(400, 300)),
                     sigma=0.3, action_grad_clip=1.0)
    cap = 1_048_576 // num_shards
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(
            capacity=cap, soft_capacity=int(cap * 0.95),
            alpha=0.6, beta=0.4, evict_alpha=-0.4,
            min_fill=10_000 // num_shards),
        lanes_per_shard=max(1, 64 // num_shards), num_shards=num_shards,
        rollout_len=50, n_step=5, batch_size=256 // num_shards,
        learner_steps_per_iter=2, param_sync_period=1,
        target_update_period=100, evict_interval=100,
        eviction="prioritized", evict_num=256,
        eps_base=0.4, eps_alpha=7.0)
    return ApexDPGPreset(apex=cfg, env=env, agent=agent)


def reduced(num_shards: int = 1) -> ApexDPGPreset:
    env = PointMass(max_steps=60)
    agent = DPGAgent(actor_net=DPGActor(action_dim=env.action_dim,
                                        hidden=(32, 32)),
                     critic_net=DPGCritic(hidden=(32, 32)),
                     sigma=0.3)
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=2048, min_fill=128),
        lanes_per_shard=8, num_shards=num_shards,
        rollout_len=20, n_step=5, batch_size=32,
        learner_steps_per_iter=2, param_sync_period=2,
        target_update_period=50, evict_interval=25,
        eviction="prioritized", evict_num=64)
    return ApexDPGPreset(apex=cfg, env=env, agent=agent, learning_rate=1e-3)
