"""internvl2-2b — VLM: InternLM2-1.8B language backbone consuming a stub
InternViT patch-embedding prefix [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92553,
    input_mode="mixed", prefix_len=1024,   # stub ViT/projector output
    act="swiglu", norm="rmsnorm", rope_theta=1000000.0,
    source="arXiv:2404.16821 (InternVL2-2B / InternLM2 backbone)",
)
