"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE decoder
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig
from repro.models.layers import MoEConfig

CONFIG = ModelConfig(
    arch_id="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    mlp="moe",
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400, num_shared=0),
    act="swiglu", norm="layernorm",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
