"""deepseek-v2-236b — MLA latent attention (kv_lora=512) + 160-routed/2-shared
top-6 MoE [arXiv:2405.04434]. Simplification: every layer is MoE (the real
model's layer-0 dense MLP is folded into the uniform scanned stack;
DESIGN.md §7)."""
from repro.configs.base import ModelConfig
from repro.models.layers import MLAConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=1536, vocab_size=102400,
    mixer="mla",
    mla=MLAConfig(q_lora=1536, kv_lora=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    mlp="moe",
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536, num_shared=2),
    act="swiglu", norm="rmsnorm",
    source="arXiv:2405.04434 (DeepSeek-V2)",
)
