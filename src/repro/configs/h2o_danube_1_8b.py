"""h2o-danube-1.8b — dense decoder, llama+mistral mix with sliding-window
attention [arXiv:2401.16818]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000,
    sliding_window=4096, rope_theta=10000.0,
    act="swiglu", norm="rmsnorm",
    source="arXiv:2401.16818 (H2O-Danube-1.8B)",
)
