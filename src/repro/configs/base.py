"""Config schema: architectures + input shapes + smoke-reduction rules.

Each assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (exact published spec, source cited) built on this schema. The
four benchmark input shapes are global (sharded over the data axes by the
launcher):

  train_4k     seq 4096   global_batch 256   training step
  prefill_32k  seq 32768  global_batch 32    inference prefill / actor scoring
  decode_32k   seq 32768  global_batch 128   one-token decode vs KV cache
  long_500k    seq 524288 global_batch 1     long-context decode (sub-quadratic archs)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.models.layers import MLAConfig, MoEConfig
from repro.models.ssm import SSMConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""                 # paper / model-card citation

    mixer: str = "attn"              # attn | mla | mamba2 | rwkv6
    mlp: str = "dense"               # dense | moe | rwkv_cm
    act: str = "swiglu"
    norm: str = "rmsnorm"
    rope_theta: float = 1e4
    rope_pct: float = 1.0
    sliding_window: int | None = None
    causal: bool = True
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    shared_attn_every: int = 0       # zamba2: shared attention block cadence
    input_mode: str = "tokens"       # tokens | embeddings | mixed
    prefix_len: int = 1024           # vlm: patch tokens per sequence
    tie_embeddings: bool = False

    dtype: str = "bfloat16"          # activation dtype
    param_dtype: str = "bfloat16"
    attn_impl: str = "einsum"
    attn_chunk: int = 512
    remat: bool = False
    scan_layers: bool = True         # False: unroll (exact HLO cost/collective
                                     # accounting — XLA counts while bodies once)
    attn_unroll: bool = False        # unroll the chunked-attention KV loop
                                     # (exact accounting in dry-run probes)
    attn_p_bf16: bool = False        # store/multiply softmax probabilities in
                                     # bf16 (exp/max/denominator stay f32)
    mixer_head_shard: bool = False   # constrain SSM/WKV mixer tensors to
                                     # head-parallel (heads over `model`,
                                     # sequence local) around the recurrence
    swa_ring_cache: bool = False     # sliding-window archs: decode KV cache
                                     # is a ring of `sliding_window` slots
                                     # instead of the full sequence (O(w)
                                     # memory; prefill must fit the window)
    act_sharding: tuple | None = None  # P spec for (B, S, d) residual stream

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def attention_free(self) -> bool:
        return self.mixer in ("mamba2", "rwkv6") and self.shared_attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid or sliding-window attention."""
        return (self.mixer in ("mamba2", "rwkv6")
                or self.sliding_window is not None)

    @property
    def encoder_only(self) -> bool:
        return not self.causal

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (brief: <=2 layers,
        d_model<=512, <=4 experts) runnable on CPU."""
        head_dim = max(32, d_model // max(self.n_heads, 1))
        n_heads = min(self.n_heads, max(2, d_model // head_dim))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        if n_heads % n_kv:
            n_kv = 1
        changes: dict[str, Any] = dict(
            n_layers=n_layers, d_model=d_model,
            n_heads=n_heads, n_kv_heads=n_kv, head_dim=d_model // n_heads,
            d_ff=2 * d_model, vocab_size=vocab,
            dtype="float32", param_dtype="float32",
        )
        if self.moe is not None:
            # capacity 4.0: no token dropping in smoke tests, so prefill+decode
            # match full-sequence apply exactly
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_expert=d_model,
                num_shared=min(self.moe.num_shared, 1), capacity_factor=4.0)
        if self.mla is not None:
            changes["mla"] = MLAConfig(q_lora=64, kv_lora=32, rope_head_dim=16,
                                       nope_head_dim=32, v_head_dim=32)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, headdim=32)
        if self.sliding_window is not None:
            changes["sliding_window"] = 32
        if self.shared_attn_every:
            changes["shared_attn_every"] = 1
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """The brief's skip rules; reasons are recorded in EXPERIMENTS.md."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full attention: 500k decode requires sub-quadratic attention"
    return True, ""
