"""hubert-xlarge — encoder-only audio transformer (wav2vec2 arch); the conv
feature-extractor frontend is a stub providing 1280-d frame embeddings
[arXiv:2106.07447]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    causal=False, input_mode="embeddings",
    act="gelu", norm="layernorm",
    # adaptation: rope in place of the conv positional embedding (DESIGN.md)
    rope_theta=10000.0,
    source="arXiv:2106.07447 (HuBERT X-Large)",
)
