"""rwkv6-1.6b ("Finch") — attention-free RNN with data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    mixer="rwkv6", mlp="rwkv_cm",
    norm="layernorm",
    source="arXiv:2404.05892 (RWKV-6 Finch 1.6B)",
)
