"""Ape-X DQN — the paper's own configuration (§4.1, Appendix C), plus a
CPU-scale reduced preset used by tests/examples.

Paper values (full): 360 actors, eps-ladder eps=0.4/alpha=7, n=3, batch 512,
replay soft cap 2e6 with FIFO en-masse eviction every 100 learner steps,
min-fill 50000, centered RMSProp lr 0.00025/4, grad clip 40, target copy
every 2500 batches, actor param sync every ~400 frames, PNG-compressed uint8
observations (here: the uint8 obs codec).

The TPU mapping (DESIGN.md §2) spreads the 360 actors across
``num_shards x lanes_per_shard`` actor lanes.
"""

from __future__ import annotations

import dataclasses

from repro.core import apex, replay as replay_lib
from repro.core.agents import DQNAgent
from repro.envs.synthetic import ChainWorld
from repro.models.qnetworks import DuelingDQN
from repro.optim import optimizers as optim


@dataclasses.dataclass(frozen=True)
class ApexDQNPreset:
    apex: apex.ApexConfig
    env: ChainWorld
    agent: DQNAgent
    learning_rate: float = 0.00025 / 4

    def make_optimizer(self):
        return optim.centered_rmsprop(self.learning_rate, decay=0.95,
                                      eps=1.5e-7)


def full(num_shards: int = 16) -> ApexDQNPreset:
    """Paper-scale geometry (per-shard replay = 2e6 / shards, batch 512)."""
    env = ChainWorld(length=64, max_steps=512)
    agent = DQNAgent(net=DuelingDQN(num_actions=env.num_actions,
                                    mlp_hidden=(512, 512), head_hidden=512),
                     grad_clip=40.0)
    cap = 2_097_152 // num_shards  # soft 2e6 global
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(
            capacity=cap, soft_capacity=int(cap * 0.95),
            alpha=0.6, beta=0.4, min_fill=50_000 // num_shards),
        lanes_per_shard=max(1, 360 // num_shards), num_shards=num_shards,
        rollout_len=64, n_step=3, batch_size=512 // num_shards,
        learner_steps_per_iter=2, param_sync_period=1,
        target_update_period=2500, evict_interval=100,
        eps_base=0.4, eps_alpha=7.0)
    return ApexDQNPreset(apex=cfg, env=env, agent=agent)


def reduced(num_shards: int = 1) -> ApexDQNPreset:
    """CPU-scale preset: same structure, small everything."""
    env = ChainWorld(length=8, max_steps=32)
    agent = DQNAgent(net=DuelingDQN(num_actions=env.num_actions,
                                    mlp_hidden=(64,), head_hidden=64),
                     grad_clip=40.0)
    cfg = apex.ApexConfig(
        replay=replay_lib.ReplayConfig(capacity=4096, min_fill=256),
        lanes_per_shard=16, num_shards=num_shards,
        rollout_len=24, n_step=3, batch_size=64,
        learner_steps_per_iter=2, param_sync_period=2,
        target_update_period=100, evict_interval=50,
        eps_base=0.4, eps_alpha=7.0)
    return ApexDQNPreset(apex=cfg, env=env, agent=agent, learning_rate=1e-3)
