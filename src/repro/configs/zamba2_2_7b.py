"""zamba2-2.7b — hybrid: Mamba2 backbone + weight-shared full-attention block
applied every 6 layers [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig
from repro.models.ssm import SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    mixer="mamba2", mlp="none",          # mamba blocks carry no per-layer MLP
    ssm=SSMConfig(d_state=64, headdim=64, conv_width=4, expand=2, ngroups=1),
    shared_attn_every=6,                 # the shared attn+MLP block (d_ff used there)
    act="swiglu", norm="rmsnorm",
    source="arXiv:2411.15242 (Zamba2-2.7B)",
)
