"""Pure-JAX vectorized environments (device-resident actor loop).

The paper's actors step ALE / MuJoCo on CPU hosts; on a TPU pod the whole
actor phase is jitted, so the environments here are pure ``jax.lax`` state
machines with the standard (obs, reward, discount) step contract and
auto-reset semantics. Real simulators can be swapped in via host callbacks
without touching the Ape-X core.

* :class:`ChainWorld` — discrete, sparse-reward exploration chain (the Atari
  stand-in). Reaching the far end pays +1; a distractor action pays a tiny
  immediate reward, so greedy policies plateau — the setting where the paper's
  eps-ladder + prioritization shine (§5).
* :class:`PointMass` — continuous control stand-in (DeepMind control suite
  style): 2-D point driven by acceleration toward a random target, reward
  = -distance (Appendix D's feature-observation regime).

Both expose uint8 or f32 observations; ChainWorld's uint8 obs exercise the
replay's quantization codec (the paper's PNG-compression analogue).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class StepOut(NamedTuple):
    obs: jax.Array
    reward: jax.Array     # scalar f32
    discount: jax.Array   # scalar f32: gamma at this step, 0 = terminal


# ---------------------------------------------------------------------------
# ChainWorld (discrete)
# ---------------------------------------------------------------------------

class ChainState(NamedTuple):
    pos: jax.Array        # int32 in [0, length)
    t: jax.Array          # int32 step counter
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class ChainWorld:
    length: int = 16
    max_steps: int = 64
    gamma: float = 0.99
    slip_prob: float = 0.05        # action slips to a random one
    distractor_reward: float = 0.01

    num_actions: int = 4           # 0: left, 1: right, 2: noop, 3: distractor

    @property
    def obs_shape(self) -> tuple[int, ...]:
        return (self.length + 2,)

    obs_dtype = jnp.uint8

    def _obs(self, state: ChainState) -> jax.Array:
        onehot = (jnp.arange(self.length) == state.pos).astype(jnp.uint8) * 255
        extra = jnp.stack([
            (state.t * (255 // self.max_steps)).astype(jnp.uint8),
            jnp.asarray(255, jnp.uint8),
        ])
        return jnp.concatenate([onehot, extra])

    def reset(self, rng: jax.Array) -> tuple[ChainState, jax.Array]:
        state = ChainState(pos=jnp.zeros((), jnp.int32),
                           t=jnp.zeros((), jnp.int32), rng=rng)
        return state, self._obs(state)

    def step(self, state: ChainState, action: jax.Array) -> tuple[ChainState, StepOut]:
        rng, slip_rng, a_rng, reset_rng = jax.random.split(state.rng, 4)
        slipped = jax.random.uniform(slip_rng) < self.slip_prob
        action = jnp.where(slipped,
                           jax.random.randint(a_rng, (), 0, self.num_actions),
                           action)
        delta = jnp.where(action == 0, -1, jnp.where(action == 1, 1, 0))
        pos = jnp.clip(state.pos + delta, 0, self.length - 1)
        t = state.t + 1
        reached = pos == self.length - 1
        timeout = t >= self.max_steps
        terminal = reached | timeout
        reward = (reached.astype(jnp.float32)
                  + (action == 3).astype(jnp.float32) * self.distractor_reward)
        discount = jnp.where(terminal, 0.0, self.gamma)
        # auto-reset
        next_state = ChainState(pos=jnp.where(terminal, 0, pos),
                                t=jnp.where(terminal, 0, t), rng=rng)
        return next_state, StepOut(self._obs(next_state), reward, discount)


# ---------------------------------------------------------------------------
# PointMass (continuous)
# ---------------------------------------------------------------------------

class PointMassState(NamedTuple):
    pos: jax.Array        # (2,) f32
    vel: jax.Array        # (2,) f32
    target: jax.Array     # (2,) f32
    t: jax.Array
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class PointMass:
    max_steps: int = 200
    gamma: float = 0.99
    dt: float = 0.05
    drag: float = 0.1

    action_dim: int = 2

    @property
    def obs_shape(self) -> tuple[int, ...]:
        return (6,)

    obs_dtype = jnp.float32

    def _obs(self, s: PointMassState) -> jax.Array:
        return jnp.concatenate([s.pos, s.vel, s.target]).astype(jnp.float32)

    def reset(self, rng: jax.Array) -> tuple[PointMassState, jax.Array]:
        rng, p_rng, t_rng = jax.random.split(rng, 3)
        state = PointMassState(
            pos=jax.random.uniform(p_rng, (2,), minval=-1.0, maxval=1.0),
            vel=jnp.zeros((2,), jnp.float32),
            target=jax.random.uniform(t_rng, (2,), minval=-1.0, maxval=1.0),
            t=jnp.zeros((), jnp.int32),
            rng=rng,
        )
        return state, self._obs(state)

    def step(self, s: PointMassState, action: jax.Array) -> tuple[PointMassState, StepOut]:
        rng, reset_rng = jax.random.split(s.rng)
        a = jnp.clip(action, -1.0, 1.0)
        vel = (1.0 - self.drag) * s.vel + self.dt * a
        pos = jnp.clip(s.pos + self.dt * vel, -1.5, 1.5)
        t = s.t + 1
        dist = jnp.linalg.norm(pos - s.target)
        reward = -dist.astype(jnp.float32)
        timeout = t >= self.max_steps
        discount = jnp.where(timeout, 0.0, self.gamma)
        # auto-reset on timeout
        fresh, _ = self.reset(reset_rng)
        nxt = jax.tree.map(
            lambda f, c: jnp.where(timeout, f, c),
            fresh, PointMassState(pos, vel, s.target, t, rng),
        )
        return nxt, StepOut(self._obs(nxt), reward, discount)


def batch_reset(env, rng: jax.Array, lanes: int):
    """Vectorized reset over actor lanes."""
    return jax.vmap(env.reset)(jax.random.split(rng, lanes))


def batch_step(env, states, actions):
    """Vectorized step over actor lanes."""
    return jax.vmap(env.step)(states, actions)
