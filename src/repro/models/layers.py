"""Transformer building blocks — pure JAX, parameterized by nested dicts.

Conventions
===========
* Activations compute in ``cfg.dtype`` (bf16 by default); softmax, norms and
  router logits in f32. Parameters are stored in ``param_dtype``.
* Attention tensors are (batch, seq, heads, head_dim); GQA never materializes
  repeated KV heads (query heads are grouped against shared KV).
* Three attention implementations behind one flag:
    - ``einsum``  : materialized scores; decode (Sq==1) and small tests.
    - ``chunked`` : online-softmax scan over KV blocks — bounded memory at
                    32k+ prefill; this is also the oracle of the Pallas
                    flash kernel.
    - ``pallas`` / ``pallas_interpret`` : the TPU kernel
                    (repro.kernels.flash_attention).
* MoE uses fixed-capacity sort-free dispatch (one-hot cumsum positions +
  scatter/gather), experts sharded over the ``model`` axis.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(rng, shape, std, dtype):
    return (std * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def norm_init(kind: str, d: int, dtype) -> dict:
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def apply_norm(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# Rotary position embeddings (with partial-rotary support, e.g. StableLM 25%)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, rope_pct: float, theta: float) -> jax.Array:
    rot_dim = int(head_dim * rope_pct) // 2 * 2
    exponents = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / max(rot_dim, 1)
    return 1.0 / (theta ** exponents)  # (rot_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, freqs: jax.Array) -> jax.Array:
    """x (B, S, H, D), positions (B, S) or (S,); rotates the first len(freqs)*2 dims."""
    rot = freqs.shape[0] * 2
    if rot == 0:
        return x
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, rot/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated, x_pass], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (GQA, causal, sliding window) — einsum & chunked paths
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: int | None, k_valid_len: jax.Array | None) -> jax.Array:
    """Additive f32 bias from position constraints.

    q_pos (Sq,) or (B, Sq); k_pos (Sk,) or (B, Sk) — batched forms support
    per-row decode positions (continuous batching) and ring-buffer caches
    whose slots hold per-row absolute positions. Returns (Sq, Sk) or
    (B, 1, 1, Sq, Sk).
    """
    batched = q_pos.ndim == 2 or k_pos.ndim == 2
    if batched:
        qp = (q_pos if q_pos.ndim == 2 else q_pos[None, :])[:, :, None]
        kp = (k_pos if k_pos.ndim == 2 else k_pos[None, :])[:, None, :]
    else:
        qp = q_pos[:, None]
        kp = k_pos[None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= qp >= kp
    if window is not None:
        ok &= qp - kp < window
    if k_valid_len is not None:
        kv = (k_valid_len[:, None, None] if batched
              and getattr(k_valid_len, "ndim", 0) == 1 else k_valid_len)
        ok &= kp < kv
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    if batched:                                    # broadcast over (h, g)
        bias = bias[:, None, None, :, :]
    return bias


def attention_einsum(q, k, v, *, causal=True, window=None, q_offset=0,
                     k_valid_len=None, scale=None, k_positions=None):
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D) -> (B,Sq,Hq,D). Materialized scores.

    ``k_positions`` overrides the implicit 0..Sk-1 key positions — used by
    ring-buffer (sliding-window) caches whose slots hold non-contiguous
    absolute positions.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qg = q.reshape(B, Sq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk) if k_positions is None else k_positions
    s = s + _mask_bias(q_pos, k_pos, causal, window, k_valid_len)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def attention_chunked(q, k, v, *, causal=True, window=None, q_offset=0,
                      k_valid_len=None, scale=None, chunk=512, unroll=False,
                      p_bf16=False):
    """Online-softmax over KV chunks: O(Sq * chunk) live scores.

    Exactly matches ``attention_einsum`` (it is the oracle for the Pallas
    flash kernel as well). Fully-masked chunks still execute — skipping them
    is a §Perf hillclimb (block-sparse schedule), not baseline behavior.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    nchunks = -(-Sk // chunk)
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, Hkv, k.shape[-1])
    vc = v.reshape(B, nchunks, chunk, Hkv, v.shape[-1])
    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, g, D)
    q_pos = q_offset + jnp.arange(Sq)
    valid = Sk if k_valid_len is None else k_valid_len

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, c = inp
        k_pos = c * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(jnp.float32))
        s = s + _mask_bias(q_pos, k_pos, causal, window, valid)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        if p_bf16:  # halve the quadratic score traffic; denominator stays f32
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(jnp.bfloat16),
                            vb.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    Dv = v.shape[-1]
    m0 = jnp.full((B, Hkv, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Sq, Dv), jnp.float32)
    # remat per chunk: backward recomputes the block softmax instead of
    # saving (Sq, Sk) residuals — the flash-attention memory profile
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0),
        (jnp.swapaxes(kc, 0, 1), jnp.swapaxes(vc, 0, 1), jnp.arange(nchunks)),
        unroll=nchunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,Hkv,g,Sq,Dv)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, Hq, Dv)
    return out.astype(q.dtype)


def attention(q, k, v, *, impl="einsum", **kw):
    if impl == "einsum" or q.shape[1] == 1:
        kw.pop("chunk", None)
        kw.pop("unroll", None)
        kw.pop("p_bf16", None)
        return attention_einsum(q, k, v, **kw)
    if impl == "chunked":
        return attention_chunked(q, k, v, **kw)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, causal=kw.get("causal", True), window=kw.get("window"),
            q_offset=kw.get("q_offset", 0),
            interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def gqa_init(rng, cfg, dtype) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    r = jax.random.split(rng, 4)
    std = d ** -0.5
    return {
        "wq": normal_init(r[0], (d, hq * dh), std, dtype),
        "wk": normal_init(r[1], (d, hkv * dh), std, dtype),
        "wv": normal_init(r[2], (d, hkv * dh), std, dtype),
        "wo": normal_init(r[3], (hq * dh, d), (hq * dh) ** -0.5, dtype),
    }


def gqa_apply(p, cfg, x, *, positions, kv_cache=None, cache_len=None,
              impl="einsum", causal=True):
    """x (B,S,d). With kv_cache=(k,v) of (B,Smax,Hkv,Dh): write at positions,
    attend against the cache (prefill/decode); else self-attention."""
    B, S, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    freqs = rope_frequencies(dh, cfg.rope_pct, cfg.rope_theta)
    q = (x @ p["wq"]).reshape(B, S, hq, dh)
    k = (x @ p["wk"]).reshape(B, S, hkv, dh)
    v = (x @ p["wv"]).reshape(B, S, hkv, dh)
    q = apply_rope(q, positions, freqs)
    k = apply_rope(k, positions, freqs)

    if kv_cache is None:
        out = attention(q, k, v, impl=impl, causal=causal,
                        window=cfg.sliding_window, chunk=cfg.attn_chunk,
                        unroll=cfg.attn_unroll, p_bf16=cfg.attn_p_bf16)
        new_cache = None
    elif (cfg.swa_ring_cache and cfg.sliding_window is not None and S == 1
          and kv_cache[0].shape[1] <= cfg.sliding_window):
        # ring-buffer SWA cache: W slots, slot = pos % W; keys carry their
        # absolute positions for masking (unwritten slots pushed out of the
        # window). O(window) memory instead of O(seq_len).
        ck, cv = kv_cache
        W = ck.shape[1]
        win = cfg.sliding_window
        pv = (positions[:, 0] if positions.ndim == 2
              else jnp.broadcast_to(positions.reshape(-1)[0], (B,)))
        rows = jnp.arange(B)
        slot = pv % W
        ck = ck.at[rows, slot].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[rows, slot].set(v[:, 0].astype(cv.dtype))
        r = jnp.arange(W)
        abs_k = pv[:, None] - ((pv[:, None] - r[None, :]) % W)   # (B, W)
        abs_k = jnp.where(abs_k < 0, -2 * win, abs_k)            # warmup slots
        out = attention_einsum(q, ck, cv, causal=causal, window=win,
                               q_offset=pv[:, None], k_positions=abs_k)
        new_cache = (ck, cv)
    elif positions.ndim == 2 and S == 1:
        # per-row decode positions (continuous batching): scatter one token
        # into each row's slot, mask each row by its own valid length
        ck, cv = kv_cache
        rows = jnp.arange(B)
        pos_vec = positions[:, 0]
        ck = ck.at[rows, pos_vec].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[rows, pos_vec].set(v[:, 0].astype(cv.dtype))
        valid = cache_len if cache_len is not None else pos_vec + 1
        out = attention_einsum(q, ck, cv, causal=causal,
                               window=cfg.sliding_window,
                               q_offset=positions, k_valid_len=valid)
        new_cache = (ck, cv)
    else:
        ck, cv = kv_cache
        start = positions if positions.ndim == 0 else positions.reshape(-1)[0]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, start, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, start, 0, 0))
        q_off = start
        valid = (cache_len if cache_len is not None else start + S)
        out = attention(q, ck, cv, impl=impl, causal=causal,
                        window=cfg.sliding_window, q_offset=q_off,
                        k_valid_len=valid, chunk=cfg.attn_chunk,
                        unroll=cfg.attn_unroll, p_bf16=cfg.attn_p_bf16)
        new_cache = (ck, cv)
    y = out.reshape(B, S, hq * dh) @ p["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2). The KV cache holds only the
# compressed latent (kv_lora) + the decoupled rope key per position.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


def mla_init(rng, cfg, dtype) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    r = jax.random.split(rng, 6)
    std = d ** -0.5
    qk = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": normal_init(r[0], (d, m.q_lora), std, dtype),
        "q_ln": rmsnorm_init(m.q_lora, dtype),
        "wq_b": normal_init(r[1], (m.q_lora, h * qk), m.q_lora ** -0.5, dtype),
        "wkv_a": normal_init(r[2], (d, m.kv_lora + m.rope_head_dim), std, dtype),
        "kv_ln": rmsnorm_init(m.kv_lora, dtype),
        "wkv_b": normal_init(
            r[3], (m.kv_lora, h * (m.nope_head_dim + m.v_head_dim)),
            m.kv_lora ** -0.5, dtype),
        "wo": normal_init(r[4], (h * m.v_head_dim, d),
                          (h * m.v_head_dim) ** -0.5, dtype),
    }


def mla_apply(p, cfg, x, *, positions, latent_cache=None, cache_len=None,
              impl="einsum", causal=True):
    """latent_cache (B, Smax, kv_lora + rope_head_dim) — the MLA decode win:
    the per-token cache is 512+64 floats instead of 2*H*Dh."""
    m: MLAConfig = cfg.mla
    B, S, d = x.shape
    h = cfg.n_heads
    qk = m.nope_head_dim + m.rope_head_dim
    freqs = rope_frequencies(m.rope_head_dim, 1.0, cfg.rope_theta)

    q = (rmsnorm(p["q_ln"], x @ p["wq_a"]) @ p["wq_b"]).reshape(B, S, h, qk)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, freqs)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv_a = x @ p["wkv_a"]                                     # (B,S,kv_lora+rope)
    latent, k_rope = kv_a[..., :m.kv_lora], kv_a[..., m.kv_lora:]
    latent = rmsnorm(p["kv_ln"], latent)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, freqs)[:, :, 0, :]
    fresh = jnp.concatenate([latent, k_rope], axis=-1)

    if latent_cache is not None and positions.ndim == 2 and S == 1:
        # per-row decode positions (continuous batching)
        rows = jnp.arange(B)
        pos_vec = positions[:, 0]
        latent_cache = latent_cache.at[rows, pos_vec].set(
            fresh[:, 0].astype(latent_cache.dtype))
        all_lat = latent_cache[..., :m.kv_lora]
        all_rope = latent_cache[..., m.kv_lora:]
        q_off = positions
        valid = cache_len if cache_len is not None else pos_vec + 1
    elif latent_cache is not None:
        start = positions if positions.ndim == 0 else positions.reshape(-1)[0]
        latent_cache = jax.lax.dynamic_update_slice(
            latent_cache, fresh.astype(latent_cache.dtype), (0, start, 0))
        all_lat = latent_cache[..., :m.kv_lora]
        all_rope = latent_cache[..., m.kv_lora:]
        q_off = start
        valid = cache_len if cache_len is not None else start + S
    else:
        all_lat, all_rope = latent, k_rope
        q_off, valid = 0, None

    kv = (all_lat.astype(x.dtype) @ p["wkv_b"]).reshape(
        all_lat.shape[0], all_lat.shape[1], h, m.nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.nope_head_dim], kv[..., m.nope_head_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(all_rope[:, :, None, :].astype(x.dtype),
                                  k_nope.shape[:3] + (m.rope_head_dim,))], axis=-1)
    out = attention(q, k, v, impl=impl, causal=causal, q_offset=q_off,
                    k_valid_len=valid, scale=1.0 / jnp.sqrt(qk),
                    chunk=cfg.attn_chunk, unroll=cfg.attn_unroll,
                    p_bf16=cfg.attn_p_bf16)
    y = out.reshape(B, S, h * m.v_head_dim) @ p["wo"]
    return y, latent_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, ff: int, act: str, dtype) -> dict:
    r = jax.random.split(rng, 3)
    std = d ** -0.5
    p = {"w_up": normal_init(r[0], (d, ff), std, dtype),
         "w_down": normal_init(r[1], (ff, d), ff ** -0.5, dtype)}
    if act == "swiglu":
        p["w_gate"] = normal_init(r[2], (d, ff), std, dtype)
    return p


def mlp_apply(p, x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts — fixed-capacity dispatch, shared + routed experts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0            # always-active experts (DeepSeek-V2: 2)
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    dispatch_groups: int = 1       # >1: shard-local dispatch (per data shard,
                                   # capacity/groups each) — the all-to-all
                                   # expert-parallel pattern instead of a
                                   # global gather/combine over all tokens


def moe_init(rng, cfg, dtype) -> dict:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    r = jax.random.split(rng, 5)
    std = d ** -0.5
    e, fe = m.num_experts, m.d_expert
    p = {
        "router": normal_init(r[0], (d, e), std, jnp.float32),
        "w_gate": normal_init(r[1], (e, d, fe), std, dtype),
        "w_up": normal_init(r[2], (e, d, fe), std, dtype),
        "w_down": normal_init(r[3], (e, fe, d), fe ** -0.5, dtype),
    }
    if m.num_shared:
        p["shared"] = mlp_init(r[4], d, fe * m.num_shared, "swiglu", dtype)
    return p


def moe_apply(p, cfg, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y, aux_load_balance_loss).

    Fixed-capacity dispatch: position-in-expert via one-hot cumsum, scatter
    token ids into a routing table, gather/expert-matmul/scatter-add back.
    Overflowing tokens are dropped from routed experts (standard capacity
    semantics); shared experts always see every token.

    With ``dispatch_groups == G > 1`` routing/gather/combine run per token
    group (group dim sharded over the data axes, capacity/G per group): the
    cross-device exchange becomes the expert-parallel all-to-all instead of
    an all-token gather + full all-reduce (§Perf iteration, EXPERIMENTS.md).
    """
    m: MoEConfig = cfg.moe
    B, S, d = x.shape
    n = B * S
    G = m.dispatch_groups if n % m.dispatch_groups == 0 else 1
    ng = n // G
    xt = x.reshape(G, ng, d)
    logits = jnp.einsum("gnd,de->gne", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, ng, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)      # (G, ng, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                # renormalize

    cap = int(max(1, round(ng * m.top_k * m.capacity_factor / m.num_experts)))
    # (G, ng*k) flattened routing within each group
    flat_expert = expert_idx.reshape(G, -1)
    flat_gate = gate_vals.reshape(G, -1)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(ng), m.top_k)[None], (G, ng * m.top_k))
    onehot = jax.nn.one_hot(flat_expert, m.num_experts, dtype=jnp.int32)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - 1             # (G, ng*k, E)
    pos = jnp.take_along_axis(pos_in_expert, flat_expert[..., None],
                              axis=2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, pos, cap - 1)

    def route_one(fe, sl, tok, gate, kp):
        table = jnp.full((m.num_experts, cap), ng, jnp.int32)  # ng = pad id
        table = table.at[fe, sl].set(jnp.where(kp, tok, ng), mode="drop")
        gates = jnp.zeros((m.num_experts, cap), jnp.float32)
        gates = gates.at[fe, sl].set(jnp.where(kp, gate, 0.0), mode="drop")
        return table, gates

    table, gates = jax.vmap(route_one)(flat_expert, slot, flat_token,
                                       flat_gate, keep)        # (G, E, C)

    xpad = jnp.concatenate([xt, jnp.zeros((G, 1, d), xt.dtype)], axis=1)
    gathered = jax.vmap(lambda xp, tb: xp[tb])(xpad, table)    # (G, E, C, d)
    if cfg.act_sharding is not None and G > 1:
        from jax.sharding import PartitionSpec as P
        gathered = jax.lax.with_sharding_constraint(
            gathered, P(cfg.act_sharding[0], "model", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", gathered, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", gathered, p["w_up"])
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])         # (G, E, C, d)
    out = out * gates[..., None].astype(out.dtype)

    def combine_one(tb, o):
        return jnp.zeros((ng + 1, d), jnp.float32).at[tb.reshape(-1)].add(
            o.reshape(-1, d).astype(jnp.float32))[:ng]

    y = jax.vmap(combine_one)(table, out).astype(x.dtype)      # (G, ng, d)
    y = y.reshape(n, d)

    if m.num_shared:
        y = y + mlp_apply(p["shared"], xt.reshape(n, d), "swiglu")

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx, m.num_experts, dtype=jnp.float32),
        axis=(0, 1, 2))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = m.aux_loss_coef * m.num_experts * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(B, S, d), aux
