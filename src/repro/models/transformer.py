"""Composable transformer assembly: scan-over-layers LM covering all six
assigned families behind one ``ModelConfig``:

  dense  — GQA attention (+ optional sliding window / partial rotary)
  moe    — GQA or MLA attention + routed/shared experts
  ssm    — Mamba2 or RWKV6 mixers (attention-free)
  hybrid — Mamba2 stack with a *weight-shared* full-attention block applied
           every ``shared_attn_every`` layers (Zamba2)
  audio  — encoder-only (non-causal), consumes stub frame embeddings
  vlm    — decoder consuming [patch-embedding prefix | token embeddings]

Three entry points (the shapes the dry-run lowers):
  * :func:`apply`       — full-sequence logits (train / actor scoring)
  * :func:`prefill`     — apply + populate the decode cache
  * :func:`decode_step` — one token against a ``max_len`` cache

Layers are scanned with stacked parameters (small HLO, O(1) compile in
depth); the cache rides in the scan carry and is indexed per layer.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as ll
from repro.models import ssm as ssm_lib


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(rng: jax.Array, cfg) -> dict:
    dtype = cfg.p_dtype
    r = jax.random.split(rng, 4)
    p = {"pre_ln": ll.norm_init(cfg.norm, cfg.d_model, dtype),
         "post_ln": ll.norm_init(cfg.norm, cfg.d_model, dtype)}
    if cfg.mixer == "attn":
        p["mixer"] = ll.gqa_init(r[0], cfg, dtype)
    elif cfg.mixer == "mla":
        p["mixer"] = ll.mla_init(r[0], cfg, dtype)
    elif cfg.mixer == "mamba2":
        p["mixer"] = ssm_lib.mamba2_init(r[0], cfg, dtype)
    elif cfg.mixer == "rwkv6":
        p["mixer"] = ssm_lib.rwkv6_init(r[0], cfg, dtype)
    else:
        raise ValueError(cfg.mixer)
    if cfg.mlp == "dense":
        p["mlp"] = ll.mlp_init(r[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    elif cfg.mlp == "moe":
        p["mlp"] = ll.moe_init(r[1], cfg, dtype)
    elif cfg.mlp == "rwkv_cm":
        p["mlp"] = ssm_lib.rwkv6_channelmix_init(r[1], cfg, dtype)
    elif cfg.mlp != "none":
        raise ValueError(cfg.mlp)
    return p


def _shared_attn_init(rng: jax.Array, cfg) -> dict:
    dtype = cfg.p_dtype
    r = jax.random.split(rng, 2)
    return {
        "pre_ln": ll.norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": ll.gqa_init(r[0], cfg, dtype),
        "post_ln": ll.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": ll.mlp_init(r[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def init(cfg, rng: jax.Array) -> dict:
    dtype = cfg.p_dtype
    r = jax.random.split(rng, 4)
    params: dict[str, Any] = {}
    if cfg.input_mode in ("tokens", "mixed"):
        params["embed"] = {"w": ll.normal_init(
            r[0], (cfg.vocab_size, cfg.d_model), 0.02, dtype)}
    layer_rngs = jax.random.split(r[1], cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _layer_init(k, cfg))(layer_rngs)
    if cfg.shared_attn_every:
        params["shared_attn"] = _shared_attn_init(r[2], cfg)
    params["final_ln"] = ll.norm_init(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = {"w": ll.normal_init(
            r[3], (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5, dtype)}
    return params


def param_count(params: Any) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int) -> dict:
    """Stacked-over-layers decode cache; structure depends on the mixer."""
    L, B, S = cfg.n_layers, batch, max_len
    adt = cfg.act_dtype
    cache: dict[str, Any] = {}
    if cfg.mixer == "attn":
        s_alloc = S
        if cfg.swa_ring_cache and cfg.sliding_window is not None:
            s_alloc = min(S, cfg.sliding_window)   # O(window) ring
        kv = (L, B, s_alloc, cfg.n_kv_heads, cfg.head_dim)
        cache["k"] = jnp.zeros(kv, adt)
        cache["v"] = jnp.zeros(kv, adt)
    elif cfg.mixer == "mla":
        m = cfg.mla
        cache["latent"] = jnp.zeros((L, B, S, m.kv_lora + m.rope_head_dim), adt)
    elif cfg.mixer == "mamba2":
        s = cfg.ssm
        din = s.d_inner(cfg.d_model)
        conv_dim = din + 2 * s.ngroups * s.d_state
        cache["conv"] = jnp.zeros((L, B, s.conv_width - 1, conv_dim), adt)
        cache["ssm"] = jnp.zeros(
            (L, B, s.nheads(cfg.d_model), s.headdim, s.d_state), jnp.float32)
    elif cfg.mixer == "rwkv6":
        h = cfg.n_heads
        k = cfg.d_model // h
        cache["tm_prev"] = jnp.zeros((L, B, cfg.d_model), adt)
        cache["wkv"] = jnp.zeros((L, B, h, k, k), jnp.float32)
        cache["cm_prev"] = jnp.zeros((L, B, cfg.d_model), adt)
    if cfg.shared_attn_every:
        calls = cfg.n_layers // cfg.shared_attn_every
        kv = (calls, B, S, cfg.n_kv_heads, cfg.head_dim)
        cache["shared_k"] = jnp.zeros(kv, adt)
        cache["shared_v"] = jnp.zeros(kv, adt)
    return cache


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _split_layer_cache(cfg, lcache):
    """Layer-cache dict -> (mixer_cache, mlp_cache)."""
    if lcache is None:
        return None, None
    if cfg.mixer == "attn":
        return (lcache["k"], lcache["v"]), None
    if cfg.mixer == "mla":
        return lcache["latent"], None
    if cfg.mixer == "mamba2":
        return {"conv": lcache["conv"], "ssm": lcache["ssm"]}, None
    if cfg.mixer == "rwkv6":
        return ({"prev": lcache["tm_prev"], "wkv": lcache["wkv"]},
                {"prev": lcache["cm_prev"]})
    raise ValueError(cfg.mixer)


def _merge_layer_cache(cfg, mixer_cache, mlp_cache) -> dict:
    if cfg.mixer == "attn":
        return {"k": mixer_cache[0], "v": mixer_cache[1]}
    if cfg.mixer == "mla":
        return {"latent": mixer_cache}
    if cfg.mixer == "mamba2":
        return dict(mixer_cache)
    if cfg.mixer == "rwkv6":
        return {"tm_prev": mixer_cache["prev"], "wkv": mixer_cache["wkv"],
                "cm_prev": mlp_cache["prev"]}
    raise ValueError(cfg.mixer)


def _block(cfg, lp, x, positions, lcache, cache_len, impl):
    """One transformer block. Returns (x, new_layer_cache, aux_loss)."""
    mixer_cache, mlp_cache = _split_layer_cache(cfg, lcache)
    h = ll.apply_norm(cfg.norm, lp["pre_ln"], x)
    if cfg.mixer == "attn":
        y, mixer_cache = ll.gqa_apply(
            lp["mixer"], cfg, h, positions=positions, kv_cache=mixer_cache,
            cache_len=cache_len, impl=impl, causal=cfg.causal)
    elif cfg.mixer == "mla":
        y, mixer_cache = ll.mla_apply(
            lp["mixer"], cfg, h, positions=positions, latent_cache=mixer_cache,
            cache_len=cache_len, impl=impl, causal=cfg.causal)
    elif cfg.mixer == "mamba2":
        y, mixer_cache = ssm_lib.mamba2_apply(
            lp["mixer"], cfg, h, state=mixer_cache,
            return_state=lcache is not None)
    else:  # rwkv6
        y, mixer_cache = ssm_lib.rwkv6_timemix(
            lp["mixer"], cfg, h, state=mixer_cache,
            return_state=lcache is not None)
    x = x + y

    aux = jnp.zeros((), jnp.float32)
    if cfg.mlp != "none":  # Zamba2 mamba blocks carry no per-layer MLP
        h = ll.apply_norm(cfg.norm, lp["post_ln"], x)
        if cfg.mlp == "dense":
            y = ll.mlp_apply(lp["mlp"], h, cfg.act)
        elif cfg.mlp == "moe":
            y, aux = ll.moe_apply(lp["mlp"], cfg, h)
        else:  # rwkv_cm
            y, mlp_cache = ssm_lib.rwkv6_channelmix(
                lp["mlp"], cfg, h, state=mlp_cache, return_state=lcache is not None)
        x = x + y
    new_cache = None if lcache is None else _merge_layer_cache(cfg, mixer_cache, mlp_cache)
    return x, new_cache, aux


def _shared_block(cfg, sp, x, positions, kv_cache, cache_len, impl):
    """Zamba2's weight-shared full-attention block (one param set, applied at
    every ``shared_attn_every``-th layer)."""
    h = ll.apply_norm(cfg.norm, sp["pre_ln"], x)
    y, kv_cache = ll.gqa_apply(sp["attn"], cfg, h, positions=positions,
                               kv_cache=kv_cache, cache_len=cache_len,
                               impl=impl, causal=cfg.causal)
    x = x + y
    h = ll.apply_norm(cfg.norm, sp["post_ln"], x)
    x = x + ll.mlp_apply(sp["mlp"], h, cfg.act)
    return x, kv_cache


# ---------------------------------------------------------------------------
# Forward core
# ---------------------------------------------------------------------------

def _constrain(x, spec):
    """Residual-stream sharding constraint (needs an active mesh context)."""
    if spec is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _forward(cfg, params, x, positions, cache=None, cache_len=None, impl=None):
    """Layer stack. Returns (hidden, new_cache, aux_total).

    ``cfg.scan_layers=True`` (default): lax.scan over stacked layer params —
    O(1) HLO in depth, the production training path. ``False``: unrolled
    python loop — used by the dry-run so XLA's cost analysis and collective
    accounting see every layer (while-loop bodies are counted once).
    """
    impl = impl or cfg.attn_impl
    has_cache = cache is not None
    shared = params.get("shared_attn")
    every = cfg.shared_attn_every

    layer_cache = None
    shared_cache = None
    if has_cache:
        layer_cache = {k: v for k, v in cache.items()
                       if not k.startswith("shared_")}
        if every:
            shared_cache = (cache["shared_k"], cache["shared_v"])

    if not cfg.scan_layers:
        return _forward_unrolled(cfg, params, x, positions, layer_cache,
                                 shared_cache, cache_len, impl, shared, every,
                                 has_cache)

    def body(carry, xs):
        x, lcache_all, sh_cache, aux = carry
        lp, idx = xs
        lcache = (None if not has_cache else jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0, keepdims=False),
            lcache_all))
        x, new_lcache, a = _block(cfg, lp, x, positions, lcache, cache_len, impl)
        if has_cache:
            lcache_all = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), idx, 0), lcache_all, new_lcache)
        if shared is not None:
            def with_shared(operands):
                x, sh = operands
                s_idx = idx // every
                if sh is not None:
                    sk = jax.lax.dynamic_index_in_dim(sh[0], s_idx, 0, False)
                    sv = jax.lax.dynamic_index_in_dim(sh[1], s_idx, 0, False)
                    x, (nk, nv) = _shared_block(cfg, shared, x, positions,
                                                (sk, sv), cache_len, impl)
                    sh = (jax.lax.dynamic_update_index_in_dim(sh[0], nk, s_idx, 0),
                          jax.lax.dynamic_update_index_in_dim(sh[1], nv, s_idx, 0))
                else:
                    x, _ = _shared_block(cfg, shared, x, positions, None,
                                         cache_len, impl)
                return x, sh

            def without_shared(operands):
                return operands

            x, sh_cache = jax.lax.cond(
                (idx + 1) % every == 0, with_shared, without_shared,
                (x, sh_cache))
        return (x, lcache_all, sh_cache, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, layer_cache, shared_cache, aux), _ = jax.lax.scan(
        body_fn, (x, layer_cache, shared_cache,
                  jnp.zeros((), jnp.float32)),
        (params["layers"], idxs))

    new_cache = None
    if has_cache:
        new_cache = dict(layer_cache)
        if every:
            new_cache["shared_k"], new_cache["shared_v"] = shared_cache
    return x, new_cache, aux


def _forward_unrolled(cfg, params, x, positions, layer_cache, shared_cache,
                      cache_len, impl, shared, every, has_cache):
    """Python loop over layers (static indices); per-layer remat when
    cfg.remat; residual-stream sharding constraint per layer."""
    aux = jnp.zeros((), jnp.float32)

    def one_layer(x, lp, lcache, sh_slice):
        x = _constrain(x, cfg.act_sharding)
        x, new_lcache, a = _block(cfg, lp, x, positions, lcache, cache_len, impl)
        new_sh = None
        if sh_slice is not None:
            x, new_sh = _shared_block(cfg, shared, x, positions, sh_slice,
                                      cache_len, impl)
        return x, new_lcache, a, new_sh

    layer_fn = jax.checkpoint(one_layer) if cfg.remat else one_layer

    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        lcache = (None if not has_cache else
                  jax.tree.map(lambda c: c[i], layer_cache))
        applies_shared = shared is not None and (i + 1) % every == 0
        sh_slice = None
        s_idx = i // every if every else 0
        if applies_shared and shared_cache is not None:
            sh_slice = (shared_cache[0][s_idx], shared_cache[1][s_idx])
        if applies_shared and shared_cache is None:
            # train path: shared block without cache
            def layer_with_shared(x, lp, lcache):
                x = _constrain(x, cfg.act_sharding)
                x, new_lcache, a = _block(cfg, lp, x, positions, lcache,
                                          cache_len, impl)
                x, _ = _shared_block(cfg, shared, x, positions, None,
                                     cache_len, impl)
                return x, new_lcache, a
            fn = jax.checkpoint(layer_with_shared) if cfg.remat else layer_with_shared
            x, new_lcache, a = fn(x, lp, lcache)
            new_sh = None
        else:
            x, new_lcache, a, new_sh = layer_fn(x, lp, lcache, sh_slice)
        aux = aux + a
        if has_cache:
            layer_cache = jax.tree.map(
                lambda c, n: c.at[i].set(n.astype(c.dtype)),
                layer_cache, new_lcache)
        if new_sh is not None:
            shared_cache = (shared_cache[0].at[s_idx].set(new_sh[0]),
                            shared_cache[1].at[s_idx].set(new_sh[1]))

    new_cache = None
    if has_cache:
        new_cache = dict(layer_cache)
        if every:
            new_cache["shared_k"], new_cache["shared_v"] = shared_cache
    return x, new_cache, aux


def _embed_inputs(cfg, params, tokens, embeddings, prefix_embeddings):
    if cfg.input_mode == "embeddings":
        return embeddings.astype(cfg.act_dtype)
    x = params["embed"]["w"][tokens].astype(cfg.act_dtype)
    if cfg.input_mode == "mixed" and prefix_embeddings is not None:
        x = jnp.concatenate(
            [prefix_embeddings.astype(cfg.act_dtype), x], axis=1)
    return x


def _head(cfg, params, x):
    ln = ll.apply_norm(cfg.norm, params["final_ln"], x)
    w = (params["embed"]["w"].T if cfg.tie_embeddings else params["head"]["w"])
    return jnp.einsum("bsd,dv->bsv", ln, w.astype(ln.dtype),
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def apply(params, tokens=None, *, cfg, embeddings=None, prefix_embeddings=None,
          return_aux=False, impl=None):
    """Full-sequence logits (training / actor-side priority scoring)."""
    x = _embed_inputs(cfg, params, tokens, embeddings, prefix_embeddings)
    positions = jnp.arange(x.shape[1])
    x, _, aux = _forward(cfg, params, x, positions, impl=impl)
    logits = _head(cfg, params, x)
    return (logits, aux) if return_aux else logits


def prefill(params, tokens=None, *, cfg, cache, embeddings=None,
            prefix_embeddings=None, impl=None):
    """Populate the decode cache with a prompt; returns (logits, cache)."""
    x = _embed_inputs(cfg, params, tokens, embeddings, prefix_embeddings)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, cache, _ = _forward(cfg, params, x, positions, cache=cache,
                           cache_len=jnp.asarray(S), impl=impl)
    return _head(cfg, params, x), cache


def prefill_chunk(params, tokens, start, *, cfg, cache, impl=None):
    """Chunked prefill: write ``tokens`` (B, C) into the cache segment at
    absolute offset ``start`` (a traced scalar — one shared start across
    rows, which is what the S>1 cache-write path supports). Positions are
    ``start + arange(C)`` and the valid length after the chunk is
    ``start + C``, so a prompt split into chunks reproduces
    :func:`prefill` of the concatenation. Returns (logits, cache)."""
    x = _embed_inputs(cfg, params, tokens, None, None)
    S = x.shape[1]
    start = jnp.asarray(start, jnp.int32)
    positions = start + jnp.arange(S, dtype=jnp.int32)
    x, cache, _ = _forward(cfg, params, x, positions, cache=cache,
                           cache_len=start + S, impl=impl)
    return _head(cfg, params, x), cache


def decode_step(params, token, pos, *, cfg, cache, impl=None):
    """One-token step: token (B, 1) int32; pos is either a scalar int32
    (all rows at the same position) or a (B,) vector of per-row positions
    (continuous batching — rows decode at independent offsets)."""
    x = params["embed"]["w"][token].astype(cfg.act_dtype) \
        if cfg.input_mode != "embeddings" else token
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        positions = pos[:, None]                   # (B, 1) per-row
    else:
        positions = jnp.full((1,), pos, jnp.int32)
    x, cache, _ = _forward(cfg, params, x, positions, cache=cache,
                           cache_len=pos + 1, impl=impl)
    return _head(cfg, params, x), cache
