"""Architecture registry: ``--arch <id>`` -> ModelConfig + input specs.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every model
input of a given (arch, shape, step-kind) — weak-type-correct, shardable,
zero allocation — which is what the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig, shape_applicable

_ARCH_MODULES = {
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe_42b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def input_specs(cfg: ModelConfig, shape: InputShape | str,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch, input-shape) combination.

    train/prefill: {tokens|embeddings[, prefix_embeddings], labels, is_weights}
    decode:        {token, pos} (+ cache built separately via init_cache specs)
    """
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.arch_id} x {shape.name} skipped: {why}")
    B = batch_override or shape.global_batch
    S = shape.seq_len
    f = jax.ShapeDtypeStruct
    i32, adt = jnp.int32, cfg.act_dtype

    if shape.kind in ("train", "prefill"):
        specs: dict = {}
        if cfg.input_mode == "embeddings":      # audio stub frontend
            specs["embeddings"] = f((B, S, cfg.d_model), adt)
        elif cfg.input_mode == "mixed":         # vlm stub frontend
            p = min(cfg.prefix_len, S // 2)
            specs["prefix_embeddings"] = f((B, p, cfg.d_model), adt)
            specs["tokens"] = f((B, S - p), i32)
        else:
            specs["tokens"] = f((B, S), i32)
        specs["labels"] = f((B, S), i32)
        if shape.kind == "train":
            specs["is_weights"] = f((B,), jnp.float32)
        return specs

    # decode: one new token against a seq_len cache
    return {"token": f((B, 1), i32), "pos": f((), i32)}


def cache_specs(cfg: ModelConfig, shape: InputShape | str,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStructs matching transformer.init_cache (no allocation)."""
    from repro.models import transformer
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    B = batch_override or shape.global_batch
    shapes = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, shape.seq_len))
    return shapes


def combos(include_skipped: bool = False):
    """All (arch, shape) pairs with applicability verdicts."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in INPUT_SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield arch_id, shape.name, ok, why
