"""State-space / linear-recurrence mixers: Mamba2 (SSD) and RWKV6 (Finch).

Both are *attention-free* token mixers with O(1)-per-token decode state — the
archs that make ``long_500k`` feasible. The Ape-X priority machinery is
mixer-agnostic (DESIGN.md §Arch-applicability): these blocks slot into the
same transformer skeleton as attention.

Baseline training path is a time scan (exact); the chunked block-parallel SSD
formulation is a §Perf hillclimb, not baseline. Decode is the single-step
recurrence with carried state:
  * Mamba2 : conv ring (W-1 inputs) + SSM state (H, P, N)
  * RWKV6  : prev-token vectors + WKV matrix state (H, K, K)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    headdim: int = 64
    conv_width: int = 4
    expand: int = 2
    ngroups: int = 1
    chunk: int = 64        # SSD block length for the chunked (matmul) path

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


def mamba2_init(rng, cfg, dtype) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    din, h, n, g = s.d_inner(d), s.nheads(d), s.d_state, s.ngroups
    conv_dim = din + 2 * g * n
    r = jax.random.split(rng, 4)
    std = d ** -0.5
    return {
        "in_proj": normal_init(r[0], (d, 2 * din + 2 * g * n + h), std, dtype),
        "conv_w": normal_init(r[1], (s.conv_width, conv_dim), 0.1, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(din, dtype),
        "out_proj": normal_init(r[2], (din, d), din ** -0.5, dtype),
    }


def _causal_conv(x, w, b, init_state=None):
    """Depthwise causal conv along time. x (B,L,C), w (W,C) -> (B,L,C).
    ``init_state`` (B,W-1,C) carries context across prefill/decode chunks."""
    W = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    final = xp[:, -(W - 1):] if W > 1 else init_state
    return out + b, final


def _ssd_scan(xin, Bh, Ch, decay, dt, h0):
    """Exact sequential SSD recurrence (oracle + decode path).
    xin (B,L,h,P), Bh/Ch (B,L,h,N), decay/dt (B,L,h), h0 (B,h,P,N)."""

    def step(hs, inp):
        xt, bt, ct, dct, dtt = inp
        dbx = jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt.astype(jnp.float32),
                         bt.astype(jnp.float32))
        hs = dct[..., None, None] * hs + dbx
        yt = jnp.einsum("bhpn,bhn->bhp", hs, ct.astype(jnp.float32))
        return hs, yt

    seq = tuple(jnp.swapaxes(t, 0, 1) for t in (xin, Bh, Ch, decay, dt))
    h_final, y = jax.lax.scan(step, h0, seq)
    return jnp.swapaxes(y, 0, 1), h_final


def _ssd_chunked(xin, Bh, Ch, decay, dt, h0, Q):
    """Block-parallel SSD (Mamba2's chunked algorithm, TPU-native):
    within-chunk contributions become (Q x Q) masked matmuls on the MXU;
    only a short cross-chunk scan (L/Q steps) over (B,h,P,N) states remains.
    Exactly equal to `_ssd_scan` (log-space decays, all exponents <= 0).
    """
    B, L, H, P = xin.shape
    N = Bh.shape[-1]
    pad = (-L) % Q
    if pad:
        z2 = lambda t, cv=0.0: jnp.pad(
            t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2),
            constant_values=cv)
        xin, Bh, Ch, dt = z2(xin), z2(Bh), z2(Ch), z2(dt)
        decay = z2(decay, 1.0)
    C = xin.shape[1] // Q
    shp = lambda t: t.reshape((B, C, Q) + t.shape[2:])
    xin, Bh, Ch, dt = map(shp, (xin.astype(jnp.float32),
                                Bh.astype(jnp.float32),
                                Ch.astype(jnp.float32), dt))
    la = jnp.log(jnp.maximum(shp(decay), 1e-30))              # (B,C,Q,H) <= 0
    cl = jnp.cumsum(la, axis=2)                               # inclusive

    # intra-chunk: y_t += sum_{s<=t} exp(cl_t - cl_s) dt_s (C_t.B_s) x_s
    G = jnp.einsum("bcqhn,bcshn->bchqs", Ch, Bh)
    clh = jnp.swapaxes(cl, 2, 3)                              # (B,C,H,Q)
    Dm = jnp.exp(clh[..., :, None] - clh[..., None, :])       # (B,C,H,Q,S)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    dt_s = jnp.swapaxes(dt, 2, 3)[..., None, :]               # (B,C,H,1,S)
    M = jnp.where(mask, G * Dm, 0.0) * dt_s
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", M, xin)

    # per-chunk state delta: sum_s exp(cl_last - cl_s) dt_s x_s (x) B_s
    T = jnp.exp(cl[:, :, -1:, :] - cl) * dt                   # (B,C,Q,H)
    delta = jnp.einsum("bcqhp,bcqhn->bchpn", xin * T[..., None], Bh)
    chunk_decay = jnp.exp(cl[:, :, -1, :])                    # (B,C,H)

    # cross-chunk scan: h_{c+1} = chunk_decay_c * h_c + delta_c
    def step(hs, inp):
        dct, dl = inp                                          # (B,H), (B,H,P,N)
        h_start = hs
        hs = dct[..., None, None] * hs + dl
        return hs, h_start

    h_final, h_starts = jax.lax.scan(
        step, h0, (jnp.swapaxes(chunk_decay, 0, 1),
                   jnp.swapaxes(delta, 0, 1)))
    h_starts = jnp.swapaxes(h_starts, 0, 1)                   # (B,C,H,P,N)

    # inter-chunk: y_t += C_t . (exp(cl_t) h_start)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         Ch * jnp.exp(cl)[..., None], h_starts)
    y = (y_intra + y_inter).reshape(B, C * Q, H, P)[:, :L]
    return y, h_final


def mamba2_apply(p, cfg, x, *, state=None, return_state=False, method="auto"):
    """x (B,L,d) -> (y, new_state|None).

    method: "scan" (exact sequential oracle; always used for decode),
    "chunked" (block-parallel SSD — the TPU training path), or "auto".
    state = {"conv": (B,W-1,conv_dim), "ssm": (B,H,P,N)} for streaming decode.
    """
    s: SSMConfig = cfg.ssm
    B, L, d = x.shape
    din, h, n, g = s.d_inner(d), s.nheads(d), s.d_state, s.ngroups
    P = s.headdim

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * g * n], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc, conv_final = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xin, Bmat, Cmat = jnp.split(xbc, [din, din + g * n], axis=-1)
    xin = xin.reshape(B, L, h, P)
    Bmat = Bmat.reshape(B, L, g, n)
    Cmat = Cmat.reshape(B, L, g, n)
    # broadcast groups over heads (g == 1 typical)
    rep = h // g
    Bh = jnp.repeat(Bmat, rep, axis=2)                        # (B,L,h,n)
    Ch = jnp.repeat(Cmat, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,L,h)
    decay = jnp.exp(-jnp.exp(p["A_log"]) * dt)                   # (B,L,h)

    h0 = (jnp.zeros((B, h, P, n), jnp.float32) if state is None
          else state["ssm"].astype(jnp.float32))

    if method == "auto":
        method = "chunked" if L >= 2 * s.chunk else "scan"
    if method == "chunked":
        y, h_final = _ssd_chunked(xin, Bh, Ch, decay, dt, h0, s.chunk)
    else:
        y, h_final = _ssd_scan(xin, Bh, Ch, decay, dt, h0)
    y = y + p["D"][:, None] * xin.astype(jnp.float32)
    y = y.reshape(B, L, din).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]
    if return_state:
        return out, {"conv": conv_final, "ssm": h_final.astype(jnp.float32)}
    return out, None


# ---------------------------------------------------------------------------
# RWKV6 ("Finch") — data-dependent per-channel decay, matrix-valued state
# ---------------------------------------------------------------------------

def rwkv6_init(rng, cfg, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    k = d // h
    r = jax.random.split(rng, 10)
    std = d ** -0.5
    lora = 64
    return {
        "mix": 0.5 * jnp.ones((5, d), jnp.float32),            # r,k,v,g,w lerps
        "wr": normal_init(r[0], (d, d), std, dtype),
        "wk": normal_init(r[1], (d, d), std, dtype),
        "wv": normal_init(r[2], (d, d), std, dtype),
        "wg": normal_init(r[3], (d, d), std, dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),               # base decay (slow)
        "w_lora_a": normal_init(r[4], (d, lora), std, jnp.float32),
        "w_lora_b": normal_init(r[5], (lora, d), lora ** -0.5, jnp.float32),
        "u": normal_init(r[6], (h, k), 0.5, jnp.float32),      # bonus
        "ln_x": {"scale": jnp.ones((d,), jnp.float32),
                 "bias": jnp.zeros((d,), jnp.float32)},
        "wo": normal_init(r[7], (d, d), std, dtype),
    }


def _shift(x, prev=None):
    """Token shift: y_t = x_{t-1}; first slot comes from ``prev`` (decode)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None] if prev.ndim == 2 else prev
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _group_norm(p, x, h):
    """Per-head layernorm on (B,L,d) viewed as (B,L,h,k)."""
    B, L, d = x.shape
    xh = x.reshape(B, L, h, d // h).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    y = ((xh - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, L, d)
    return y * p["scale"] + p["bias"]


RWKV_CHUNK = 16  # f32-safe with the decay floor below (exp range <= e^80)
RWKV_DECAY_FLOOR = 5.0  # log-decay clamp: w >= exp(-5) per step


def _wkv_scan(r, key, val, w, u, s0):
    """Exact sequential WKV recurrence (oracle + decode path).
    r/key/val/w (B,L,h,k), u (h,k), s0 (B,h,k,k)."""

    def step(s, inp):
        rt, kt, vt, wt = inp                                   # (B,h,k) each
        kv = jnp.einsum("bhi,bhj->bhij", kt.astype(jnp.float32),
                        vt.astype(jnp.float32))
        out = jnp.einsum("bhi,bhij->bhj", rt.astype(jnp.float32),
                         s + u[:, :, None] * kv)
        s = wt.astype(jnp.float32)[..., None] * s + kv
        return s, out

    seq = tuple(jnp.swapaxes(t, 0, 1) for t in (r, key, val, w))
    s_final, out = jax.lax.scan(step, s0, seq)
    return jnp.swapaxes(out, 0, 1), s_final


def _wkv_chunked(r, key, val, w, u, s0, Q=RWKV_CHUNK):
    """Block-parallel WKV (flash-linear-attention style) with per-channel
    decays normalized to the chunk start: within-chunk terms become masked
    (Q x Q) matmuls; only an L/Q cross-chunk scan over (B,h,k,k) states
    remains. Exact vs `_wkv_scan` given the shared decay floor."""
    B, L, H, K = r.shape
    pad = (-L) % Q
    if pad:
        z = lambda t, cv=0.0: jnp.pad(
            t, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=cv)
        r, key, val = z(r), z(key), z(val)
        w = z(w, 1.0)
    C = r.shape[1] // Q
    shp = lambda t: t.astype(jnp.float32).reshape(B, C, Q, H, K)
    r, key, val, w = map(shp, (r, key, val, w))
    lw = jnp.log(jnp.maximum(w, 1e-30))                        # <= 0
    cl = jnp.cumsum(lw, axis=2)                                # inclusive
    cl_prev = cl - lw                                          # exclusive

    r_tilde = r * jnp.exp(cl_prev)                             # <= |r|
    k_tilde = key * jnp.exp(-cl)                               # <= |k| e^(floor*Q)
    M = jnp.einsum("bcqhk,bcshk->bchqs", r_tilde, k_tilde)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)              # strictly s < t
    M = jnp.where(mask, M, 0.0)
    y_intra = jnp.einsum("bchqs,bcshv->bcqhv", M, val)
    bonus = jnp.sum(r * u * key, axis=-1, keepdims=True)       # (B,C,Q,H,1)
    y_intra = y_intra + bonus * val

    cl_last = cl[:, :, -1:, :, :]                              # (B,C,1,H,K)
    k2 = key * jnp.exp(cl_last - cl)
    delta = jnp.einsum("bcqhk,bcqhv->bchkv", k2, val)
    chunk_decay = jnp.exp(cl_last[:, :, 0])                    # (B,C,H,K)

    def step(s, inp):
        dct, dl = inp                                          # (B,H,K),(B,H,K,V)
        s_start = s
        s = dct[..., None] * s + dl
        return s, s_start

    s_final, s_starts = jax.lax.scan(
        step, s0, (jnp.swapaxes(chunk_decay, 0, 1),
                   jnp.swapaxes(delta, 0, 1)))
    s_starts = jnp.swapaxes(s_starts, 0, 1)                    # (B,C,H,K,V)
    y_inter = jnp.einsum("bcqhk,bchkv->bcqhv", r_tilde, s_starts)
    y = (y_intra + y_inter).reshape(B, C * Q, H, K)[:, :L]
    return y, s_final


def rwkv6_timemix(p, cfg, x, *, state=None, return_state=False, method="auto"):
    """x (B,L,d) -> (y, state). state = {"prev": (B,d), "wkv": (B,h,k,k)}."""
    B, L, d = x.shape
    h = cfg.n_heads
    k = d // h
    prev = None if state is None else state["prev"]
    xs = _shift(x, prev)
    mix = p["mix"].astype(x.dtype)
    lerp = lambda i: x + mix[i] * (xs - x)
    r = (lerp(0) @ p["wr"]).reshape(B, L, h, k)
    key = (lerp(1) @ p["wk"]).reshape(B, L, h, k)
    val = (lerp(2) @ p["wv"]).reshape(B, L, h, k)
    gate = jax.nn.silu(lerp(3) @ p["wg"])
    # data-dependent decay (the Finch contribution); the decay floor keeps
    # the chunked path's normalized exponents inside the f32 range
    wx = lerp(4).astype(jnp.float32)
    w = p["w0"] + jnp.tanh(wx @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.minimum(jnp.exp(w), RWKV_DECAY_FLOOR))
    w = w.reshape(B, L, h, k)                                  # in (0,1)

    s0 = (jnp.zeros((B, h, k, k), jnp.float32) if state is None
          else state["wkv"].astype(jnp.float32))
    u = p["u"]

    if getattr(cfg, "mixer_head_shard", False) and cfg.act_sharding:
        # head-parallel recurrence: heads over `model`, sequence local — the
        # cross-chunk scan then never iterates over a sharded dimension
        from jax.sharding import PartitionSpec as P
        spec = P(cfg.act_sharding[0], None, "model", None)
        r, key, val, w = (jax.lax.with_sharding_constraint(t, spec)
                          for t in (r, key, val, w))

    if method == "auto":
        method = "chunked" if L >= 2 * RWKV_CHUNK else "scan"
    if method == "chunked":
        out, s_final = _wkv_chunked(r, key, val, w, u, s0)
    else:
        out, s_final = _wkv_scan(r, key, val, w, u, s0)
    out = out.reshape(B, L, d)
    out = _group_norm(p["ln_x"], out, h).astype(x.dtype)
    y = (out * gate) @ p["wo"]
    if return_state:
        return y, {"prev": x[:, -1], "wkv": s_final}
    return y, None


def rwkv6_channelmix_init(rng, cfg, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    r = jax.random.split(rng, 3)
    std = d ** -0.5
    return {
        "mix": 0.5 * jnp.ones((2, d), jnp.float32),            # k, r lerps
        "wk": normal_init(r[0], (d, ff), std, dtype),
        "wv": normal_init(r[1], (ff, d), ff ** -0.5, dtype),
        "wr": normal_init(r[2], (d, d), std, dtype),
    }


def rwkv6_channelmix(p, cfg, x, *, state=None, return_state=False):
    prev = None if state is None else state["prev"]
    xs = _shift(x, prev)
    mix = p["mix"].astype(x.dtype)
    xk = x + mix[0] * (xs - x)
    xr = x + mix[1] * (xs - x)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    y = jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])
    if return_state:
        return y, {"prev": x[:, -1]}
    return y, None
