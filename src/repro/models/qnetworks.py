"""Function approximators for Ape-X DQN / DPG — pure-JAX, from scratch.

* Dueling double-DQN network (Wang et al. 2016): the paper uses "the same
  network as in the Dueling DDQN agent" — Nature-CNN torso (conv 32x8x8/4,
  64x4x4/2, 64x3x3/1, fc512) + value/advantage streams. An MLP torso variant
  serves vector observations (ChainWorld / unit tests).
* DPG actor & critic (Appendix D): critic 400 -> tanh -> 300; actor
  300 -> tanh -> 200, tanh-squashed actions.

Parameters are plain nested dicts so they shard/checkpoint like every other
pytree in the framework.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def _uniform_init(rng, shape, scale):
    return jax.random.uniform(rng, shape, jnp.float32, -scale, scale)


def dense_init(rng, d_in, d_out):
    scale = jnp.sqrt(6.0 / (d_in + d_out))  # glorot uniform
    w_rng, b_rng = jax.random.split(rng)
    return {"w": _uniform_init(w_rng, (d_in, d_out), scale),
            "b": jnp.zeros((d_out,), jnp.float32)}


def dense(p, x):
    return x @ p["w"] + p["b"]


def conv_init(rng, h, w, c_in, c_out):
    fan_in = h * w * c_in
    scale = jnp.sqrt(2.0 / fan_in)  # he
    return {"w": scale * jax.random.normal(rng, (h, w, c_in, c_out), jnp.float32),
            "b": jnp.zeros((c_out,), jnp.float32)}


def conv(p, x, stride):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _prep_obs(obs: jax.Array) -> jax.Array:
    if obs.dtype == jnp.uint8:
        return obs.astype(jnp.float32) / 255.0
    return obs.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Dueling DQN
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DuelingDQN:
    """Dueling Q-network; conv torso for image obs, MLP torso for vectors."""

    num_actions: int
    torso: str = "mlp"               # "mlp" | "nature_cnn"
    mlp_hidden: tuple[int, ...] = (256, 256)
    head_hidden: int = 512

    def init(self, rng: jax.Array, obs_example: jax.Array) -> Any:
        rngs = jax.random.split(rng, 8)
        p: dict[str, Any] = {}
        x = _prep_obs(obs_example[None]) if obs_example.ndim in (1, 3) else _prep_obs(obs_example)
        if self.torso == "nature_cnn":
            p["c1"] = conv_init(rngs[0], 8, 8, x.shape[-1], 32)
            p["c2"] = conv_init(rngs[1], 4, 4, 32, 64)
            p["c3"] = conv_init(rngs[2], 3, 3, 64, 64)
            x = jax.nn.relu(conv(p["c1"], x, 4))
            x = jax.nn.relu(conv(p["c2"], x, 2))
            x = jax.nn.relu(conv(p["c3"], x, 1))
            feat = x.reshape(x.shape[0], -1).shape[-1]
        else:
            feat = x.shape[-1]
            for i, h in enumerate(self.mlp_hidden):
                p[f"fc{i}"] = dense_init(rngs[i], feat, h)
                feat = h
        p["val1"] = dense_init(rngs[4], feat, self.head_hidden)
        p["val2"] = dense_init(rngs[5], self.head_hidden, 1)
        p["adv1"] = dense_init(rngs[6], feat, self.head_hidden)
        p["adv2"] = dense_init(rngs[7], self.head_hidden, self.num_actions)
        return p

    def apply(self, params: Any, obs: jax.Array) -> jax.Array:
        """obs (B, ...) -> q-values (B, num_actions)."""
        x = _prep_obs(obs)
        if self.torso == "nature_cnn":
            x = jax.nn.relu(conv(params["c1"], x, 4))
            x = jax.nn.relu(conv(params["c2"], x, 2))
            x = jax.nn.relu(conv(params["c3"], x, 1))
            x = x.reshape(x.shape[0], -1)
        else:
            i = 0
            while f"fc{i}" in params:
                x = jax.nn.relu(dense(params[f"fc{i}"], x))
                i += 1
        v = dense(params["val2"], jax.nn.relu(dense(params["val1"], x)))       # (B, 1)
        a = dense(params["adv2"], jax.nn.relu(dense(params["adv1"], x)))       # (B, A)
        return v + a - a.mean(axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# DPG actor / critic (Appendix D)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPGActor:
    action_dim: int
    hidden: tuple[int, int] = (300, 200)

    def init(self, rng: jax.Array, obs_example: jax.Array) -> Any:
        r = jax.random.split(rng, 3)
        d = obs_example.shape[-1]
        return {
            "fc0": dense_init(r[0], d, self.hidden[0]),
            "fc1": dense_init(r[1], self.hidden[0], self.hidden[1]),
            "out": dense_init(r[2], self.hidden[1], self.action_dim),
        }

    def apply(self, params: Any, obs: jax.Array) -> jax.Array:
        x = _prep_obs(obs)
        x = jnp.tanh(dense(params["fc0"], x))
        x = jax.nn.relu(dense(params["fc1"], x))
        return jnp.tanh(dense(params["out"], x))


@dataclasses.dataclass(frozen=True)
class DPGCritic:
    hidden: tuple[int, int] = (400, 300)

    def init(self, rng: jax.Array, obs_example: jax.Array, action_example: jax.Array) -> Any:
        r = jax.random.split(rng, 3)
        d = obs_example.shape[-1] + action_example.shape[-1]
        return {
            "fc0": dense_init(r[0], d, self.hidden[0]),
            "fc1": dense_init(r[1], self.hidden[0], self.hidden[1]),
            "out": dense_init(r[2], self.hidden[1], 1),
        }

    def apply(self, params: Any, obs: jax.Array, action: jax.Array) -> jax.Array:
        x = jnp.concatenate([_prep_obs(obs), action.astype(jnp.float32)], axis=-1)
        x = jnp.tanh(dense(params["fc0"], x))
        x = jax.nn.relu(dense(params["fc1"], x))
        return dense(params["out"], x)[..., 0]
