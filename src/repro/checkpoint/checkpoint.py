"""Checkpointing — the paper's failure-tolerance story (Appendix F).

"All stateful parts of the system must periodically save their work and be
able to resume": here the learner state (params, optimizer, counters) and the
replay state are saved; actor state is deliberately *not* — actors are pure
functions of (params, rng) and are rebuilt on restart, exactly as the paper's
actors are restartable at any time with only a temporary dip in ingest rate.

Format: a single ``.npz`` per checkpoint with flattened pytree paths as keys,
plus a tiny JSON sidecar for tree structure. Device-sharded arrays are pulled
to host; restore re-shards via the caller's jit/sharding.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, step: int | None = None) -> str:
    """Atomically write ``tree`` to ``path`` (a .npz file).

    The ``.npz`` rename is the commit point: the JSON sidecar lands (itself
    via tmp + ``os.replace``) *before* the array file is renamed into place,
    so a crash at any instant leaves either a fully usable checkpoint or, at
    worst, an orphan sidecar/tmp that ``latest()`` ignores and the next
    ``save`` sweeps up. A failed ``np.savez`` never leaks its tmp file.
    """
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    _sweep_stale_tmp(directory)
    flat = _flatten(tree)
    tmp = path + ".tmp"
    # np.savez appends .npz to names without it
    actual_tmp = tmp if tmp.endswith(".npz") else tmp + ".npz"
    try:
        np.savez(tmp, **flat)
        meta = {"step": step, "keys": sorted(flat.keys())}
        meta_tmp = path + ".json.tmp"
        with open(meta_tmp, "w") as f:
            json.dump(meta, f)
        os.replace(meta_tmp, path + ".json")
        os.replace(actual_tmp, path)
    except BaseException:
        for leftover in (actual_tmp, path + ".json.tmp"):
            try:
                os.remove(leftover)
            except OSError:
                pass
        raise
    return path


def _sweep_stale_tmp(directory: str) -> None:
    """Remove interrupted-save droppings (``*.tmp`` / ``*.tmp.npz``) left by
    a previous process that died mid-write. Safe against concurrent savers
    in the same directory only to the extent their tmp names differ (one
    writer per checkpoint path is the supported regime)."""
    try:
        names = os.listdir(directory)
    except OSError:
        return
    for name in names:
        if name.endswith((".tmp", ".tmp.npz")):
            try:
                os.remove(os.path.join(directory, name))
            except OSError:
                pass


def restore(path: str, example: Any) -> Any:
    """Load into the structure of ``example`` (shapes/dtypes must match)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(example)
    leaves = []
    for path_elems, leaf in paths_leaves:
        key = "/".join(_path_str(p) for p in path_elems)
        if key not in flat:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(jax.numpy.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs example {jax.numpy.shape(leaf)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest(directory: str, prefix: str = "ckpt_") -> str | None:
    """Newest checkpoint path in ``directory`` by step number, or None."""
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for name in os.listdir(directory):
        m = re.fullmatch(rf"{re.escape(prefix)}(\d+)\.npz", name)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, name), int(m.group(1))
    return best
