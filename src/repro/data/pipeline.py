"""Deterministic synthetic sequence pipeline for the LLM-scale integration.

Offline container: no real corpus is shipped, so the pipeline synthesizes a
*learnable* token stream — a mixture of order-2 Markov "languages" with
per-document switching — deterministically from a seed. Per-sequence losses
then genuinely differ across documents (some languages are lower-entropy),
which is what prioritized selection needs to demonstrate signal; an i.i.d.
uniform stream would make prioritization a no-op.

The interface is the usual sharded-iterator contract: ``make_batch(rng, step,
shard, num_shards)`` is a pure function, so every data shard can regenerate
its slice without host I/O, and restarts are reproducible (the paper's
failure-tolerance requirement applied to the data path).

[audio]/[vlm] frontends are stubs per the brief: ``embedding_batch`` emits
precomputed frame/patch embeddings of the right shape.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # sequences per shard per round
    num_languages: int = 8     # Markov mixture components
    seed: int = 0


def _language_tables(cfg: PipelineConfig) -> jax.Array:
    """(num_languages, K, K) transition logits over a K-symbol alphabet that is
    hashed into the real vocab; K kept small so tables are O(KB)."""
    k = 64
    rng = jax.random.key(cfg.seed)
    # temperature per language controls its entropy => its learnability;
    # log-spaced so the coldest languages are near-deterministic cycles and
    # the hottest near-uniform (prioritized selection needs a real spread)
    temps = jnp.logspace(-1.5, 0.5, cfg.num_languages)[:, None, None]
    logits = jax.random.normal(rng, (cfg.num_languages, k, k)) / temps
    return logits


def make_batch(cfg: PipelineConfig, rng: jax.Array, step: jax.Array | int,
               shard: jax.Array | int = 0, num_shards: int = 1) -> dict:
    """Pure, shardable batch synthesis -> {tokens, labels} of (B, S) int32."""
    k = 64
    tables = _language_tables(cfg)
    rng = jax.random.fold_in(jax.random.fold_in(rng, jnp.asarray(step)),
                             jnp.asarray(shard))
    lang_rng, start_rng, walk_rng = jax.random.split(rng, 3)
    lang = jax.random.randint(lang_rng, (cfg.batch_size,), 0, cfg.num_languages)
    table = tables[lang]                                        # (B, K, K)
    state0 = jax.random.randint(start_rng, (cfg.batch_size,), 0, k)

    def walk(state, r):
        nxt = jax.random.categorical(r, jnp.take_along_axis(
            table, state[:, None, None], axis=1)[:, 0, :])
        return nxt, nxt

    rngs = jax.random.split(walk_rng, cfg.seq_len)
    _, sym = jax.lax.scan(walk, state0, rngs)                   # (S, B)
    sym = sym.T                                                 # (B, S)
    # hash symbols into the real vocab, language-dependent offset so languages
    # occupy distinct vocab regions (documents are separable)
    mixed = (sym + lang[:, None] * 9973).astype(jnp.uint32)
    tokens = (mixed * jnp.uint32(2654435761)) % jnp.uint32(cfg.vocab_size)
    tokens = tokens.astype(jnp.int32)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((cfg.batch_size, 1), -1, jnp.int32)], axis=1)
    return {"tokens": tokens, "labels": labels}


def embedding_batch(rng: jax.Array, batch_size: int, seq_len: int,
                    d_model: int, dtype=jnp.bfloat16) -> jax.Array:
    """STUB modality frontend output: precomputed frame/patch embeddings of
    the right shape (the one sanctioned stub — see DESIGN.md §6)."""
    return jax.random.normal(rng, (batch_size, seq_len, d_model), dtype) * 0.02
