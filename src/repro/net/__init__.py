"""Multi-host actor ingest (paper §3: distributed acting, after Gorila).

* ``wire``         — versioned length-prefixed frame codec: transition
  blocks + priorities (optionally quantized via ``repro.core.codec``)
  and ``ParamStore`` snapshots as deterministic array-trees; every encoder
  has a scatter-gather ``*_iov`` twin emitting buffer views instead of one
  concatenated payload (bitwise-identical on the wire).
* ``transport``    — the byte-moving plane: ``Transport``/``Listener``
  with two implementations behind one API — ``TcpTransport`` (classic
  socket, iovec ``sendmsg`` writes) and ``ShmRingTransport`` (same-host
  shared-memory ring arena: data frames are written once into the mmap'd
  arena, ACKs/control stay on a small socket control plane). Clients dial
  ``connect(host, port, kind="tcp"|"shm"|"auto")``; auto upgrades to shm
  when the peer is loopback-local and falls back to tcp otherwise.
* ``gateway``      — ``ReplayGateway``: server thread routing decoded
  blocks into ``ReplayFabric.add`` (same global ``(shard, slot)`` keys and
  backpressure as the in-process queue) and serving param snapshots.
* ``actor_client`` — ``RemoteActorLoop``: actor *process* entry point that
  streams jitted ``act_phase`` rollouts over its transport with a bounded
  in-flight window; ``python -m repro.net.actor_client`` runs it against a
  remote gateway (the multi-host path), ``launch/train.py --actor-procs N``
  spawns local subprocesses (the single-machine proof).
* ``policy_client`` — ``PolicyClient``: the *policy plane* — a thin
  client shipping its ``ActorSlice`` per ``ACT_REQUEST`` to a
  ``--serve-policy`` gateway, whose shared slot-scheduled
  ``InferenceServer`` runs the rollout and replies ``ACT_RESULT``
  (bit-identical to an in-process rollout; the client never holds params).
* ``learner_client`` — ``RemoteFabricSource``: the *sample plane* — a
  ``repro.runtime.sources.SampleSource`` speaking ``SAMPLE_REQUEST`` /
  ``SAMPLE_BATCH`` / ``PRIORITY_UPDATE`` (coalesced, one frame per sample
  round) / ``PARAM_PUSH`` against the same gateway/fabric the actors feed,
  so a learner on another host samples, learns, and writes priorities back
  through the global (shard, slot) keys unchanged
  (``launch/train.py --learner-remote HOST:PORT``).
"""

from repro.net.actor_client import (RemoteActorLoop, RemoteActorSpec,
                                    initial_slice, run_remote_actor)
from repro.net.gateway import GatewayStats, ReplayGateway
from repro.net.learner_client import RemoteFabricSource, parse_hostport
from repro.net.policy_client import PolicyClient
from repro.net.transport import (Listener, ShmRingTransport, ShmUnavailable,
                                 TcpTransport, Transport, TransportClosed,
                                 connect, is_local_host, listen, resolve_kind)
from repro.net.wire import (FrameReader, WireError, decode_block,
                            decode_params, decode_priority_update,
                            decode_sample_batch, decode_tree, encode_block,
                            encode_block_iov, encode_params,
                            encode_params_iov, encode_priority_update,
                            encode_sample_batch, encode_sample_batch_iov,
                            encode_tree, encode_tree_iov)

__all__ = [
    "FrameReader", "GatewayStats", "Listener", "PolicyClient",
    "RemoteActorLoop", "RemoteActorSpec", "RemoteFabricSource", "ReplayGateway",
    "ShmRingTransport", "ShmUnavailable", "TcpTransport", "Transport",
    "TransportClosed", "WireError", "connect", "decode_block",
    "decode_params", "decode_priority_update", "decode_sample_batch",
    "decode_tree", "encode_block", "encode_block_iov", "encode_params",
    "encode_params_iov", "encode_priority_update", "encode_sample_batch",
    "encode_sample_batch_iov", "encode_tree", "encode_tree_iov",
    "initial_slice", "is_local_host", "listen", "parse_hostport",
    "resolve_kind", "run_remote_actor",
]
