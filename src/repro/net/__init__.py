"""Multi-host actor ingest (paper §3: distributed acting, after Gorila).

* ``wire``         — versioned length-prefixed frame codec: transition
  blocks + priorities (optionally obs-quantized via ``repro.core.codec``)
  and ``ParamStore`` snapshots as deterministic array-trees.
* ``gateway``      — ``ReplayGateway``: TCP server thread routing decoded
  blocks into ``ReplayFabric.add`` (same global ``(shard, slot)`` keys and
  backpressure as the in-process queue) and serving param snapshots.
* ``actor_client`` — ``RemoteActorLoop``: actor *process* entry point that
  streams jitted ``act_phase`` rollouts over the socket with a bounded
  in-flight window; ``python -m repro.net.actor_client`` runs it against a
  remote gateway (the multi-host path), ``launch/train.py --actor-procs N``
  spawns local subprocesses (the single-machine proof).

The wire format established here is the contract every future multi-host
feature (remote learners, replay replication) builds on.
"""

from repro.net.actor_client import (RemoteActorLoop, RemoteActorSpec,
                                    initial_slice, run_remote_actor)
from repro.net.gateway import GatewayStats, ReplayGateway
from repro.net.wire import (FrameReader, WireError, decode_block,
                            decode_params, decode_tree, encode_block,
                            encode_params, encode_tree)

__all__ = [
    "FrameReader", "GatewayStats", "RemoteActorLoop", "RemoteActorSpec",
    "ReplayGateway", "WireError", "decode_block", "decode_params",
    "decode_tree", "encode_block", "encode_params", "encode_tree",
    "initial_slice", "run_remote_actor",
]
