"""Multi-host actor ingest (paper §3: distributed acting, after Gorila).

* ``wire``         — versioned length-prefixed frame codec: transition
  blocks + priorities (optionally obs-quantized via ``repro.core.codec``)
  and ``ParamStore`` snapshots as deterministic array-trees.
* ``gateway``      — ``ReplayGateway``: TCP server thread routing decoded
  blocks into ``ReplayFabric.add`` (same global ``(shard, slot)`` keys and
  backpressure as the in-process queue) and serving param snapshots.
* ``actor_client`` — ``RemoteActorLoop``: actor *process* entry point that
  streams jitted ``act_phase`` rollouts over the socket with a bounded
  in-flight window; ``python -m repro.net.actor_client`` runs it against a
  remote gateway (the multi-host path), ``launch/train.py --actor-procs N``
  spawns local subprocesses (the single-machine proof).
* ``learner_client`` — ``RemoteFabricSource``: the *sample plane* — a
  ``repro.runtime.sources.SampleSource`` speaking ``SAMPLE_REQUEST`` /
  ``SAMPLE_BATCH`` / ``PRIORITY_UPDATE`` / ``PARAM_PUSH`` against the same
  gateway/fabric the actors feed, so a learner on another host samples,
  learns, and writes priorities back through the global (shard, slot) keys
  unchanged (``launch/train.py --learner-remote HOST:PORT``).
"""

from repro.net.actor_client import (RemoteActorLoop, RemoteActorSpec,
                                    initial_slice, run_remote_actor)
from repro.net.gateway import GatewayStats, ReplayGateway
from repro.net.learner_client import RemoteFabricSource, parse_hostport
from repro.net.wire import (FrameReader, WireError, decode_block,
                            decode_params, decode_priority_update,
                            decode_sample_batch, decode_tree, encode_block,
                            encode_params, encode_priority_update,
                            encode_sample_batch, encode_tree)

__all__ = [
    "FrameReader", "GatewayStats", "RemoteActorLoop", "RemoteActorSpec",
    "RemoteFabricSource", "ReplayGateway", "WireError", "decode_block",
    "decode_params", "decode_priority_update", "decode_sample_batch",
    "decode_tree", "encode_block", "encode_params",
    "encode_priority_update", "encode_sample_batch", "encode_tree",
    "initial_slice", "parse_hostport", "run_remote_actor",
]
