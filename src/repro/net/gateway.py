"""Replay gateway: the serving side of the transport plane, in front of the
replay fabric.

This is the machine boundary of Fig. 1: remote actor processes (same host or
across the network) stream ``ADD_BLOCK`` frames in, and the gateway routes
the decoded ``TransitionBlock``s into the *same* ``ReplayFabric.add`` the
in-process actor threads use — the learner cannot tell the two ingest paths
apart (same round-robin shard routing, same global ``(shard, slot)`` keys,
same backpressure semantics).

Topology::

    remote actor proc 0 ──tcp/shm──┐
    remote actor proc 1 ──tcp/shm──┤   ReplayGateway        ReplayFabric
           ...                     ├── (accept thread + ──► add / round-robin
    remote actor proc K ──tcp/shm──┘    handler thread        shard routing
                                        per connection)

Connections arrive through ``repro.net.transport.Listener``: every client
starts on TCP and may upgrade itself to a shared-memory ring
(``ShmRingTransport``) in-band — the handler below never knows which bytes
path it is on, it just calls ``conn.recv()``/``conn.send()``.

* Each connection gets its own handler thread: frame decode (a memcpy-level
  numpy view) runs concurrently across actors, and the device transfer
  happens on the owning shard's thread as for in-process adds.
* **Backpressure propagates end to end.** ``fabric.add`` returning False
  (bounded shard queue full) makes the handler retry — meanwhile no
  ``ADD_ACK`` is sent, the client's bounded in-flight window stays open, and
  the remote actor blocks exactly like a local actor blocks on the queue.
  Retries are counted in ``GatewayStats.add_retries`` (the remote analogue
  of the runner's ``actor_blocked``).
* **Parameter serving.** ``PARAM_PULL {have: v}`` answers with the latest
  ``ParamStore`` snapshot when its version is newer, else
  ``PARAM_UNCHANGED`` — the client pulls every ``param_sync_period``
  rollouts (Alg. 1 l.2), so the period is honored client-side and the
  gateway never pushes unsolicited traffic.
* **Sample plane (remote learners).** The same fabric's *learner* side is
  served over the same connection discipline: ``SAMPLE_REQUEST`` pops one
  prioritized batch (empty ``SAMPLE_BATCH`` reply while starved — the
  remote analogue of ``get_batch`` returning None), ``PRIORITY_UPDATE``
  scatters write-backs by the global (shard, slot) keys the batch carried
  (one frame may coalesce several write-back rounds; the ``batches`` leaf
  advances the ``priority_updates`` learner clock by that many), and
  ``PARAM_PUSH`` publishes the remote learner's fresh params into this
  host's ``ParamStore`` so the actors feeding the fabric keep pulling
  learning-current snapshots. ``fabric.get_batch`` is single-consumer, so
  sample pops are serialized under a lock; exactly one remote learner
  should be attached at a time (a second one would consume from the same
  logical replay — replay replication, not an error, but not a fan-out).

* **Policy plane (``--serve-policy``).** A gateway built with
  ``inference=`` (an ``InferenceServer``) and ``act_example=`` (a local
  ``ActorSlice`` fixing the wire geometry) serves ``ACT_REQUEST`` frames:
  each is one rollout request admitted into the shared slot-scheduled
  engine alongside the in-process actors, answered with ``ACT_RESULT``
  (advanced slice + ``TransitionBlock`` + metrics) or ``STOP`` when the
  runtime is shutting down. Concurrency across connections is what fills
  the engine's slots — each handler thread blocks in ``engine.act`` while
  the engine batches every blocked handler into one compiled dispatch. A
  policy-only gateway passes ``fabric=None``; fabric-plane frames on such
  a gateway are a protocol error.

``stop()`` sends ``STOP`` to every live client (best effort), closes the
listener, and joins the handlers; a handler that dies on malformed traffic
records the error and drops that one connection, never the gateway.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

import jax

from repro.net import transport as transport_lib
from repro.net import wire
from repro.obs import Telemetry
from repro.runtime.params import ParamStore


@dataclasses.dataclass
class GatewayStats:
    connections: int = 0        # accepted actor connections (lifetime)
    shm_connections: int = 0    # ... that upgraded to the shm ring path
    blocks_in: int = 0          # ADD_BLOCKs routed into the fabric
    transitions_in: int = 0     # transitions carried by those blocks
    add_retries: int = 0        # fabric.add backpressure retries (remote
                                # analogue of the runner's actor_blocked)
    param_pulls: int = 0        # PARAM_PULL requests served
    param_sends: int = 0        # ... that shipped a fresh snapshot
    bytes_in: int = 0
    bytes_out: int = 0
    client_rollouts: int = 0    # merged from BYE frames (client-side view)
    client_blocked: int = 0     # client waits on a full in-flight window
    wire_errors: int = 0        # connections dropped on malformed traffic
    sample_requests: int = 0    # SAMPLE_REQUESTs served (incl. starved)
    sample_sends: int = 0       # ... that shipped an actual batch
    sample_starved: int = 0     # ... answered empty (fabric below min-fill
                                # or prefetch lagging)
    priority_updates: int = 0   # priority write-back *rounds* routed into
                                # the fabric (the serve-side learner clock;
                                # coalesced frames count every round they
                                # carry)
    priority_frames: int = 0    # PRIORITY_UPDATE frames received
    param_pushes: int = 0       # PARAM_PUSH snapshots published locally
    client_reconnects: int = 0  # HELLOs from clients that came back after
                                # a severed transport (fault-tolerance
                                # plane: safe because priority updates are
                                # idempotent LWW and adds are append-only)
    learner_byes: int = 0       # clean BYEs from sample-plane learner
                                # clients — the serving runtime's end-of-run
                                # signal when severed transports swallowed
                                # some in-flight priority frames
    act_requests: int = 0       # policy-plane rollouts served (ACT_RESULT
                                # replies; a STOP answer is not counted)


class ReplayGateway:
    """Server thread feeding ``ReplayFabric.add`` from remote actors."""

    def __init__(self, fabric: Any, store: ParamStore, *,
                 host: str = "127.0.0.1", port: int = 0,
                 add_timeout_s: float = 0.05, sample_timeout_s: float = 0.05,
                 poll_s: float = 0.2, drain_grace_s: float = 1.0,
                 backlog: int = 64, accept_shm: bool = True,
                 ring_bytes: int = transport_lib.DEFAULT_RING_BYTES,
                 inference: Any = None, act_example: Any = None,
                 telemetry: Telemetry | None = None):
        if fabric is None and inference is None:
            raise ValueError("gateway needs a fabric, an inference engine, "
                             "or both — got neither")
        if inference is not None and act_example is None:
            raise ValueError("policy serving needs act_example (a local "
                             "ActorSlice fixing the wire geometry)")
        self._fabric = fabric
        self._store = store
        self._inference = inference
        self._act_example = act_example
        self._tel = telemetry if telemetry is not None else Telemetry.local()
        # decode + fabric-route latency per ADD_BLOCK; the retries counter
        # mirrors GatewayStats.add_retries into the obs registry so the
        # run report's backpressure section sees it.
        self._h_route = self._tel.histogram("gateway/route_us")
        self._c_retries = self._tel.counter("gateway/add_retries")
        self._c_blocks = self._tel.counter("gateway/blocks_in")
        # policy plane: decode + engine dispatch + encode per ACT_REQUEST
        self._h_act = self._tel.histogram("gateway/act_us")
        self._add_timeout_s = add_timeout_s
        self._sample_timeout_s = sample_timeout_s
        # fabric.get_batch is single-consumer (parked sub-batches); serialize
        # sample pops across handler threads so the contract holds even if
        # several learner connections appear.
        self._sample_lock = threading.Lock()
        self._poll_s = poll_s
        self._drain_grace_s = drain_grace_s
        self._listener = transport_lib.Listener(
            host, port, backlog=backlog, accept_shm=accept_shm,
            ring_bytes=ring_bytes, poll_s=poll_s)
        self.host, self.port = self._listener.host, self._listener.port
        self._stop = threading.Event()
        self._lock = threading.Lock()      # stats + connection registry
        self._conns: dict[int, transport_lib.Transport] = {}
        self._conn_blocks: dict[int, int] = {}  # routed blocks per accepted
                                                # connection (kept after
                                                # close, for observability)
        self._handlers: list[threading.Thread] = []
        # One device->host transfer + encode per published version, not one
        # per pull per connection: K pulling actors share this payload.
        self._param_cache: tuple[int, bytes] | None = None
        self._param_cache_lock = threading.Lock()
        self.stats = GatewayStats()
        self.error: BaseException | None = None
        self._thread = threading.Thread(target=self._accept_loop, daemon=True,
                                        name="replay-gateway")

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "ReplayGateway":
        self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        """Send STOP to every client, close the listener, join handlers."""
        self._stop.set()
        with self._lock:
            conns = list(self._conns.values())
        for conn in conns:
            try:
                conn.send(wire.STOP)
            except (OSError, wire.WireError):
                pass
        self._listener.close()
        if join:
            if self._thread.is_alive():
                self._thread.join()
            for th in list(self._handlers):
                th.join()
            with self._lock:
                conns = list(self._conns.values())
            for conn in conns:
                conn.close()

    @property
    def address(self) -> tuple[str, int]:
        return (self.host, self.port)

    def snapshot(self) -> GatewayStats:
        with self._lock:
            return dataclasses.replace(self.stats)

    def connection_block_counts(self) -> list[int]:
        """Blocks routed per accepted connection (accept order). Lets a
        caller distinguish 'every actor is streaming' from 'one hot actor
        carries the total' — e.g. warm-up gates in benchmarks."""
        with self._lock:
            return list(self._conn_blocks.values())

    def _bump(self, **deltas: int) -> None:
        with self._lock:
            for k, d in deltas.items():
                setattr(self.stats, k, getattr(self.stats, k) + d)

    # -- accept loop --------------------------------------------------------

    def _accept_loop(self) -> None:
        try:
            while not self._stop.is_set():
                try:
                    conn = self._listener.accept()
                except OSError:
                    break  # listener closed by stop()
                if conn is None:
                    continue
                cid = id(conn)
                with self._lock:
                    self._conns[cid] = conn
                    self._conn_blocks[cid] = 0
                    self.stats.connections += 1
                th = threading.Thread(
                    target=self._handle, args=(cid, conn),
                    daemon=True, name=f"gateway-conn-{self.stats.connections}")
                self._handlers.append(th)
                th.start()
        except BaseException as e:  # noqa: BLE001
            self.error = e

    # -- per-connection handler ---------------------------------------------

    def _handle(self, cid: int, conn: transport_lib.Transport) -> None:
        drain_deadline = None  # set when stop() is first observed
        in_seen = out_seen = 0
        was_shm = False
        staged_sample: list | None = None  # pre-encoded next reply
        # Decoded PRIORITY_UPDATE frames whose fabric application is
        # deferred: the learner flushes write-backs immediately before its
        # next SAMPLE_REQUEST, so applying eagerly puts the jitted scatter
        # in the reply's critical path. Parking it and peeking for the
        # request first moves the application into the learner's compute
        # window. Application order relative to the reply batch is
        # unchanged — that batch was popped before the update arrived.
        pending_prio: list[tuple] = []

        def account() -> None:
            nonlocal in_seen, out_seen
            bi, bo = conn.bytes_in, conn.bytes_out
            if bi != in_seen or bo != out_seen:
                self._bump(bytes_in=bi - in_seen, bytes_out=bo - out_seen)
                in_seen, out_seen = bi, bo

        def apply_priorities() -> None:
            # Same asynchronous write-back path as the in-process learner;
            # the global keys route to the owning shards. One frame may
            # coalesce several rounds — re-apply each as its own call so
            # the shard eviction clock ticks per round, exactly as if each
            # had shipped separately. A traced frame's id follows every
            # round to the owning shards' writeback spans.
            while pending_prio:
                idx, prios, counts, tid = pending_prio.pop(0)
                off = 0
                for n in counts:
                    n = int(n)
                    self._fabric.write_back(idx[off:off + n],
                                            prios[off:off + n],
                                            trace_id=tid)
                    off += n
                self._bump(priority_updates=len(counts))

        try:
            while True:
                if self._stop.is_set():
                    # Grace window after STOP: clients drain their in-flight
                    # blocks and report BYE counters before we hang up.
                    now = time.monotonic()
                    if drain_deadline is None:
                        drain_deadline = now + self._drain_grace_s
                    elif now >= drain_deadline:
                        break
                got = conn.recv(timeout=0 if pending_prio else self._poll_s)
                account()
                if not was_shm and conn.kind == "shm":
                    was_shm = True
                    self._bump(shm_connections=1)
                if got is None:
                    apply_priorities()  # no request on its heels: apply now
                    continue
                msg_type, payload = got
                if self._fabric is None and msg_type in (
                        wire.ADD_BLOCK, wire.SAMPLE_REQUEST,
                        wire.PRIORITY_UPDATE):
                    raise wire.WireError(
                        f"fabric-plane message {msg_type} on a policy-only "
                        "gateway")
                if msg_type == wire.ACT_REQUEST:
                    self._serve_act(conn, payload)
                elif msg_type == wire.ADD_BLOCK:
                    if self._route_block(cid, payload, conn.last_trace_id):
                        conn.send(wire.ADD_ACK)
                    # else: dropped during shutdown — no ACK; the client is
                    # about to receive STOP anyway
                elif msg_type == wire.SAMPLE_REQUEST:
                    staged_sample = self._serve_sample(conn, staged_sample)
                    apply_priorities()
                elif msg_type == wire.PRIORITY_UPDATE:
                    pending_prio.append(
                        (*wire.decode_priority_update(payload),
                         conn.last_trace_id))
                    self._bump(priority_frames=1)
                elif msg_type == wire.PARAM_PUSH:
                    _version, params = wire.decode_params(payload)
                    # Publish on-device so the K actors pulling this
                    # snapshot don't each re-transfer host leaves. The
                    # store numbers versions itself (single local writer).
                    self._store.publish(jax.device_put(params))
                    self._bump(param_pushes=1)
                elif msg_type == wire.PARAM_PULL:
                    have = wire.decode_json(payload).get("have", -1)
                    self._serve_params(conn, int(have))
                elif msg_type == wire.HELLO:
                    hello = wire.decode_json(payload)
                    if hello.get("protocol") != wire.PROTOCOL_VERSION:
                        raise wire.WireError(
                            f"client protocol {hello.get('protocol')} != "
                            f"{wire.PROTOCOL_VERSION}")
                    if hello.get("reconnects"):
                        # A client that survived a severed transport and
                        # dialed back in — count the comeback, not its
                        # lifetime total (each HELLO reports cumulative).
                        self._bump(client_reconnects=1)
                elif msg_type == wire.BYE:
                    stats = wire.decode_json(payload)
                    self._bump(
                        client_rollouts=int(stats.get("rollouts", 0)),
                        client_blocked=int(stats.get("blocked", 0)),
                        learner_byes=1 if stats.get("learner") else 0)
                    break
                else:
                    raise wire.WireError(f"unexpected message {msg_type}")
        except EOFError:
            pass  # client went away; its blocks are already routed
        except wire.WireError:
            self._bump(wire_errors=1)
        except OSError:
            pass  # transport torn down under us during stop()
        except BaseException as e:  # noqa: BLE001
            self.error = e
        finally:
            # A connection may end (BYE, EOF, stop) with a parked update —
            # the client's final flush-then-BYE must still land in the
            # fabric, whoever wins the shutdown race.
            try:
                apply_priorities()
            except BaseException as e:  # noqa: BLE001
                if self.error is None:
                    self.error = e
            account()
            with self._lock:
                self._conns.pop(cid, None)
            conn.close()

    def _route_block(self, cid: int, payload: memoryview,
                     trace_id: int = 0) -> bool:
        """Decode and push into the fabric, holding the client's ACK (and
        therefore its in-flight window) open while the shard queue is full.
        False only when the block was dropped because stop() interrupted
        the retry loop. A traced block (nonzero wire-header id) records a
        "gateway" span — decode plus route, including backpressure wait —
        and hands its id to the fabric for the shard's add span."""
        t0 = time.perf_counter()
        block = wire.decode_block(payload)
        n = int(block.priorities.shape[0])
        while not self._fabric.add(block, timeout=self._add_timeout_s,
                                   trace_id=trace_id):
            self._bump(add_retries=1)
            self._c_retries.inc()
            if self._stop.is_set():
                return False
        us = 1e6 * (time.perf_counter() - t0)
        self._h_route.record(us)
        self._c_blocks.inc()
        if trace_id:
            self._tel.tracer.record("gateway", trace_id, us)
        with self._lock:
            self.stats.blocks_in += 1
            self.stats.transitions_in += n
            self._conn_blocks[cid] += 1
        return True

    def _serve_act(self, conn: transport_lib.Transport,
                   payload: memoryview) -> None:
        """One policy-plane rollout: decode the client's slice, block in the
        shared engine (the batching — every concurrently-blocked handler
        lands in the same compiled dispatch), reply with the advanced slice.
        ``STOP`` answers a request the engine refused because the runtime is
        shutting down; the client treats it like the fabric-plane STOP."""
        if self._inference is None:
            raise wire.WireError("ACT_REQUEST on a gateway without an "
                                 "inference engine (--serve-policy not set)")
        t0 = time.perf_counter()
        aslice, sid = wire.decode_act_request(payload, self._act_example)
        res = self._inference.act(aslice, sid)
        if res is None:
            conn.send(wire.STOP)
            return
        out_slice, block, metrics = res
        conn.send(wire.ACT_RESULT,
                  wire.encode_act_result(out_slice, block, metrics))
        self._h_act.record(1e6 * (time.perf_counter() - t0))
        self._bump(act_requests=1)

    def _serve_sample(self, conn: transport_lib.Transport,
                      staged: list | None = None) -> list | None:
        """Ship one prioritized batch; an empty payload tells the learner
        the fabric is starved (poll again) — backpressure in the sampling
        direction, mirroring the ADD_ACK window on ingest.

        Returns the next reply, staged: after answering, the handler pops
        and encodes the *next* batch immediately, so the fabric's prefetch
        refill (a jitted sample + host transfer that competes for the same
        cores) runs while the learner is busy computing on the batch just
        shipped, not serially inside the next request. The pop order is the
        fabric's prefetch-queue order either way — staging moves work in
        time, never reorders or drops a batch the learner will see."""
        if staged is None:
            with self._sample_lock:
                batch = self._fabric.get_batch(timeout=self._sample_timeout_s)
            served = batch is not None
            staged = wire.encode_sample_batch_iov(batch) if served else []
        else:
            served = True
        conn.send(wire.SAMPLE_BATCH, staged)
        self._bump(sample_requests=1,
                   sample_sends=int(served),
                   sample_starved=int(not served))
        with self._sample_lock:
            nxt = self._fabric.get_batch(timeout=0)
        return None if nxt is None else wire.encode_sample_batch_iov(nxt)

    def _encoded_params(self, snap) -> bytes:
        with self._param_cache_lock:
            cached = self._param_cache
            if cached is not None and cached[0] == snap.version:
                return cached[1]
            payload = wire.encode_params(snap.version, snap.params)
            self._param_cache = (snap.version, payload)
            return payload

    def _serve_params(self, conn: transport_lib.Transport, have: int) -> None:
        snap = self._store.get()
        # Bump before the reply ships: a client that has read the reply
        # must see the stats already counted (tests and operators poll
        # snapshot() right after a round trip).
        if snap.version > have:
            payload = self._encoded_params(snap)
            self._bump(param_pulls=1, param_sends=1)
            conn.send(wire.PARAM, payload)
        else:
            self._bump(param_pulls=1)
            conn.send(wire.PARAM_UNCHANGED,
                      wire.encode_json({"version": snap.version}))
