"""Policy-plane client: remote rollouts against a ``--serve-policy`` gateway.

Gorila's one-policy-many-clients surface (Nair et al., 2015) over this
repo's transport plane: a client holds its own ``ActorSlice`` (env state,
rng, return accumulator — the *state* stays client-side) and ships it to
the policy gateway per rollout; the server admits the slice into the shared
slot-scheduled ``InferenceServer`` alongside the in-process actors and
replies with the advanced slice plus the ``TransitionBlock`` it produced.
The client never holds parameters — param freshness, hot-swap, and
batching economics all live server-side, which is the point: hundreds of
CPU-only clients share one device-resident policy.

One request is in flight per client connection (the reply *is* the next
request's input), so concurrency — and therefore server-side batch
occupancy — comes from the number of connected clients, exactly like the
paper's actor fleet.

Wire: ``ACT_REQUEST`` (slice + shard id) / ``ACT_RESULT`` (slice + block +
metrics), fp32/int32 leaves and PRNG key data round-tripping bit-exactly,
so a remote rollout equals the in-process rollout bit for bit. A ``STOP``
reply means the runtime is shutting down: ``act`` returns ``None`` and the
caller drains out, mirroring ``InferenceServer.act``.
"""

from __future__ import annotations

import time
from typing import Any

from repro.net import transport as transport_lib
from repro.net import wire


class PolicyClient:
    """Blocking one-request-at-a-time client for the policy plane."""

    def __init__(self, host: str, port: int, *, example: Any,
                 transport: str = "auto", connect_timeout_s: float = 10.0,
                 act_timeout_s: float = 120.0,
                 ring_bytes: int = transport_lib.DEFAULT_RING_BYTES):
        # ``example`` fixes the wire geometry: the reply slice is unflattened
        # against a locally built ActorSlice (both sides derive the same
        # structure from (cfg, env)), so no treedef travels on the wire.
        self._example = example
        self._act_timeout_s = act_timeout_s
        self._conn = transport_lib.connect(
            host, port, transport, timeout=connect_timeout_s,
            ring_bytes=ring_bytes)
        self._conn.send(wire.HELLO, wire.encode_json(
            {"protocol": wire.PROTOCOL_VERSION, "policy": True}))
        self.stats = {"acts": 0, "stopped": 0}

    @property
    def transport_kind(self) -> str:
        return self._conn.kind

    def act(self, aslice: Any, shard_id: int,
            ) -> tuple[Any, Any, dict] | None:
        """One remote rollout: returns (advanced slice, TransitionBlock,
        metrics), or None when the server answered STOP (runtime shutting
        down)."""
        self._conn.send(wire.ACT_REQUEST,
                        wire.encode_act_request(aslice, shard_id))
        deadline = time.monotonic() + self._act_timeout_s
        while True:
            got = self._conn.recv(timeout=0.05)
            if got is None:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        "policy gateway never answered ACT_REQUEST "
                        f"(waited {self._act_timeout_s}s)")
                continue
            msg_type, payload = got
            if msg_type == wire.ACT_RESULT:
                self.stats["acts"] += 1
                return wire.decode_act_result(payload, self._example)
            if msg_type == wire.STOP:
                self.stats["stopped"] += 1
                return None
            raise wire.WireError(
                f"unexpected message {msg_type} on the policy plane")

    def close(self) -> None:
        try:
            self._conn.send(wire.BYE, wire.encode_json(
                {"rollouts": self.stats["acts"]}))
        except (OSError, wire.WireError):
            pass
        try:
            self._conn.close()
        except OSError:
            pass
