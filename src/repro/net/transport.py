"""Byte-moving layer under the ``repro.net`` wire protocol.

The wire format (``repro.net.wire``) defines *what* travels — framed,
versioned messages. This module defines *how* bytes move, behind one small
API so the gateway, the remote actor loop, and the remote learner client
never touch sockets or shared memory directly:

* :class:`Transport` — ``send(msg_type, payload)`` / ``recv(timeout)`` /
  ``close()``, where ``payload`` may be bytes-like **or an iovec-style list
  of buffers** (``wire.encode_*_iov``): segments are handed to the kernel
  (``sendmsg``) or written once into the ring arena, never concatenated
  host-side, and the bytes on the wire are identical either way.
* :class:`Listener` — ``accept(timeout) -> Transport`` for the serving side.
* :func:`connect` / :func:`listen` — the only constructors callers need;
  ``kind`` is ``"tcp"``, ``"shm"``, or ``"auto"`` (shm when the peer host is
  loopback-local, tcp otherwise).

Two transports implement the API:

* :class:`TcpTransport` — today's stream socket + ``FrameReader`` path,
  with scatter-gather ``sendmsg`` on the way out.
* :class:`ShmRingTransport` — same-host processes exchange frames through a
  mmap'd arena holding two SPSC byte rings (one per direction). **Bulk data
  frames** (blocks, batches, params, priority updates above a small size
  cutover) are written once into the ring and delivered from it; **ACKs,
  control frames, and sub-cutover data frames stay on the socket control
  plane**, which also carries the upgrade handshake and peer-liveness (EOF)
  detection. A connection starts as TCP
  and upgrades in-band: the client sends ``SHM_REQ``, the serving side
  creates the arena file (under ``/dev/shm`` when available), replies
  ``SHM_SETUP{path}``, and unlinks the file once the client confirms
  ``SHM_ATTACHED`` — so a crash on either side reclaims the memory.

Ring protocol: per ring a monotonically increasing u64 ``head`` (writer)
and ``tail`` (reader) byte counter pair; frames are the exact TCP wire
bytes, written with wraparound split copies, and the writer *commits a
whole frame at once* by advancing ``head`` after the last byte is in place.
A writer killed mid-frame therefore never publishes a torn frame: the
reader sees socket EOF plus a quiet ring and fails fast with ``EOFError``
(the same end-of-stream signal the socket path raises, which is what lets
``RemoteFabricSource`` surface ``SourceClosed`` on both sides of the
shutdown race). Aligned 8-byte counter loads/stores are atomic on the
x86-64/arm64 hosts this targets, and the x86-TSO/acquire-release ordering
of CPython's memcpy-based buffer writes makes data visible before the head
that publishes it.

Each ring commit is followed by a header-only ``SHM_DOORBELL`` frame on the
socket, so the receive side *blocks on the socket* instead of polling the
ring — commit-to-delivery latency is a socket wake-up (~µs on loopback),
not a sleep quantum, while the bulk bytes still bypass the socket entirely.
Doorbells are tokens: the reader pops exactly one ring frame per doorbell,
which makes the socket's byte stream the single FIFO delivery order for
both channels — a doorbell *is* the ring frame's slot in that order. Any
frame sent before another by one sender is therefore delivered before it,
regardless of which channel each rode: e.g. a coalesced
``PRIORITY_UPDATE`` flushed right before a ``BYE`` is never lost to the
shutdown race (the only exception is socket EOF, where committed ring
frames are drained before ``EOFError`` is raised).

The receive side copies a frame's payload out of the arena before handing
it up — one deliberate memcpy, because payloads outlive the recv call (the
gateway queues decoded blocks into shard queues asynchronously) while ring
space must be reusable immediately. The zero-copy win is the send path:
tensors go straight from their numpy buffers into the arena (or the
kernel's iovec), never through an intermediate payload buffer.
"""

from __future__ import annotations

import mmap
import os
import select
import socket
import struct
import tempfile
import threading
import time
from typing import Any

import numpy as np

from repro.net import wire

# Per-direction ring capacity. 16 MiB holds dozens of the largest frames the
# protocol ships (MB-class param snapshots / sample batches); the arena is
# two rings + one header page.
DEFAULT_RING_BYTES = 1 << 24

# Data frames at or below this size ride the socket even on an shm
# connection (per connection the cutover is ``min(this, ring_bytes // 4)``):
# for a ~KB coalesced priority flush one ``sendmsg`` beats ring write +
# doorbell syscall + wake-and-pop, while bulk frames still bypass the
# socket entirely.
RING_CUTOVER_BYTES = 1 << 15

_ARENA_MAGIC = b"APXRING2"
_HDR_A = 64            # client -> server ring counters (head u64, tail u64)
_HDR_B = 128           # server -> client ring counters
_DATA_OFF = 192
_U64 = struct.Struct("<Q")

# Frames that carry experience/params ride the ring on an shm connection;
# everything else (HELLO/ACK/PULL/UNCHANGED/STOP/BYE/SAMPLE_REQUEST and the
# SHM_* handshake itself) is small control traffic and stays on the socket.
DATA_TYPES = frozenset({
    wire.ADD_BLOCK, wire.SAMPLE_BATCH, wire.PARAM, wire.PARAM_PUSH,
    wire.PRIORITY_UPDATE,
})

# recv/send wait backoff: start by yielding, escalate to sub-millisecond
# sleeps — tight enough for request/reply latency, kind to single-CPU hosts
# where a busy spin would starve the very peer we are waiting on.
_POLL_MAX_S = 5e-4
_POLL_STEP_S = 1e-4


class TransportClosed(ConnectionError):
    """The peer is gone (closed socket / dead ring partner) — raised from
    ``send``; ``recv`` keeps the socket convention and raises ``EOFError``."""


class ShmUnavailable(RuntimeError):
    """The shm upgrade handshake was refused or cannot proceed; ``auto``
    connections fall back to TCP, explicit ``shm`` connections fail."""


def _tune(sock: socket.socket) -> None:
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    for opt in (socket.SO_SNDBUF, socket.SO_RCVBUF):
        try:
            sock.setsockopt(socket.SOL_SOCKET, opt, 1 << 21)
        except OSError:
            pass  # platform cap: the default stays


def _sendmsg_all(sock: socket.socket, segments) -> None:
    """Scatter-gather sendall: hand ``segments`` to ``sendmsg`` and resume
    after partial sends / timeouts until every byte is out. Tolerates a
    reader thread flipping the shared socket timeout (timeouts/would-block
    park on select instead of erroring)."""
    mvs = [m for m in (memoryview(s) for s in segments) if len(m)]
    i = 0
    while i < len(mvs):
        try:
            n = sock.sendmsg(mvs[i:i + 64])
        except (socket.timeout, TimeoutError, BlockingIOError,
                InterruptedError):
            try:
                select.select([], [sock], [], 0.05)
            except (OSError, ValueError) as e:
                raise TransportClosed(f"socket gone during send: {e!r}") from e
            continue
        except OSError as e:
            raise TransportClosed(f"peer gone during send: {e!r}") from e
        while n:
            if n >= len(mvs[i]):
                n -= len(mvs[i])
                i += 1
            else:
                mvs[i] = mvs[i][n:]
                n = 0


class Transport:
    """One bidirectional framed connection; see the module docstring.

    * ``send(msg_type, payload)`` — payload is bytes-like or an iovec list;
      thread-safe (internal lock), returns bytes put on the wire. Raises
      ``WireError`` (oversize), ``TransportClosed`` (peer gone).
    * ``recv(timeout)`` — next ``(msg_type, payload_view)`` or None on
      timeout (``timeout=0`` polls); single consumer. Raises ``EOFError``
      at end-of-stream, ``WireError`` on garbage.
    """

    kind = "?"

    # Trace id from the most recent frame returned by recv (0 = untraced;
    # see repro.obs). Exposed as an attribute, not in the recv return
    # shape, so the existing (msg_type, payload) contract is untouched.
    last_trace_id = 0

    @property
    def bytes_in(self) -> int:
        raise NotImplementedError

    @property
    def bytes_out(self) -> int:
        raise NotImplementedError

    def send(self, msg_type: int, payload: Any = b"",
             trace_id: int = 0) -> int:
        raise NotImplementedError

    def recv(self, timeout: float | None = None,
             ) -> tuple[int, memoryview] | None:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError


class TcpTransport(Transport):
    """Stream-socket transport: ``FrameReader`` in, scatter-gather
    ``sendmsg`` out. A serving-side instance (``accept_shm=True``) upgrades
    itself in place when the peer requests shm — after the handshake every
    call delegates to the :class:`ShmRingTransport` it became."""

    def __init__(self, sock: socket.socket, *, max_payload: int | None = None,
                 accept_shm: bool = False,
                 ring_bytes: int = DEFAULT_RING_BYTES,
                 handshake_timeout_s: float = 10.0):
        self._sock = sock
        self._max_payload = wire.MAX_PAYLOAD if max_payload is None \
            else max_payload
        self._reader = wire.FrameReader(sock, max_payload=self._max_payload)
        self._send_lock = threading.Lock()
        self._accept_shm = accept_shm
        self._ring_bytes = ring_bytes
        self._handshake_timeout_s = handshake_timeout_s
        self._shm: ShmRingTransport | None = None
        self._sent = 0

    @property
    def kind(self) -> str:  # type: ignore[override]
        return self._shm.kind if self._shm is not None else "tcp"

    @property
    def bytes_in(self) -> int:
        return self._shm.bytes_in if self._shm is not None \
            else self._reader.bytes_in

    @property
    def bytes_out(self) -> int:
        return self._shm.bytes_out if self._shm is not None else self._sent

    def send(self, msg_type: int, payload: Any = b"",
             trace_id: int = 0) -> int:
        if self._shm is not None:
            return self._shm.send(msg_type, payload, trace_id)
        segs = wire.frame_iov(msg_type, payload, self._max_payload,
                              trace_id)
        n = wire.iov_len(segs)
        with self._send_lock:
            _sendmsg_all(self._sock, segs)
            self._sent += n
        return n

    @property
    def last_trace_id(self) -> int:  # type: ignore[override]
        return self._shm.last_trace_id if self._shm is not None \
            else self._reader.last_trace_id

    def recv(self, timeout: float | None = None,
             ) -> tuple[int, memoryview] | None:
        if self._shm is not None:
            return self._shm.recv(timeout)
        got = self._reader.read_frame(timeout)
        if got is not None and got[0] == wire.SHM_REQ:
            self._serve_upgrade(got[1])
            return self.recv(timeout)
        return got

    def _serve_upgrade(self, req_payload: memoryview) -> None:
        """Handle a peer's ``SHM_REQ``: build the arena and swap this
        connection onto rings, or ``SHM_NACK`` and stay on TCP."""
        req = wire.decode_json(req_payload)
        if not self._accept_shm:
            self.send(wire.SHM_NACK,
                      wire.encode_json({"reason": "shm not accepted here"}))
            return
        n = int(req.get("ring_bytes", self._ring_bytes))
        try:
            path, mm = _create_arena(n)
        except OSError as e:
            self.send(wire.SHM_NACK, wire.encode_json({"reason": repr(e)}))
            return
        try:
            self.send(wire.SHM_SETUP,
                      wire.encode_json({"path": path, "ring_bytes": n}))
            got = self._reader.read_frame(timeout=self._handshake_timeout_s)
            if got is None:
                raise wire.WireError("shm handshake: peer never attached")
            if got[0] != wire.SHM_ATTACHED:
                raise wire.WireError(
                    f"shm handshake: expected SHM_ATTACHED, got {got[0]}")
        except BaseException:
            mm.close()
            _unlink_quiet(path)
            raise
        # Peer holds its own mapping now: the name can go away — whoever
        # dies last just drops the final reference to anonymous-again pages.
        _unlink_quiet(path)
        self._shm = ShmRingTransport(self._sock, self._reader, mm,
                                     is_server=True, ring_bytes=n,
                                     max_payload=self._max_payload)

    def close(self) -> None:
        if self._shm is not None:
            self._shm.close()
            return
        try:
            self._sock.close()
        except OSError:
            pass


class _Ring:
    """One SPSC byte ring inside the arena: monotone u64 head (writer) /
    tail (reader) counters plus a circular data area. Whole frames only —
    the writer advances head once per frame, after its last byte."""

    def __init__(self, arena: memoryview, hdr_off: int, data_off: int,
                 size: int):
        self._arena = arena
        self._hdr = hdr_off
        self._data = arena[data_off:data_off + size]
        # numpy views for the bulk copies: ndarray slice-assign out of the
        # mmap into a fresh (non-zeroed) np.empty measures ~5x faster than
        # bytearray allocation + memoryview slice-assign for ~1 MB frames.
        self._np = np.frombuffer(self._data, np.uint8)
        self.size = size

    # Counter loads/stores are 8-byte aligned single-word accesses — atomic
    # on every platform jax runs on; each counter has exactly one writer.
    @property
    def head(self) -> int:
        return _U64.unpack_from(self._arena, self._hdr)[0]

    @property
    def tail(self) -> int:
        return _U64.unpack_from(self._arena, self._hdr + 8)[0]

    def free(self) -> int:
        return self.size - (self.head - self.tail)

    def avail(self) -> int:
        return self.head - self.tail

    def write(self, segments, total: int) -> None:
        """Copy ``segments`` in at head (caller checked ``free() >= total``),
        then publish the frame by advancing head once."""
        pos = self.head
        i = pos % self.size
        for seg in segments:
            src = np.frombuffer(seg, np.uint8)
            n = len(src)
            if i + n <= self.size:
                self._np[i:i + n] = src
                i = (i + n) % self.size
            else:
                first = self.size - i
                self._np[i:] = src[:first]
                self._np[:n - first] = src[first:]
                i = n - first
        _U64.pack_into(self._arena, self._hdr, pos + total)

    def read_out(self, offset: int, n: int) -> np.ndarray:
        """Copy ``n`` bytes at ``tail + offset`` out of the ring (split-safe;
        does not consume)."""
        i = (self.tail + offset) % self.size
        out = np.empty(n, np.uint8)
        first = min(n, self.size - i)
        out[:first] = self._np[i:i + first]
        if n > first:
            out[first:] = self._np[:n - first]
        return out

    def consume(self, n: int) -> None:
        _U64.pack_into(self._arena, self._hdr + 8, self.tail + n)

    def release(self) -> None:
        self._data.release()


class ShmRingTransport(Transport):
    """Same-host transport over a mmap'd two-ring arena; the socket stays
    as the control plane (ACKs, small control frames, liveness)."""

    kind = "shm"

    def __init__(self, sock: socket.socket, reader: wire.FrameReader,
                 mm: mmap.mmap, *, is_server: bool, ring_bytes: int,
                 max_payload: int | None = None):
        self._sock = sock
        self._reader = reader
        self._mm = mm
        self._mv = memoryview(mm)
        self._max_payload = wire.MAX_PAYLOAD if max_payload is None \
            else max_payload
        a = _Ring(self._mv, _HDR_A, _DATA_OFF, ring_bytes)            # c2s
        b = _Ring(self._mv, _HDR_B, _DATA_OFF + ring_bytes, ring_bytes)  # s2c
        self._send_ring, self._recv_ring = (b, a) if is_server else (a, b)
        self._send_lock = threading.Lock()   # ring writer
        self._ctrl_lock = threading.Lock()   # socket writer
        # Below this size a data frame rides the socket: for small frames
        # (priority updates, sample requests) one sendmsg beats ring write +
        # doorbell + wake-and-pop; the ring earns its copies on bulk frames.
        self._ring_min = min(RING_CUTOVER_BYTES, ring_bytes // 4)
        self._peer_eof = False
        self._closed = False
        self._ring_in = 0
        self._ring_out = 0
        self._ctrl_out = 0
        self.last_trace_id = 0

    # -- establishment ------------------------------------------------------

    @classmethod
    def establish(cls, sock: socket.socket, *,
                  ring_bytes: int = DEFAULT_RING_BYTES,
                  max_payload: int | None = None,
                  timeout: float = 10.0) -> "ShmRingTransport":
        """Client side of the upgrade handshake. Raises
        :class:`ShmUnavailable` when the serving side refuses or never
        answers (the socket is still clean TCP then — ``connect(kind="auto")``
        falls back on it)."""
        cap = wire.MAX_PAYLOAD if max_payload is None else max_payload
        reader = wire.FrameReader(sock, max_payload=cap)
        wire.send_frame(sock, wire.SHM_REQ,
                        wire.encode_json({"ring_bytes": int(ring_bytes)}))
        try:
            got = reader.read_frame(timeout=timeout)
        except EOFError as e:
            raise ShmUnavailable(f"peer closed during shm handshake: {e}") \
                from e
        if got is None:
            raise ShmUnavailable("shm handshake timed out")
        msg, payload = got
        if msg == wire.SHM_NACK:
            raise ShmUnavailable(
                wire.decode_json(payload).get("reason", "refused"))
        if msg != wire.SHM_SETUP:
            raise wire.WireError(
                f"shm handshake: expected SHM_SETUP, got {msg}")
        setup = wire.decode_json(payload)
        path, n = setup["path"], int(setup["ring_bytes"])
        # Past this point the serving side is committed to rings: an attach
        # failure is a hard connection failure, not a fallback.
        fd = os.open(path, os.O_RDWR)
        try:
            mm = mmap.mmap(fd, 0)
        finally:
            os.close(fd)
        if mm[:8] != _ARENA_MAGIC or _U64.unpack_from(mm, 8)[0] != n:
            mm.close()
            raise wire.WireError(f"shm arena {path!r} failed validation")
        wire.send_frame(sock, wire.SHM_ATTACHED)
        return cls(sock, reader, mm, is_server=False, ring_bytes=n,
                   max_payload=max_payload)

    # -- accounting ---------------------------------------------------------

    @property
    def bytes_in(self) -> int:
        return self._ring_in + self._reader.bytes_in

    @property
    def bytes_out(self) -> int:
        return self._ring_out + self._ctrl_out

    # -- send ---------------------------------------------------------------

    def send(self, msg_type: int, payload: Any = b"",
             trace_id: int = 0) -> int:
        if self._closed:
            raise TransportClosed("transport is closed")
        segs = wire.frame_iov(msg_type, payload, self._max_payload,
                              trace_id)
        total = wire.iov_len(segs)
        if msg_type not in DATA_TYPES or total <= self._ring_min:
            with self._ctrl_lock:
                _sendmsg_all(self._sock, segs)
                self._ctrl_out += total
            return total
        if total > self._send_ring.size:
            raise wire.WireError(
                f"frame of {total} bytes exceeds the {self._send_ring.size}"
                f"-byte ring — raise ring_bytes for payloads this large")
        with self._send_lock:
            try:
                sleep = 0.0
                while self._send_ring.free() < total:
                    if self._closed or self._peer_eof or self._peer_gone():
                        raise TransportClosed(
                            "ring peer gone with the ring full")
                    time.sleep(sleep)
                    sleep = min(_POLL_MAX_S, sleep + _POLL_STEP_S)
                self._send_ring.write(segs, total)
            except ValueError:
                raise TransportClosed("transport is closed") from None
            self._ring_out += total
        # Doorbell after the commit: the peer's recv blocks on the socket
        # and pops exactly one ring frame per doorbell, so delivery order is
        # the socket's FIFO order and commit latency is a socket wake-up,
        # not a sleep quantum. The count invariant survives concurrent
        # senders: when doorbell #k arrives, k distinct commits are done,
        # and ring commits are prefix-ordered, so frame #k is committed.
        with self._ctrl_lock:
            try:
                _sendmsg_all(self._sock, wire.frame_iov(wire.SHM_DOORBELL,
                                                        b""))
                self._ctrl_out += wire.HEADER_SIZE
            except TransportClosed:
                pass  # frame is committed; the reader drains the ring on EOF
        return total

    def _peer_gone(self) -> bool:
        """Liveness probe usable from the send side: MSG_PEEK never steals
        control frames from the recv side. The zero-timeout select guard
        matters — a dead peer makes the fd readable (EOF), an idle one does
        not, and probing an idle socket through ``recv`` would park in
        Python's internal readiness wait for the socket's full timeout."""
        try:
            readable, _, _ = select.select([self._sock], [], [], 0)
            if not readable:
                return False
            return self._sock.recv(1, socket.MSG_PEEK
                                   | socket.MSG_DONTWAIT) == b""
        except (BlockingIOError, InterruptedError, socket.timeout,
                TimeoutError):
            return False
        except (OSError, ValueError):
            return True

    # -- recv ---------------------------------------------------------------

    def _pop_ring(self) -> tuple[int, memoryview] | None:
        ring = self._recv_ring
        try:
            avail = ring.avail()
        except ValueError:
            # close() released the arena under this concurrent recv — the
            # shutdown race, not corruption; surface the normal EOF signal.
            raise EOFError("transport closed locally") from None
        if avail == 0:
            return None
        if avail < wire.HEADER_SIZE:
            raise wire.WireError(f"torn ring frame: {avail} bytes committed")
        hdr = ring.read_out(0, wire.HEADER_SIZE)
        magic, version, msg_type, length, trace_id = \
            wire._HEADER.unpack_from(hdr, 0)
        wire.check_header(magic, version, length, self._max_payload)
        if avail < wire.HEADER_SIZE + length:
            raise wire.WireError(
                f"torn ring frame: {avail} of {wire.HEADER_SIZE + length} "
                f"bytes committed")
        # The one receive-side copy: the payload must outlive ring reuse.
        payload = ring.read_out(wire.HEADER_SIZE, length)
        ring.consume(wire.HEADER_SIZE + length)
        self._ring_in += wire.HEADER_SIZE + length
        self.last_trace_id = trace_id
        return msg_type, memoryview(payload)

    def recv(self, timeout: float | None = None,
             ) -> tuple[int, memoryview] | None:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self._closed:
                raise EOFError("transport closed locally")
            if self._peer_eof:
                got = self._pop_ring()  # deliver committed frames first
                if got is not None:
                    return got
                raise EOFError("peer closed")
            if deadline is None:
                wait = None
            else:
                # At/past the deadline, wait=0 still makes one non-blocking
                # poll — ``timeout=0`` means "poll", as on the tcp path.
                wait = max(0.0, deadline - time.monotonic())
            # Block on the control socket — never touch the ring until its
            # doorbell arrives: the socket is the single delivery order for
            # both channels (a doorbell *is* the ring frame's FIFO slot),
            # control frames carry themselves, and peer death is socket
            # EOF. Commit latency is a socket wake-up, not a sleep quantum.
            try:
                ctrl = self._reader.read_frame(timeout=wait)
            except EOFError:
                self._peer_eof = True
                continue
            if ctrl is None:
                return None
            if ctrl[0] != wire.SHM_DOORBELL:
                self.last_trace_id = self._reader.last_trace_id
                return ctrl
            got = self._pop_ring()
            if got is None:
                # Commit happens-before the doorbell send, so an empty ring
                # here is a protocol violation, not a race.
                raise wire.WireError("doorbell rang on an empty ring")
            return got

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass
        try:
            self._send_ring.release()
            self._recv_ring.release()
            self._mv.release()
            self._mm.close()
        except BufferError:
            # A decoded view still aliases the arena somewhere: leak the
            # mapping rather than invalidate live buffers.
            pass


class Listener:
    """Serving-side acceptor; every accepted connection is a
    :class:`TcpTransport` that upgrades itself to shm when the client asks
    (``accept_shm=False`` NACKs such requests instead)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 backlog: int = 16, accept_shm: bool = True,
                 ring_bytes: int = DEFAULT_RING_BYTES,
                 max_payload: int | None = None, poll_s: float = 0.2):
        self._sock = socket.create_server((host, port), backlog=backlog)
        self._sock.settimeout(poll_s)
        self._accept_shm = accept_shm
        self._ring_bytes = ring_bytes
        self._max_payload = max_payload
        self.host, self.port = self._sock.getsockname()[:2]

    def accept(self, timeout: float | None = None) -> TcpTransport | None:
        """Next connection or None on timeout; raises ``OSError`` once the
        listener is closed."""
        if timeout is not None:
            self._sock.settimeout(timeout)
        try:
            sock, _ = self._sock.accept()
        except (socket.timeout, TimeoutError):
            return None
        _tune(sock)
        sock.settimeout(None)
        return TcpTransport(sock, max_payload=self._max_payload,
                            accept_shm=self._accept_shm,
                            ring_bytes=self._ring_bytes)

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

_LOOPBACK = {"localhost", "127.0.0.1", "::1", ""}


def is_local_host(host: str) -> bool:
    """Same-host detection for ``kind="auto"``: loopback names/addresses."""
    return host in _LOOPBACK or host.startswith("127.")


def resolve_kind(kind: str, host: str) -> str:
    if kind == "auto":
        return "shm" if is_local_host(host) else "tcp"
    if kind not in ("tcp", "shm"):
        raise ValueError(f"transport kind must be tcp|shm|auto, got {kind!r}")
    return kind


def connect(host: str, port: int, kind: str = "auto", *,
            timeout: float | None = 10.0,
            ring_bytes: int = DEFAULT_RING_BYTES,
            max_payload: int | None = None) -> Transport:
    """Dial a gateway and return a ready transport. ``auto`` tries the shm
    upgrade against loopback peers and falls back to plain TCP when the
    serving side refuses; ``shm`` makes refusal an error."""
    want = resolve_kind(kind, host)
    sock = socket.create_connection((host, port), timeout=timeout)
    _tune(sock)
    sock.settimeout(None)
    if want == "tcp":
        return TcpTransport(sock, max_payload=max_payload)
    try:
        return ShmRingTransport.establish(
            sock, ring_bytes=ring_bytes, max_payload=max_payload,
            timeout=10.0 if timeout is None else timeout)
    except ShmUnavailable:
        if kind != "auto":
            try:
                sock.close()
            finally:
                raise
        return TcpTransport(sock, max_payload=max_payload)


def listen(host: str = "127.0.0.1", port: int = 0, **kw) -> Listener:
    return Listener(host, port, **kw)


# ---------------------------------------------------------------------------
# Arena plumbing
# ---------------------------------------------------------------------------

def _create_arena(ring_bytes: int) -> tuple[str, mmap.mmap]:
    """mkstemp + ftruncate + mmap one two-ring arena; prefers ``/dev/shm``
    (tmpfs — guaranteed RAM-backed) and falls back to the default tmp dir,
    which is still a correct same-host shared mapping."""
    if ring_bytes < (1 << 12) or ring_bytes > (1 << 34):
        raise ValueError(f"ring_bytes {ring_bytes} out of range")
    size = _DATA_OFF + 2 * ring_bytes
    shm_dir = "/dev/shm" if os.path.isdir("/dev/shm") else None
    fd, path = tempfile.mkstemp(prefix="apx-ring-", dir=shm_dir)
    try:
        os.ftruncate(fd, size)
        mm = mmap.mmap(fd, size)
    except BaseException:
        os.close(fd)
        _unlink_quiet(path)
        raise
    os.close(fd)
    mm[:8] = _ARENA_MAGIC
    _U64.pack_into(mm, 8, ring_bytes)
    return path, mm


def _unlink_quiet(path: str) -> None:
    try:
        os.unlink(path)
    except OSError:
        pass
