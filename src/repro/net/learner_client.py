"""Remote learner client: sample a replay fabric on another host.

The Gorila lineage ("Massively Parallel Methods for Deep RL") separates
learners from the replay memory across machines; in-network experience
sampling work pushes the same boundary into the transport. This module is
that boundary for our runtime: :class:`RemoteFabricSource` implements the
``repro.runtime.sources.SampleSource`` protocol over the ``repro.net`` wire
format, so the learner loop in ``runtime/runner.py`` runs unchanged against
a fabric it cannot touch in-process.

Per batch, the exchange is strict request/reply::

    learner ── SAMPLE_REQUEST ──────────────► gateway
    learner ◄───────── SAMPLE_BATCH ───────── gateway   (empty = starved)
    learner ── PRIORITY_UPDATE (async) ─────► gateway
    learner ── PARAM_PUSH (on publish) ─────► gateway

Deliberately *serial and simple*: the client holds at most one outstanding
request and does no overlap of its own. Hiding the round trip + decode +
host→device copy behind learner compute is the job of the ``StagedSource``
decorator — wrap this source in one (``AsyncConfig.sample_staging``) and the
stager thread runs this client's request/decode while the learner computes
on the previous batch. That keeps the overlap policy in one place instead of
re-implemented per transport.

Thread contract: ``get_batch`` (and therefore the socket *reader*) belongs
to one consumer thread (the learner, or the stager when wrapped);
``write_back``/``publish_params`` only send and may be called from the
learner thread concurrently with a stager's ``get_batch`` — sends are
serialized by an internal lock.

Numerics: batches carry final globally-corrected IS weights and global
(shard, slot) keys; fp32/int32 leaves travel bit-identically, so a remote
learner consumes byte-for-byte what a local learner would.
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Any

from repro.core.sampling import LearnerBatch
from repro.net import wire
from repro.runtime.service import ServiceStats
from repro.runtime.sources import SampleSource, SourceClosed, SourceStats


class RemoteFabricSource(SampleSource):
    """Sample/write-back against a ``ReplayGateway`` over TCP."""

    def __init__(self, host: str, port: int, *,
                 connect_timeout_s: float = 10.0, poll_s: float = 0.05):
        self._addr = (host, int(port))
        self._connect_timeout_s = connect_timeout_s
        self._poll_s = poll_s
        self._sock: socket.socket | None = None
        self._reader: wire.FrameReader | None = None
        self._send_lock = threading.Lock()
        self._requested = False   # one SAMPLE_REQUEST may be outstanding
        self._closed = False
        self.stats = SourceStats()
        self.bytes_out = 0

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "RemoteFabricSource":
        """Connect and handshake. Connection attempts retry until the
        timeout — the serving runtime may still be binding its gateway when
        the learner host comes up."""
        deadline = time.monotonic() + self._connect_timeout_s
        while True:
            try:
                self._sock = socket.create_connection(
                    self._addr, timeout=self._connect_timeout_s)
                break
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(0.1)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._reader = wire.FrameReader(self._sock)
        self._send(wire.HELLO, wire.encode_json(
            {"actor_id": -1, "role": "learner",
             "protocol": wire.PROTOCOL_VERSION}))
        return self

    def stop(self) -> None:
        if self._sock is None:
            return
        try:
            self._send(wire.BYE, wire.encode_json(
                {"rollouts": 0, "blocked": self.stats.starved_polls}))
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._closed = True

    def _send(self, msg_type: int, payload: bytes = b"") -> None:
        with self._send_lock:
            self.bytes_out += wire.send_frame(self._sock, msg_type, payload)

    # -- SampleSource -------------------------------------------------------

    def get_batch(self, timeout: float | None = None) -> LearnerBatch | None:
        """Request/await one batch. None on reply timeout or a starved
        (empty) reply; the outstanding request survives a timeout, so the
        next call resumes waiting instead of double-requesting."""
        if self._closed:
            raise SourceClosed("remote fabric connection is closed")
        if not self._requested:
            self._send(wire.SAMPLE_REQUEST)
            self._requested = True
        try:
            got = self._reader.read_frame(
                timeout=self._poll_s if timeout is None else timeout)
        except EOFError as e:
            self._closed = True
            raise SourceClosed(
                "replay gateway went away while the learner was sampling"
            ) from e
        if got is None:
            self.stats.starved_polls += 1
            return None
        msg_type, payload = got
        self._requested = False
        if msg_type == wire.STOP:
            self._closed = True
            raise SourceClosed(
                "replay gateway sent STOP while the learner was sampling")
        if msg_type != wire.SAMPLE_BATCH:
            raise wire.WireError(
                f"unexpected message {msg_type} from gateway")
        if len(payload) == 0:   # fabric starved: poll again
            self.stats.starved_polls += 1
            return None
        batch = wire.decode_sample_batch(payload)
        self.stats.batches += 1
        return batch

    def write_back(self, indices: Any, priorities: Any) -> None:
        self._send(wire.PRIORITY_UPDATE,
                   wire.encode_priority_update(indices, priorities))
        self.stats.writebacks += 1

    def publish_params(self, version: int, params: Any) -> None:
        """Ship fresh learner params to the gateway, which publishes them
        into *its* ParamStore — the one the fabric-side actors pull from —
        closing the acting↔learning loop across the machine boundary."""
        self._send(wire.PARAM_PUSH, wire.encode_params(version, params))
        self.stats.param_pushes += 1

    def snapshot(self) -> ServiceStats:
        """Client-side view: what this learner consumed/wrote back. The
        authoritative replay counters live in the serving host's fabric and
        gateway snapshots."""
        return ServiceStats(batches_sampled=self.stats.batches,
                            updates_applied=self.stats.writebacks)

    @property
    def bytes_in(self) -> int:
        return self._reader.bytes_in if self._reader is not None else 0


def parse_hostport(spec: str, default_host: str = "127.0.0.1",
                   ) -> tuple[str, int]:
    """``"host:port"`` (or bare ``"port"``) → ``(host, port)``, with an
    actionable error for anything else — including out-of-range ports,
    which would otherwise surface as an OverflowError (or a futile retry
    loop, for port 0) deep inside the connect path."""
    host, _, port = spec.rpartition(":")
    try:
        port_num = int(port)
    except ValueError:
        raise ValueError(
            f"expected HOST:PORT (or just PORT), got {spec!r}") from None
    if not 1 <= port_num <= 65535:
        raise ValueError(f"port must be in [1, 65535], got {port_num} "
                         f"(from {spec!r})")
    return (host or default_host, port_num)
