"""Remote learner client: sample a replay fabric on another host.

The Gorila lineage ("Massively Parallel Methods for Deep RL") separates
learners from the replay memory across machines; in-network experience
sampling work pushes the same boundary into the transport. This module is
that boundary for our runtime: :class:`RemoteFabricSource` implements the
``repro.runtime.sources.SampleSource`` protocol over the ``repro.net`` wire
format, so the learner loop in ``runtime/runner.py`` runs unchanged against
a fabric it cannot touch in-process.

Per batch, the exchange is strict request/reply::

    learner ── PRIORITY_UPDATE (coalesced) ─► gateway
    learner ── SAMPLE_REQUEST ──────────────► gateway
    learner ◄───────── SAMPLE_BATCH ───────── gateway   (empty = starved)
    learner ── PARAM_PUSH (on publish) ─────► gateway

Deliberately *serial and simple*: the client holds at most one outstanding
request and does no overlap of its own. Hiding the round trip + decode +
host→device copy behind learner compute is the job of the ``StagedSource``
decorator — wrap this source in one (``AsyncConfig.sample_staging``) and the
stager thread runs this client's request/decode while the learner computes
on the previous batch. That keeps the overlap policy in one place instead of
re-implemented per transport.

Write-backs coalesce: ``write_back`` only parks the arrays, and the pending
rounds ship as **one** ``PRIORITY_UPDATE`` frame right before the next
``SAMPLE_REQUEST`` (or params push / shutdown) — one frame per sample round
instead of one per learner step. Rounds are concatenated in call order with
their per-round lengths in the frame's ``counts`` leaf; the gateway
re-applies each round as its own ``fabric.write_back``, so last-writer-wins
ordering AND eviction-clock pacing are exactly those of per-round frames,
and its learner clock (``priority_updates``) keeps counting rounds.

The byte-moving layer is ``repro.net.transport``: ``transport="tcp"`` dials
the classic socket path, ``"shm"`` requires the same-host ring upgrade, and
``"auto"`` (default) uses shm when the gateway host is loopback-local.

Fault tolerance: a *severed* transport (socket reset, gateway restart —
anything but an explicit ``STOP``) does not kill the source. It reconnects
with capped backoff (``reconnect_timeout_s``), re-handshakes, and resumes:
the outstanding sample request is re-issued, parked write-backs re-ship
(safe — priorities are idempotent last-writer-wins updates), and a param
push retries once on the fresh transport. Only an explicit ``STOP``, a
``stop()`` on this side, or a gateway that stays away past the deadline
surfaces as :class:`SourceClosed` from ``get_batch``. Survived reconnects
are counted in ``SourceStats.reconnects`` and the ``source/reconnects``
telemetry counter.

Thread contract: ``get_batch`` (and therefore the transport *reader*)
belongs to one consumer thread (the learner, or the stager when wrapped);
``write_back``/``publish_params`` may be called from the learner thread
concurrently — they only touch the pending list / send under locks.

Numerics: batches carry final globally-corrected IS weights and global
(shard, slot) keys; fp32/int32 leaves travel bit-identically, so a remote
learner consumes byte-for-byte what a local learner would (unless the lossy
``quantize_prios``/``quantize_params`` wire options are enabled).
"""

from __future__ import annotations

import threading
import time
from typing import Any

import numpy as np

from repro.core.sampling import LearnerBatch
from repro.net import transport as transport_lib
from repro.net import wire
from repro.obs import Telemetry
from repro.runtime.service import ServiceStats
from repro.runtime.sources import SampleSource, SourceClosed, SourceStats


class RemoteFabricSource(SampleSource):
    """Sample/write-back against a ``ReplayGateway`` over tcp or shm."""

    def __init__(self, host: str, port: int, *,
                 transport: str = "auto",
                 connect_timeout_s: float = 10.0, poll_s: float = 0.05,
                 ring_bytes: int = transport_lib.DEFAULT_RING_BYTES,
                 quantize_prios: bool = False,
                 quantize_params: bool = False,
                 reconnect: bool = True,
                 reconnect_timeout_s: float = 20.0,
                 telemetry: Telemetry | None = None):
        self._addr = (host, int(port))
        self._kind = transport_lib.resolve_kind(transport, host) \
            if transport != "auto" else "auto"
        self._connect_timeout_s = connect_timeout_s
        self._poll_s = poll_s
        self._ring_bytes = ring_bytes
        self._quantize_prios = quantize_prios
        self._quantize_params = quantize_params
        self._reconnect = reconnect
        self._reconnect_timeout_s = reconnect_timeout_s
        self._reconnect_lock = threading.Lock()
        self._conn_gen = 0        # bumped per successful (re)connection
        self._conn: transport_lib.Transport | None = None
        self._requested = False   # one SAMPLE_REQUEST may be outstanding
        self._closed = False
        self._pending: list[tuple[np.ndarray, np.ndarray, int]] = []
        self._pending_lock = threading.Lock()
        self.stats = SourceStats()
        self._tel = telemetry if telemetry is not None else Telemetry.local()
        self._h_get = self._tel.histogram("source/get_batch_us")
        self._c_starved = self._tel.counter("source/starved_polls")
        self._c_reconnects = self._tel.counter("source/reconnects")
        self.last_trace_id = 0

    # -- lifecycle ----------------------------------------------------------

    def _dial(self, deadline: float, backoff: float = 0.1,
              ) -> transport_lib.Transport:
        """Connect with retries until ``deadline`` (monotonic seconds)."""
        while True:
            try:
                return transport_lib.connect(
                    *self._addr, self._kind,
                    timeout=self._connect_timeout_s,
                    ring_bytes=self._ring_bytes)
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(backoff)
                backoff = min(backoff * 2, 1.0)

    def _hello(self, reconnects: int = 0) -> None:
        self._conn.send(wire.HELLO, wire.encode_json(
            {"actor_id": -1, "role": "learner",
             "protocol": wire.PROTOCOL_VERSION,
             "reconnects": reconnects}))

    def start(self) -> "RemoteFabricSource":
        """Connect and handshake. Connection attempts retry until the
        timeout — the serving runtime may still be binding its gateway when
        the learner host comes up."""
        self._conn = self._dial(time.monotonic() + self._connect_timeout_s,
                                backoff=0.1)
        self._hello()
        return self

    def _revive(self, cause: BaseException, what: str) -> None:
        """Reconnect with capped backoff after a severed transport (never
        after an explicit STOP — that is a shutdown, not a fault). Raises
        :class:`SourceClosed` when reconnecting is disabled, the source is
        stopping, or the gateway stays away past ``reconnect_timeout_s``.
        Safe from both the consumer thread and the learner thread: the
        first to arrive reconnects, late arrivals observe the already-fresh
        connection generation and return."""
        if not self._reconnect or self._closed:
            self._closed = True
            raise SourceClosed(
                f"replay gateway went away {what}") from cause
        gen = self._conn_gen
        with self._reconnect_lock:
            if self._closed:
                raise SourceClosed(
                    f"replay gateway went away {what}") from cause
            if self._conn_gen != gen:
                return  # the other thread already reconnected
            try:
                self._conn.close()
            except OSError:
                pass
            try:
                conn = self._dial(
                    time.monotonic() + self._reconnect_timeout_s,
                    backoff=0.05)
            except OSError:
                self._closed = True
                raise SourceClosed(
                    f"replay gateway went away {what} and did not come "
                    f"back within {self._reconnect_timeout_s}s") from cause
            self._conn = conn
            # The request (if any) died with the old transport; the next
            # get_batch re-requests. Parked write-backs re-ship on the new
            # transport — priorities are idempotent LWW updates, so a
            # re-send after reconnect is safe.
            self._requested = False
            self._conn_gen += 1
            self.stats.reconnects += 1
            self._c_reconnects.inc()
            try:
                self._hello(reconnects=self.stats.reconnects)
            except (OSError, transport_lib.TransportClosed) as e:
                self._closed = True
                raise SourceClosed(
                    f"replay gateway went away again during the reconnect "
                    f"handshake ({what})") from e

    def stop(self) -> None:
        self._closed = True  # no revive attempts during shutdown
        if self._conn is None:
            return
        try:
            self._flush_writebacks()
            # "learner" marks this BYE as the sample-plane client leaving:
            # a serving runtime treats it as end-of-run even when a severed
            # transport swallowed some in-flight priority frames (bounded
            # loss the replay tolerates), instead of waiting forever for a
            # count that will never arrive.
            self._conn.send(wire.BYE, wire.encode_json(
                {"rollouts": 0, "blocked": self.stats.starved_polls,
                 "learner": True, "writebacks": self.stats.writebacks}))
        except (OSError, SourceClosed):
            pass
        self._conn.close()

    @property
    def transport_kind(self) -> str:
        """Resolved transport of the live connection (``tcp``/``shm``)."""
        return self._conn.kind if self._conn is not None else self._kind

    # -- SampleSource -------------------------------------------------------

    def _flush_writebacks(self) -> None:
        """Ship every parked write-back round as one coalesced frame.
        Concatenation order = ``write_back`` call order, so a key written
        twice keeps its later priority (last-writer-wins)."""
        with self._pending_lock:
            pending, self._pending = self._pending, []
        if not pending:
            return
        if len(pending) == 1:
            idx, prios, _ = pending[0]
        else:
            idx = np.concatenate([p[0] for p in pending])
            prios = np.concatenate([p[1] for p in pending])
        counts = [p[0].shape[0] for p in pending]
        # A coalesced frame carries one header trace id; the most recent
        # traced round wins (rounds are rarely coalesced at rates where
        # tracing is on, so in practice this is "the" round's id).
        tid = next((p[2] for p in reversed(pending) if p[2]), 0)
        try:
            self._conn.send(wire.PRIORITY_UPDATE, wire.encode_priority_update(
                idx, prios, counts=counts,
                quantize=self._quantize_prios), trace_id=tid)
        except (transport_lib.TransportClosed, OSError) as e:
            # Re-park the rounds first (priorities are idempotent LWW
            # updates — re-sending after a reconnect is safe), then revive
            # the transport; they ship with the next flush.
            with self._pending_lock:
                self._pending = pending + self._pending
            self._revive(e, "during priority write-back")
            return
        self.stats.writeback_frames += 1

    def get_batch(self, timeout: float | None = None) -> LearnerBatch | None:
        """Request/await one batch. None on reply timeout or a starved
        (empty) reply; the outstanding request survives a timeout, so the
        next call resumes waiting instead of double-requesting."""
        if self._closed:
            raise SourceClosed("remote fabric connection is closed")
        t0 = time.perf_counter()
        if not self._requested:
            self._flush_writebacks()
            try:
                self._conn.send(wire.SAMPLE_REQUEST)
            except (transport_lib.TransportClosed, OSError) as e:
                self._revive(e, "while requesting a sample")
                self.stats.starved_polls += 1
                self._c_starved.inc()
                return None
            self._requested = True
        try:
            got = self._conn.recv(
                timeout=self._poll_s if timeout is None else timeout)
        except (EOFError, transport_lib.TransportClosed) as e:
            # Severed mid-reply: the outstanding request (and possibly a
            # sampled batch) died with the transport — an accepted loss,
            # the replay tolerates unreturned batches. Reconnect and let
            # the next call re-request.
            self._revive(e, "while the learner was sampling")
            self.stats.starved_polls += 1
            self._c_starved.inc()
            return None
        if got is None:
            self.stats.starved_polls += 1
            self._c_starved.inc()
            return None
        msg_type, payload = got
        self._requested = False
        if msg_type == wire.STOP:
            self._closed = True
            raise SourceClosed(
                "replay gateway sent STOP while the learner was sampling")
        if msg_type != wire.SAMPLE_BATCH:
            raise wire.WireError(
                f"unexpected message {msg_type} from gateway")
        if len(payload) == 0:   # fabric starved: poll again
            self.stats.starved_polls += 1
            self._c_starved.inc()
            return None
        batch = wire.decode_sample_batch(payload)
        us = 1e6 * (time.perf_counter() - t0)
        self._h_get.record(us)
        # A batch starts a fresh consume-plane trace client-side (the
        # gateway's SAMPLE_BATCH header is untraced): the learner is the
        # process whose sink records this run's spans.
        tid = self._tel.tracer.sample()
        if tid:
            self._tel.tracer.record("sample", tid, us,
                                    transport=self.transport_kind)
        self.last_trace_id = tid
        self.stats.batches += 1
        return batch

    def write_back(self, indices: Any, priorities: Any,
                   trace_id: int = 0) -> None:
        """Park one write-back round; it ships coalesced with the next
        sample request (or params push / shutdown flush)."""
        row = (np.asarray(indices), np.asarray(priorities), trace_id)
        with self._pending_lock:
            self._pending.append(row)
        self.stats.writebacks += 1

    def publish_params(self, version: int, params: Any) -> None:
        """Ship fresh learner params to the gateway, which publishes them
        into *its* ParamStore — the one the fabric-side actors pull from —
        closing the acting↔learning loop across the machine boundary."""
        self._flush_writebacks()
        payload = wire.encode_params_iov(
            version, params, quantize=self._quantize_params)
        try:
            self._conn.send(wire.PARAM_PUSH, payload)
        except (transport_lib.TransportClosed, OSError) as e:
            self._revive(e, "during param push")
            # One retry on the fresh transport: a param snapshot is an
            # idempotent publish, and actors need a current one after the
            # gateway came back.
            self._conn.send(wire.PARAM_PUSH, payload)
        self.stats.param_pushes += 1

    def snapshot(self) -> ServiceStats:
        """Client-side view: what this learner consumed/wrote back. The
        authoritative replay counters live in the serving host's fabric and
        gateway snapshots."""
        return ServiceStats(batches_sampled=self.stats.batches,
                            updates_applied=self.stats.writebacks)

    @property
    def bytes_in(self) -> int:
        return self._conn.bytes_in if self._conn is not None else 0

    @property
    def bytes_out(self) -> int:
        return self._conn.bytes_out if self._conn is not None else 0


def parse_hostport(spec: str, default_host: str = "127.0.0.1",
                   allow_ephemeral: bool = False) -> tuple[str, int]:
    """``"host:port"`` (or bare ``"port"``) → ``(host, port)``, with an
    actionable error for anything else — including out-of-range ports,
    which would otherwise surface as an OverflowError (or a futile retry
    loop, for port 0) deep inside the connect path. ``allow_ephemeral``
    admits port 0 — meaningful for a *bind* address (the OS picks), never
    for a connect target."""
    host, _, port = spec.rpartition(":")
    try:
        port_num = int(port)
    except ValueError:
        raise ValueError(
            f"expected HOST:PORT (or just PORT), got {spec!r}") from None
    low = 0 if allow_ephemeral else 1
    if not low <= port_num <= 65535:
        raise ValueError(f"port must be in [{low}, 65535], got {port_num} "
                         f"(from {spec!r})")
    return (host or default_host, port_num)
