"""Versioned length-prefixed wire protocol for actor → replay ingest.

The paper's actors run on hundreds of CPU hosts and stream experience into a
central replay (§3, after Gorila); the unit of that traffic is exactly the
in-process unit — a ``TransitionBlock`` of n-step transitions plus
actor-side initial priorities — so the wire format is a serialization of
that block, not a new abstraction. Three design rules:

* **Framed and versioned.** Every message is ``MAGIC | version | type |
  payload_len | payload``. A peer speaking a different protocol version is
  rejected at the first frame instead of corrupting the replay.
* **Arrays travel as raw bytes.** Payloads carrying tensors use a
  deterministic nested-dict codec (sorted key paths; per-leaf dtype/shape
  headers; C-order raw data). fp32 fields round-trip bit-identically —
  required for the remote path to be numerically indistinguishable from the
  in-process queue.
* **Observations may ride the replay codec.** With ``quantize_obs`` the
  float ``obs``/``next_obs`` leaves are quantized with
  ``repro.core.codec`` (the paper's PNG-compression analogue, §4.1) before
  serialization — ~4x less actor→replay bandwidth, the same uint8+affine
  representation the replay itself stores under ``compress_obs``. uint8
  observations pass through lossless; already-encoded blocks (actors
  running with ``compress_obs``) are dicts of uint8+fp32 leaves and are
  shipped as-is.

Message inventory (direction, payload):

=================  ==============  ==========================================
``HELLO``          actor → gw      JSON ``{actor_id, protocol}``
``ADD_BLOCK``      actor → gw      array-tree ``{items..., priorities}``
``ADD_ACK``        gw → actor      empty (one per routed block; the client's
                                   bounded in-flight window closes on these)
``PARAM_PULL``     actor → gw      JSON ``{have: version}``
``PARAM``          gw → actor      u64 version ++ array-tree params
``PARAM_UNCHANGED``gw → actor      JSON ``{version}``
``STOP``           gw → actor      empty (shutdown; actor drains and exits)
``BYE``            actor → gw      JSON client-side counters
``SAMPLE_REQUEST`` learner → gw    empty (one prioritized batch, please)
``SAMPLE_BATCH``   gw → learner    array-tree ``{indices, items,
                                   is_weights}``; *empty* payload = fabric
                                   starved (below min-fill / prefetch
                                   lagging), poll again
``PRIORITY_UPDATE``learner → gw    array-tree ``{indices, priorities}``
                                   (global (shard, slot) keys; fire-and-
                                   forget, like the in-process update queue)
``PARAM_PUSH``     learner → gw    u64 version ++ array-tree params (remote
                                   learner publishes into the gateway-side
                                   ParamStore its actors pull from)
=================  ==============  ==========================================

The last four frames are the *sample plane* (remote learners): a gateway
serves its replay fabric's learner side over the same connection discipline
as ingest, and because batches carry global keys and final IS weights, a
remote learner is numerically indistinguishable from a local one — fp32
leaves travel bit-identically.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import Any

import numpy as np

from repro.core import codec
from repro.core.sampling import LearnerBatch
from repro.runtime.phases import TransitionBlock

PROTOCOL_VERSION = 1
MAGIC = b"APXW"

# Frame header: magic, protocol version, message type, payload length.
_HEADER = struct.Struct("<4sHHI")

# Message types.
HELLO = 1
ADD_BLOCK = 2
ADD_ACK = 3
PARAM_PULL = 4
PARAM = 5
PARAM_UNCHANGED = 6
STOP = 7
BYE = 8
SAMPLE_REQUEST = 9
SAMPLE_BATCH = 10
PRIORITY_UPDATE = 11
PARAM_PUSH = 12

# Array-tree leaf header: key_len, dtype_len, ndim  (then key, dtype.str,
# shape as u32s, nbytes as u64, raw bytes).
_LEAF = struct.Struct("<HBB")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")

# Guard against a corrupt/hostile length prefix allocating unbounded memory.
# 256 MiB comfortably covers every legitimate payload (transition blocks are
# ~100 KB-class, param snapshots MB-class, sample batches well under that);
# a corrupt 4-byte prefix used to pass anything up to 2 GiB straight into
# the receive buffer's allocation. Peers that agree on genuinely larger
# payloads raise the bound on both ends: ``max_payload`` on the receiving
# ``FrameReader`` and on the sending ``frame``/``send_frame``.
MAX_PAYLOAD = 1 << 28

# Key used to mark a wire-quantized observation subtree.
_QUANT_KEY = "__wireq__"


class WireError(RuntimeError):
    """Malformed or protocol-incompatible traffic."""


# ---------------------------------------------------------------------------
# Array-tree codec (nested dicts of arrays <-> bytes)
# ---------------------------------------------------------------------------

def _flatten(tree: Any, prefix: str, out: list[tuple[str, np.ndarray]]) -> None:
    if isinstance(tree, dict):
        for k in sorted(tree):
            key = str(k)
            if "/" in key:
                raise WireError(f"tree key {key!r} may not contain '/'")
            _flatten(tree[k], f"{prefix}{key}/", out)
    else:
        out.append((prefix[:-1], np.asarray(tree)))


def encode_tree(tree: Any) -> bytes:
    """Serialize a pytree of nested dicts with array leaves. Deterministic:
    leaves are emitted in sorted key-path order, C-order raw bytes."""
    leaves: list[tuple[str, np.ndarray]] = []
    _flatten(tree, "", leaves)
    parts = [_U32.pack(len(leaves))]
    for key, arr in leaves:
        arr = np.ascontiguousarray(arr)
        kb = key.encode()
        db = arr.dtype.str.encode()
        parts.append(_LEAF.pack(len(kb), len(db), arr.ndim))
        parts.append(kb)
        parts.append(db)
        for d in arr.shape:
            parts.append(_U32.pack(d))
        raw = arr.tobytes()
        parts.append(_U64.pack(len(raw)))
        parts.append(raw)
    return b"".join(parts)


def decode_tree(payload: bytes | memoryview) -> dict:
    """Inverse of :func:`encode_tree`. Leaves are zero-copy (read-only)
    views into ``payload`` where alignment allows. Any malformed payload
    raises :class:`WireError` (so receivers can contain it to the one
    connection), never a raw struct/numpy/unicode error."""
    try:
        return _decode_tree(memoryview(payload))
    except WireError:
        raise
    except Exception as e:  # struct.error, ValueError, UnicodeDecodeError...
        raise WireError(f"malformed tree payload: {e!r}") from e


def _decode_tree(mv: memoryview) -> dict:
    (n,) = _U32.unpack_from(mv, 0)
    off = _U32.size
    tree: dict = {}
    for _ in range(n):
        klen, dlen, ndim = _LEAF.unpack_from(mv, off)
        off += _LEAF.size
        key = bytes(mv[off:off + klen]).decode()
        off += klen
        dtype = np.dtype(bytes(mv[off:off + dlen]).decode())
        off += dlen
        shape = []
        for _ in range(ndim):
            (d,) = _U32.unpack_from(mv, off)
            shape.append(d)
            off += _U32.size
        (nbytes,) = _U64.unpack_from(mv, off)
        off += _U64.size
        count = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if nbytes != count * dtype.itemsize:
            raise WireError(f"leaf {key!r}: {nbytes} bytes for shape "
                            f"{tuple(shape)} {dtype}")
        arr = np.frombuffer(mv, dtype, count=count, offset=off).reshape(shape)
        off += nbytes
        node = tree
        *path, leaf = key.split("/")
        for p in path:
            node = node.setdefault(p, {})
        node[leaf] = arr
    if off != len(mv):
        raise WireError(f"trailing bytes in tree payload ({len(mv) - off})")
    return tree


# ---------------------------------------------------------------------------
# TransitionBlock payloads
# ---------------------------------------------------------------------------

def _quantize_items(items: dict) -> dict:
    """Swap float obs/next_obs leaves for their replay-codec encoding, marked
    with a ``__wireq__`` subtree so the decoder knows to reverse it."""
    out = dict(items)
    for key in ("obs", "next_obs"):
        leaf = out.get(key)
        if isinstance(leaf, dict):        # compress_obs: already encoded
            continue
        arr = np.asarray(leaf)
        if arr.dtype == np.uint8:
            # already byte-sized: ship raw, skip the redundant scale/offset
            continue
        out[key] = {_QUANT_KEY: codec.encode_np(arr)._asdict()}
    return out


def _dequantize_items(items: dict) -> dict:
    out = dict(items)
    for key, leaf in items.items():
        if isinstance(leaf, dict) and set(leaf) == {_QUANT_KEY}:
            out[key] = codec.decode_np(codec.EncodedObs(**leaf[_QUANT_KEY]))
    return out


def encode_block(block: TransitionBlock, quantize_obs: bool = False) -> bytes:
    """``ADD_BLOCK`` payload for one transition block. ``quantize_obs``
    applies the replay codec to float observation leaves (uint8 + per-obs
    affine) — the decoded block then equals the in-process block up to the
    codec's quantization, while every other field is bit-identical."""
    items = jax_to_np(block.items)
    if quantize_obs:
        items = _quantize_items(items)
    prios = np.asarray(block.priorities)
    return encode_tree({"items": items, "priorities": prios})


def decode_block(payload: bytes | memoryview) -> TransitionBlock:
    """Inverse of :func:`encode_block` (numpy leaves; the replay shard's
    jitted add transfers them to the device on its own thread)."""
    tree = decode_tree(payload)
    try:
        items, prios = tree["items"], tree["priorities"]
        return TransitionBlock(items=_dequantize_items(items),
                               priorities=prios)
    except WireError:
        raise
    except Exception as e:  # missing keys, malformed __wireq__ subtree, ...
        raise WireError(f"malformed ADD_BLOCK payload: {e!r}") from e


def jax_to_np(tree: Any) -> Any:
    """Materialize a (possibly device-resident) pytree as numpy leaves."""
    if isinstance(tree, dict):
        return {k: jax_to_np(v) for k, v in tree.items()}
    return np.asarray(tree)


# ---------------------------------------------------------------------------
# Sample-plane payloads (remote learners)
# ---------------------------------------------------------------------------

def encode_sample_batch(batch: Any) -> bytes:
    """``SAMPLE_BATCH`` payload for one learner batch. Accepts anything with
    ``indices``/``items``/``is_weights`` fields (a merged ``LearnerBatch`` or
    a single-shard ``SampleBatch`` — shard-internal fields are *not* shipped:
    the wire carries exactly the learner-plane contract). fp32/int32 leaves
    round-trip bit-identically, so a remote learner's batch equals the local
    learner's bit for bit."""
    return encode_tree({
        "indices": np.asarray(batch.indices),
        "is_weights": np.asarray(batch.is_weights),
        "items": jax_to_np(batch.items),
    })


def decode_sample_batch(payload: bytes | memoryview) -> LearnerBatch:
    """Inverse of :func:`encode_sample_batch` (numpy leaves; the learner's
    jitted update — or a ``StagedSource`` wrapper — moves them on-device)."""
    tree = decode_tree(payload)
    try:
        return LearnerBatch(indices=tree["indices"], items=tree["items"],
                            is_weights=tree["is_weights"])
    except WireError:
        raise
    except Exception as e:  # missing keys
        raise WireError(f"malformed SAMPLE_BATCH payload: {e!r}") from e


def encode_priority_update(indices: Any, priorities: Any) -> bytes:
    """``PRIORITY_UPDATE`` payload: the write-back half of the sample plane.
    ``indices`` are the global (shard, slot) keys of a previously shipped
    batch (any subset/ordering — the keys are self-describing)."""
    return encode_tree({"indices": np.asarray(indices),
                        "priorities": np.asarray(priorities)})


def decode_priority_update(payload: bytes | memoryview,
                           ) -> tuple[np.ndarray, np.ndarray]:
    tree = decode_tree(payload)
    try:
        return tree["indices"], tree["priorities"]
    except WireError:
        raise
    except Exception as e:
        raise WireError(f"malformed PRIORITY_UPDATE payload: {e!r}") from e


# ---------------------------------------------------------------------------
# Parameter payloads
# ---------------------------------------------------------------------------

def encode_params(version: int, params: Any) -> bytes:
    """``PARAM`` payload: u64 store version, then the params array-tree."""
    return _U64.pack(version) + encode_tree(jax_to_np(params))


def decode_params(payload: bytes | memoryview) -> tuple[int, dict]:
    mv = memoryview(payload)
    try:
        (version,) = _U64.unpack_from(mv, 0)
    except Exception as e:
        raise WireError(f"malformed PARAM payload: {e!r}") from e
    return int(version), decode_tree(mv[_U64.size:])


# ---------------------------------------------------------------------------
# JSON control payloads
# ---------------------------------------------------------------------------

def encode_json(obj: dict) -> bytes:
    return json.dumps(obj, separators=(",", ":")).encode()


def decode_json(payload: bytes | memoryview) -> dict:
    try:
        return json.loads(bytes(payload).decode())
    except (ValueError, UnicodeDecodeError) as e:
        raise WireError(f"malformed JSON payload: {e!r}") from e


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------

def frame(msg_type: int, payload: bytes = b"",
          max_payload: int | None = None) -> bytes:
    """One wire frame: header + payload, ready for ``sendall``. Oversized
    payloads fail *here*, on the sender, with a clear error — the receiver
    would otherwise drop the whole connection on the length prefix.
    ``max_payload`` mirrors the ``FrameReader`` override: peers that agree
    on a larger bound raise it on both ends (sender here, receiver at the
    reader); the default is the module cap."""
    cap = MAX_PAYLOAD if max_payload is None else max_payload
    if len(payload) > cap:
        raise WireError(f"payload length {len(payload)} exceeds cap {cap}")
    return _HEADER.pack(MAGIC, PROTOCOL_VERSION, msg_type,
                        len(payload)) + payload


def send_frame(sock: socket.socket, msg_type: int, payload: bytes = b"",
               max_payload: int | None = None) -> int:
    buf = frame(msg_type, payload, max_payload)
    sock.sendall(buf)
    return len(buf)


class FrameReader:
    """Incremental frame parser over a stream socket.

    ``read_frame`` tolerates socket timeouts mid-frame: partially received
    bytes stay buffered, and the next call resumes where the stream left
    off — which is what lets single-threaded peers interleave blocking
    reads with periodic stop-flag checks.
    """

    def __init__(self, sock: socket.socket, chunk: int = 1 << 16,
                 max_payload: int = MAX_PAYLOAD):
        self._sock = sock
        self._chunk = chunk
        self._max_payload = max_payload
        self._buf = bytearray()
        self.bytes_in = 0
        self.eof = False

    def _fill(self, need: int, timeout: float | None) -> bool:
        """Grow the buffer to ``need`` bytes; False on timeout, raises
        ``EOFError`` when the peer closed mid-stream."""
        self._sock.settimeout(timeout)
        while len(self._buf) < need:
            try:
                data = self._sock.recv(max(self._chunk, need - len(self._buf)))
            except (socket.timeout, TimeoutError):
                return False
            except OSError:
                data = b""  # peer reset / socket shut down: treat as EOF
            if not data:
                self.eof = True
                if self._buf:
                    raise EOFError("peer closed mid-frame")
                raise EOFError("peer closed")
            self._buf += data
            self.bytes_in += len(data)
        return True

    def read_frame(self, timeout: float | None = None,
                   ) -> tuple[int, memoryview] | None:
        """Next ``(msg_type, payload)`` or None on timeout. Raises
        ``EOFError`` on a cleanly closed peer, ``WireError`` on garbage."""
        if not self._fill(_HEADER.size, timeout):
            return None
        magic, version, msg_type, length = _HEADER.unpack_from(self._buf, 0)
        if magic != MAGIC:
            raise WireError(f"bad magic {magic!r}")
        if version != PROTOCOL_VERSION:
            raise WireError(f"protocol version {version} != "
                            f"{PROTOCOL_VERSION}")
        if length > self._max_payload:
            # Reject before any payload-sized allocation: a corrupt/hostile
            # 4-byte prefix must not size the receive buffer.
            raise WireError(f"payload length {length} exceeds cap "
                            f"{self._max_payload}")
        if not self._fill(_HEADER.size + length, timeout):
            return None
        payload = bytes(self._buf[_HEADER.size:_HEADER.size + length])
        del self._buf[:_HEADER.size + length]
        return msg_type, memoryview(payload)
