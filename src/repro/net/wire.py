"""Versioned length-prefixed wire protocol for actor → replay ingest.

The paper's actors run on hundreds of CPU hosts and stream experience into a
central replay (§3, after Gorila); the unit of that traffic is exactly the
in-process unit — a ``TransitionBlock`` of n-step transitions plus
actor-side initial priorities — so the wire format is a serialization of
that block, not a new abstraction. Three design rules:

* **Framed and versioned.** Every message is ``MAGIC | version | type |
  payload_len | trace_id | payload``. A peer speaking a different protocol
  version is rejected at the first frame instead of corrupting the replay.
* **Arrays travel as raw bytes.** Payloads carrying tensors use a
  deterministic nested-dict codec (sorted key paths; per-leaf dtype/shape
  headers; C-order raw data). fp32 fields round-trip bit-identically —
  required for the remote path to be numerically indistinguishable from the
  in-process queue.
* **Observations may ride the replay codec.** With ``quantize_obs`` the
  float ``obs``/``next_obs`` leaves are quantized with
  ``repro.core.codec`` (the paper's PNG-compression analogue, §4.1) before
  serialization — ~4x less actor→replay bandwidth, the same uint8+affine
  representation the replay itself stores under ``compress_obs``. uint8
  observations pass through lossless; already-encoded blocks (actors
  running with ``compress_obs``) are dicts of uint8+fp32 leaves and are
  shipped as-is.

Message inventory (direction, payload):

=================  ==============  ==========================================
``HELLO``          actor → gw      JSON ``{actor_id, protocol}``
``ADD_BLOCK``      actor → gw      array-tree ``{items..., priorities}``
``ADD_ACK``        gw → actor      empty (one per routed block; the client's
                                   bounded in-flight window closes on these)
``PARAM_PULL``     actor → gw      JSON ``{have: version}``
``PARAM``          gw → actor      u64 version ++ array-tree params
``PARAM_UNCHANGED``gw → actor      JSON ``{version}``
``STOP``           gw → actor      empty (shutdown; actor drains and exits)
``BYE``            actor → gw      JSON client-side counters
``SAMPLE_REQUEST`` learner → gw    empty (one prioritized batch, please)
``SAMPLE_BATCH``   gw → learner    array-tree ``{indices, items,
                                   is_weights}``; *empty* payload = fabric
                                   starved (below min-fill / prefetch
                                   lagging), poll again
``PRIORITY_UPDATE``learner → gw    array-tree ``{counts, indices,
                                   priorities}`` (global (shard, slot) keys;
                                   fire-and-forget, like the in-process
                                   update queue; may coalesce several
                                   write-back rounds — ``counts`` holds the
                                   per-round lengths, concatenation order =
                                   call order, and the receiver re-applies
                                   round by round, so last-writer-wins AND
                                   eviction-clock pacing are preserved)
``PARAM_PUSH``     learner → gw    u64 version ++ array-tree params (remote
                                   learner publishes into the gateway-side
                                   ParamStore its actors pull from)
``SHM_REQ``        client → gw     JSON ``{ring_bytes}`` — ask to upgrade
                                   this connection to a shared-memory ring
``SHM_SETUP``      gw → client     JSON ``{path, ring_bytes}`` — arena ready
``SHM_ATTACHED``   client → gw     empty (client mapped the arena; the
                                   gateway unlinks the file and switches)
``SHM_NACK``       gw → client     JSON ``{reason}`` — stay on TCP
``ACT_REQUEST``    client → gw     array-tree: one ``ActorSlice`` (leaves in
                                   tree-flatten order; typed PRNG keys as raw
                                   uint32 key data) plus the shard id — "run
                                   one rollout for me on the policy server"
``ACT_RESULT``     gw → client     array-tree: the advanced slice, the
                                   rollout's ``TransitionBlock``, and the
                                   act-phase metrics
=================  ==============  ==========================================

``SAMPLE_REQUEST`` .. ``PARAM_PUSH`` are the *sample plane* (remote
learners): a gateway serves its replay fabric's learner side over the same
connection discipline as ingest, and because batches carry global keys and
final IS weights, a remote learner is numerically indistinguishable from a
local one — fp32 leaves travel bit-identically. The ``SHM_*`` frames are the
transport-upgrade handshake (``repro.net.transport``); they never carry
experience.

Protocol v2 adds the ``SHM_*`` handshake and the ``counts`` leaf in
``PRIORITY_UPDATE`` (v1 peers are rejected at the first frame, as always).

Protocol v3 adds a fixed ``trace_id`` (u64) field to the frame header for
end-to-end pipeline tracing (``repro.obs``): a sampled ``ADD_BLOCK`` carries
its block's trace id from the actor process into the gateway, and a
``SAMPLE_BATCH``/``PRIORITY_UPDATE`` carries the batch's id between learner
and gateway. ``trace_id = 0`` means untraced — the common case — so the
cost on every frame is 8 header bytes, nothing else. The id is header
metadata, not payload: codecs are unchanged and fp32 leaves still travel
bit-identically.

The ``ACT_*`` frames are the *policy plane* (``--serve-policy``): a thin
remote client ships its ``ActorSlice`` to a gateway fronting the shared
:class:`repro.runtime.inference.InferenceServer` and receives the advanced
slice + transition block back — Gorila's one-policy-many-clients surface.
They are new message types on the same v3 framing (no version bump: an old
peer that receives one rejects the *message*, not the stream version).
fp32/int32 leaves and PRNG key data round-trip bit-identically, so a remote
rollout equals the in-process rollout bit for bit.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import Any

import numpy as np

from repro.core import codec
from repro.core.sampling import LearnerBatch
from repro.runtime.phases import TransitionBlock

PROTOCOL_VERSION = 3
MAGIC = b"APXW"

# Frame header: magic, protocol version, message type, payload length,
# trace id (0 = untraced; see repro.obs.trace).
_HEADER = struct.Struct("<4sHHIQ")
HEADER_SIZE = _HEADER.size

# Message types.
HELLO = 1
ADD_BLOCK = 2
ADD_ACK = 3
PARAM_PULL = 4
PARAM = 5
PARAM_UNCHANGED = 6
STOP = 7
BYE = 8
SAMPLE_REQUEST = 9
SAMPLE_BATCH = 10
PRIORITY_UPDATE = 11
PARAM_PUSH = 12
SHM_REQ = 13
SHM_SETUP = 14
SHM_ATTACHED = 15
SHM_NACK = 16
SHM_DOORBELL = 17   # header-only: "a frame was committed to the ring"
ACT_REQUEST = 18    # policy plane: ActorSlice + shard id -> run one rollout
ACT_RESULT = 19     # policy plane: advanced slice + TransitionBlock + metrics

# Array-tree leaf header: key_len, dtype_len, ndim  (then key, dtype.str,
# shape as u32s, nbytes as u64, raw bytes).
_LEAF = struct.Struct("<HBB")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")

# Guard against a corrupt/hostile length prefix allocating unbounded memory.
# 256 MiB comfortably covers every legitimate payload (transition blocks are
# ~100 KB-class, param snapshots MB-class, sample batches well under that);
# a corrupt 4-byte prefix used to pass anything up to 2 GiB straight into
# the receive buffer's allocation. Peers that agree on genuinely larger
# payloads raise the bound on both ends: ``max_payload`` on the receiving
# ``FrameReader`` and on the sending ``frame``/``send_frame``.
MAX_PAYLOAD = 1 << 28

# Key used to mark a wire-quantized subtree (obs, priorities, param leaves).
_QUANT_KEY = "__wireq__"

# Leaves smaller than this are packed into the accumulated metadata buffer
# of a scatter-gather encode instead of travelling as their own segment —
# a segment per 4-byte scalar would cost more iovec bookkeeping than the
# copy it avoids.
_IOV_INLINE = 1024


class WireError(RuntimeError):
    """Malformed or protocol-incompatible traffic."""


# ---------------------------------------------------------------------------
# Array-tree codec (nested dicts of arrays <-> bytes)
# ---------------------------------------------------------------------------

def _flatten(tree: Any, prefix: str, out: list[tuple[str, np.ndarray]]) -> None:
    if isinstance(tree, dict):
        for k in sorted(tree):
            key = str(k)
            if "/" in key:
                raise WireError(f"tree key {key!r} may not contain '/'")
            _flatten(tree[k], f"{prefix}{key}/", out)
    else:
        out.append((prefix[:-1], np.asarray(tree)))


def encode_tree(tree: Any) -> bytes:
    """Serialize a pytree of nested dicts with array leaves. Deterministic:
    leaves are emitted in sorted key-path order, C-order raw bytes."""
    leaves: list[tuple[str, np.ndarray]] = []
    _flatten(tree, "", leaves)
    parts = [_U32.pack(len(leaves))]
    for key, arr in leaves:
        arr = np.ascontiguousarray(arr)
        kb = key.encode()
        db = arr.dtype.str.encode()
        parts.append(_LEAF.pack(len(kb), len(db), arr.ndim))
        parts.append(kb)
        parts.append(db)
        for d in arr.shape:
            parts.append(_U32.pack(d))
        raw = arr.tobytes()
        parts.append(_U64.pack(len(raw)))
        parts.append(raw)
    return b"".join(parts)


def encode_tree_iov(tree: Any) -> list:
    """Scatter-gather twin of :func:`encode_tree`: the same byte stream as a
    list of buffers (``bytes`` metadata runs + read-only memoryviews over the
    large array leaves) for ``sendmsg``/ring-segment transports — large
    tensors are never copied into an intermediate payload buffer.

    ``b"".join(encode_tree_iov(t)) == encode_tree(t)`` bitwise, for every
    tree (property-tested). Segments alias the caller's arrays: they are
    valid until the arrays are mutated, so transports must finish writing
    before ``send`` returns (both ``repro.net.transport`` paths do).
    """
    leaves: list[tuple[str, np.ndarray]] = []
    _flatten(tree, "", leaves)
    out: list = []
    meta = bytearray(_U32.pack(len(leaves)))
    for key, arr in leaves:
        arr = np.ascontiguousarray(arr)
        kb = key.encode()
        db = arr.dtype.str.encode()
        meta += _LEAF.pack(len(kb), len(db), arr.ndim)
        meta += kb
        meta += db
        for d in arr.shape:
            meta += _U32.pack(d)
        meta += _U64.pack(arr.nbytes)
        if arr.nbytes < _IOV_INLINE:
            meta += arr.tobytes()
        else:
            out.append(bytes(meta))
            meta = bytearray()
            out.append(memoryview(arr).cast("B"))
    if meta:
        out.append(bytes(meta))
    return out


def iov_len(segments) -> int:
    """Total byte length of a scatter-gather segment list."""
    return sum(len(s) for s in segments)


def decode_tree(payload: bytes | memoryview) -> dict:
    """Inverse of :func:`encode_tree`. Leaves are zero-copy (read-only)
    views into ``payload`` where alignment allows. Any malformed payload
    raises :class:`WireError` (so receivers can contain it to the one
    connection), never a raw struct/numpy/unicode error."""
    try:
        return _decode_tree(memoryview(payload))
    except WireError:
        raise
    except Exception as e:  # struct.error, ValueError, UnicodeDecodeError...
        raise WireError(f"malformed tree payload: {e!r}") from e


def _decode_tree(mv: memoryview) -> dict:
    (n,) = _U32.unpack_from(mv, 0)
    off = _U32.size
    tree: dict = {}
    for _ in range(n):
        klen, dlen, ndim = _LEAF.unpack_from(mv, off)
        off += _LEAF.size
        key = bytes(mv[off:off + klen]).decode()
        off += klen
        dtype = np.dtype(bytes(mv[off:off + dlen]).decode())
        off += dlen
        shape = []
        for _ in range(ndim):
            (d,) = _U32.unpack_from(mv, off)
            shape.append(d)
            off += _U32.size
        (nbytes,) = _U64.unpack_from(mv, off)
        off += _U64.size
        count = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if nbytes != count * dtype.itemsize:
            raise WireError(f"leaf {key!r}: {nbytes} bytes for shape "
                            f"{tuple(shape)} {dtype}")
        arr = np.frombuffer(mv, dtype, count=count, offset=off).reshape(shape)
        off += nbytes
        node = tree
        *path, leaf = key.split("/")
        for p in path:
            node = node.setdefault(p, {})
        node[leaf] = arr
    if off != len(mv):
        raise WireError(f"trailing bytes in tree payload ({len(mv) - off})")
    return tree


# ---------------------------------------------------------------------------
# TransitionBlock payloads
# ---------------------------------------------------------------------------

def quantize_leaf(arr: Any, feature_dims: int | None = None) -> Any:
    """Swap one float array for its replay-codec encoding, marked with a
    ``__wireq__`` subtree so :func:`dequantize_tree` knows to reverse it.

    ``feature_dims`` picks the affine granularity: 1 = per-row over the
    trailing axis (the observation convention), None = one affine over the
    whole tensor (priorities, param leaves). uint8 and non-float inputs pass
    through untouched (already byte-sized / must stay exact), as do scalars
    (nothing to quantize over).
    """
    arr = np.asarray(arr)
    if arr.dtype.kind != "f" or arr.ndim == 0:
        return arr
    fd = arr.ndim if feature_dims is None else feature_dims
    return {_QUANT_KEY: codec.encode(arr, feature_dims=fd)._asdict()}


def dequantize_tree(tree: Any) -> Any:
    """Recursively undo :func:`quantize_leaf` markers anywhere in a tree."""
    if not isinstance(tree, dict):
        return tree
    if set(tree) == {_QUANT_KEY}:
        return codec.decode(codec.EncodedObs(**tree[_QUANT_KEY]))
    return {k: dequantize_tree(v) for k, v in tree.items()}


def _quantize_items(items: dict) -> dict:
    """Swap float obs/next_obs leaves for their replay-codec encoding."""
    out = dict(items)
    for key in ("obs", "next_obs"):
        leaf = out.get(key)
        if isinstance(leaf, dict):        # compress_obs: already encoded
            continue
        arr = np.asarray(leaf)
        if arr.dtype == np.uint8:
            # already byte-sized: ship raw, skip the redundant scale/offset
            continue
        out[key] = quantize_leaf(arr, feature_dims=1)
    return out


def _quantize_params(params: Any) -> Any:
    """Per-leaf whole-tensor affine over a param tree (scalars and integer
    leaves pass through exact)."""
    if isinstance(params, dict):
        return {k: _quantize_params(v) for k, v in params.items()}
    return quantize_leaf(params, feature_dims=None)


def _block_tree(block: TransitionBlock, quantize_obs: bool) -> dict:
    items = jax_to_np(block.items)
    if quantize_obs:
        items = _quantize_items(items)
    return {"items": items, "priorities": np.asarray(block.priorities)}


def encode_block(block: TransitionBlock, quantize_obs: bool = False) -> bytes:
    """``ADD_BLOCK`` payload for one transition block. ``quantize_obs``
    applies the replay codec to float observation leaves (uint8 + per-obs
    affine) — the decoded block then equals the in-process block up to the
    codec's quantization, while every other field is bit-identical."""
    return encode_tree(_block_tree(block, quantize_obs))


def encode_block_iov(block: TransitionBlock,
                     quantize_obs: bool = False) -> list:
    """Scatter-gather twin of :func:`encode_block` (same bytes on the wire,
    obs tensors travel as views instead of being copied into one buffer)."""
    return encode_tree_iov(_block_tree(block, quantize_obs))


def decode_block(payload: bytes | memoryview) -> TransitionBlock:
    """Inverse of :func:`encode_block` (numpy leaves; the replay shard's
    jitted add transfers them to the device on its own thread)."""
    tree = decode_tree(payload)
    try:
        items, prios = tree["items"], tree["priorities"]
        return TransitionBlock(items=dequantize_tree(items),
                               priorities=prios)
    except WireError:
        raise
    except Exception as e:  # missing keys, malformed __wireq__ subtree, ...
        raise WireError(f"malformed ADD_BLOCK payload: {e!r}") from e


def jax_to_np(tree: Any) -> Any:
    """Materialize a (possibly device-resident) pytree as numpy leaves."""
    if isinstance(tree, dict):
        return {k: jax_to_np(v) for k, v in tree.items()}
    return np.asarray(tree)


# ---------------------------------------------------------------------------
# Sample-plane payloads (remote learners)
# ---------------------------------------------------------------------------

def _sample_batch_tree(batch: Any) -> dict:
    return {
        "indices": np.asarray(batch.indices),
        "is_weights": np.asarray(batch.is_weights),
        "items": jax_to_np(batch.items),
    }


def encode_sample_batch(batch: Any) -> bytes:
    """``SAMPLE_BATCH`` payload for one learner batch. Accepts anything with
    ``indices``/``items``/``is_weights`` fields (a merged ``LearnerBatch`` or
    a single-shard ``SampleBatch`` — shard-internal fields are *not* shipped:
    the wire carries exactly the learner-plane contract). fp32/int32 leaves
    round-trip bit-identically, so a remote learner's batch equals the local
    learner's bit for bit."""
    return encode_tree(_sample_batch_tree(batch))


def encode_sample_batch_iov(batch: Any) -> list:
    """Scatter-gather twin of :func:`encode_sample_batch`."""
    return encode_tree_iov(_sample_batch_tree(batch))


def decode_sample_batch(payload: bytes | memoryview) -> LearnerBatch:
    """Inverse of :func:`encode_sample_batch` (numpy leaves; the learner's
    jitted update — or a ``StagedSource`` wrapper — moves them on-device)."""
    tree = decode_tree(payload)
    try:
        return LearnerBatch(indices=tree["indices"], items=tree["items"],
                            is_weights=tree["is_weights"])
    except WireError:
        raise
    except Exception as e:  # missing keys
        raise WireError(f"malformed SAMPLE_BATCH payload: {e!r}") from e


def encode_priority_update(indices: Any, priorities: Any, *,
                           counts: Any = None,
                           quantize: bool = False) -> bytes:
    """``PRIORITY_UPDATE`` payload: the write-back half of the sample plane.
    ``indices`` are the global (shard, slot) keys of previously shipped
    batches (any subset/ordering — the keys are self-describing). A frame may
    carry several coalesced write-back rounds concatenated in call order;
    ``counts`` gives the per-round lengths (default: one round spanning the
    whole frame). The receiver re-applies each round as its own
    ``fabric.write_back`` call, so a duplicate key's later priority lands
    later (last-writer-wins) AND the shard eviction clock ticks once per
    round — byte-coalescing never changes replay semantics.
    ``quantize`` ships the priorities uint8+affine via the replay codec."""
    idx = np.asarray(indices)
    prios = np.asarray(priorities)
    counts = (np.asarray([idx.shape[0]], np.uint32) if counts is None
              else np.asarray(counts, np.uint32))
    return encode_tree({
        "counts": counts,
        "indices": idx,
        "priorities": quantize_leaf(prios) if quantize else prios,
    })


def decode_priority_update(payload: bytes | memoryview,
                           ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Inverse of :func:`encode_priority_update`:
    ``(indices, priorities, counts)`` with ``sum(counts) == len(indices)``."""
    tree = decode_tree(payload)
    try:
        idx = tree["indices"]
        prios = dequantize_tree(tree["priorities"])
        counts = tree["counts"]
        if int(counts.sum()) != int(idx.shape[0]):
            raise WireError(
                f"PRIORITY_UPDATE round counts sum to {int(counts.sum())} "
                f"but the frame carries {int(idx.shape[0])} keys")
        return idx, prios, counts
    except WireError:
        raise
    except Exception as e:
        raise WireError(f"malformed PRIORITY_UPDATE payload: {e!r}") from e


# ---------------------------------------------------------------------------
# Parameter payloads
# ---------------------------------------------------------------------------

def encode_params(version: int, params: Any,
                  quantize: bool = False) -> bytes:
    """``PARAM`` payload: u64 store version, then the params array-tree.
    ``quantize`` applies a whole-tensor affine per float leaf (scalars and
    integer leaves stay exact) — ~4x less param bandwidth; the decoder
    reverses it transparently via the ``__wireq__`` markers."""
    tree = jax_to_np(params)
    if quantize:
        tree = _quantize_params(tree)
    return _U64.pack(version) + encode_tree(tree)


def encode_params_iov(version: int, params: Any,
                      quantize: bool = False) -> list:
    """Scatter-gather twin of :func:`encode_params`."""
    tree = jax_to_np(params)
    if quantize:
        tree = _quantize_params(tree)
    iov = encode_tree_iov(tree)
    return [_U64.pack(version) + iov[0], *iov[1:]]


def decode_params(payload: bytes | memoryview) -> tuple[int, dict]:
    mv = memoryview(payload)
    try:
        (version,) = _U64.unpack_from(mv, 0)
    except Exception as e:
        raise WireError(f"malformed PARAM payload: {e!r}") from e
    return int(version), dequantize_tree(decode_tree(mv[_U64.size:]))


# ---------------------------------------------------------------------------
# Policy-plane payloads (ACT_REQUEST / ACT_RESULT)
# ---------------------------------------------------------------------------
# An ActorSlice is a nested NamedTuple pytree (env state, obs, rng, ...),
# not a dict — it travels as its tree-flatten leaf list under zero-padded
# index keys, and the receiver unflattens against a locally derived example
# slice (both sides rebuild the identical structure from (cfg, env, seed,
# actor_id), so shipping the treedef would be redundant). Typed PRNG keys
# cannot be viewed as numpy arrays; they travel as their raw uint32 key
# data, which round-trips exactly — required for remote rollouts to be
# bit-identical to in-process ones.

def _is_prng_key(leaf: Any) -> bool:
    import jax
    dt = getattr(leaf, "dtype", None)
    return dt is not None and jax.dtypes.issubdtype(dt, jax.dtypes.prng_key)


def _slice_tree(aslice: Any) -> dict:
    import jax
    leaves = jax.tree_util.tree_leaves(aslice)
    return {f"{i:04d}": np.asarray(jax.random.key_data(leaf)
                                   if _is_prng_key(leaf) else leaf)
            for i, leaf in enumerate(leaves)}


def _unflatten_slice(tree: dict, example: Any) -> Any:
    import jax
    ex_leaves, treedef = jax.tree_util.tree_flatten(example)
    if len(tree) != len(ex_leaves):
        raise WireError(f"slice payload carries {len(tree)} leaves, the "
                        f"local example slice has {len(ex_leaves)} — "
                        "mismatched (cfg, env) geometry between peers")
    leaves = []
    for i, ex in enumerate(ex_leaves):
        arr = tree[f"{i:04d}"]
        if _is_prng_key(ex):
            leaves.append(jax.random.wrap_key_data(
                arr, impl=jax.random.key_impl(ex)))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def encode_act_request(aslice: Any, shard_id: int) -> bytes:
    """``ACT_REQUEST`` payload: one actor's slice + its ladder shard id."""
    return encode_tree({"sid": np.asarray(int(shard_id), np.int32),
                        "slice": _slice_tree(aslice)})


def decode_act_request(payload: bytes | memoryview,
                       example: Any) -> tuple[Any, int]:
    """Inverse of :func:`encode_act_request`; ``example`` is a locally
    built ActorSlice providing the tree structure and key impls."""
    tree = decode_tree(payload)
    try:
        return (_unflatten_slice(tree["slice"], example),
                int(np.asarray(tree["sid"]).reshape(())))
    except WireError:
        raise
    except Exception as e:
        raise WireError(f"malformed ACT_REQUEST payload: {e!r}") from e


def encode_act_result(aslice: Any, block: TransitionBlock,
                      metrics: dict) -> bytes:
    """``ACT_RESULT`` payload: the advanced slice, the rollout's transition
    block, and the act-phase metrics (scalar leaves)."""
    return encode_tree({
        "slice": _slice_tree(aslice),
        "block": {"items": jax_to_np(block.items),
                  "priorities": np.asarray(block.priorities)},
        "metrics": {str(k): np.asarray(v) for k, v in metrics.items()},
    })


def decode_act_result(payload: bytes | memoryview, example: Any,
                      ) -> tuple[Any, TransitionBlock, dict]:
    """Inverse of :func:`encode_act_result` (numpy block leaves, exactly
    like :func:`decode_block`)."""
    tree = decode_tree(payload)
    try:
        aslice = _unflatten_slice(tree["slice"], example)
        block = TransitionBlock(items=tree["block"]["items"],
                                priorities=tree["block"]["priorities"])
        return aslice, block, dict(tree.get("metrics", {}))
    except WireError:
        raise
    except Exception as e:
        raise WireError(f"malformed ACT_RESULT payload: {e!r}") from e


# ---------------------------------------------------------------------------
# JSON control payloads
# ---------------------------------------------------------------------------

def encode_json(obj: dict) -> bytes:
    return json.dumps(obj, separators=(",", ":")).encode()


def decode_json(payload: bytes | memoryview) -> dict:
    try:
        return json.loads(bytes(payload).decode())
    except (ValueError, UnicodeDecodeError) as e:
        raise WireError(f"malformed JSON payload: {e!r}") from e


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------

def frame(msg_type: int, payload: bytes = b"",
          max_payload: int | None = None, trace_id: int = 0) -> bytes:
    """One wire frame: header + payload, ready for ``sendall``. Oversized
    payloads fail *here*, on the sender, with a clear error — the receiver
    would otherwise drop the whole connection on the length prefix.
    ``max_payload`` mirrors the ``FrameReader`` override: peers that agree
    on a larger bound raise it on both ends (sender here, receiver at the
    reader); the default is the module cap. ``trace_id`` stamps the v3
    header field (0 = untraced)."""
    cap = MAX_PAYLOAD if max_payload is None else max_payload
    if len(payload) > cap:
        raise WireError(f"payload length {len(payload)} exceeds cap {cap}")
    return _HEADER.pack(MAGIC, PROTOCOL_VERSION, msg_type,
                        len(payload), trace_id) + payload


def as_segments(payload: Any) -> list:
    """Normalize a frame payload — ``bytes``-like or an iovec-style list of
    buffers — to a list of byte-level buffers (numpy arrays become read-only
    C-order byte views, nothing is concatenated)."""
    if isinstance(payload, (list, tuple)):
        return [s for p in payload for s in as_segments(p)]
    if isinstance(payload, np.ndarray):
        return [memoryview(np.ascontiguousarray(payload)).cast("B")]
    mv = memoryview(payload)
    return [mv if mv.format == "B" and mv.ndim == 1 else mv.cast("B")]


def frame_iov(msg_type: int, payload: Any = b"",
              max_payload: int | None = None, trace_id: int = 0) -> list:
    """Scatter-gather twin of :func:`frame`: ``[header, *segments]`` ready
    for ``socket.sendmsg`` or ring-segment writes — the concatenation equals
    ``frame(msg_type, b"".join(segments))`` bitwise. Oversized payloads fail
    here on the sender, exactly like :func:`frame`."""
    segs = as_segments(payload)
    total = iov_len(segs)
    cap = MAX_PAYLOAD if max_payload is None else max_payload
    if total > cap:
        raise WireError(f"payload length {total} exceeds cap {cap}")
    return [_HEADER.pack(MAGIC, PROTOCOL_VERSION, msg_type, total,
                         trace_id), *segs]


def send_frame(sock: socket.socket, msg_type: int, payload: bytes = b"",
               max_payload: int | None = None, trace_id: int = 0) -> int:
    buf = frame(msg_type, payload, max_payload, trace_id)
    sock.sendall(buf)
    return len(buf)


def check_header(magic: bytes, version: int, length: int,
                 max_payload: int) -> None:
    """Shared frame-header validation (socket reader and shm rings)."""
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r}")
    if version != PROTOCOL_VERSION:
        raise WireError(
            f"peer speaks protocol v{version}, this process speaks "
            f"v{PROTOCOL_VERSION} (v3 added a trace-id header field for "
            f"pipeline tracing) — upgrade the older peer; mixed versions "
            f"cannot share a frame stream")
    if length > max_payload:
        # Reject before any payload-sized allocation: a corrupt/hostile
        # 4-byte prefix must not size the receive buffer.
        raise WireError(f"payload length {length} exceeds cap "
                        f"{max_payload}")


class FrameReader:
    """Incremental frame parser over a stream socket.

    ``read_frame`` tolerates socket timeouts mid-frame: partially received
    bytes stay buffered, and the next call resumes where the stream left
    off — which is what lets single-threaded peers interleave blocking
    reads with periodic stop-flag checks. Bytes land via ``recv_into``
    directly in the frame's own buffer (header scratch, then a
    payload-sized bytearray), so a payload is never copied host-side after
    the kernel hands it over — the old bytearray-append path cost two extra
    copies per frame. ``timeout=0`` polls without blocking.
    """

    def __init__(self, sock: socket.socket, chunk: int = 1 << 16,
                 max_payload: int = MAX_PAYLOAD):
        self._sock = sock
        del chunk  # kept for signature compat; recv_into needs no chunking
        self._max_payload = max_payload
        self._hdr = bytearray(_HEADER.size)
        self._hdr_mv = memoryview(self._hdr)
        self._hdr_got = 0
        self._msg_type = 0
        self._length = -1              # -1: header not yet parsed
        self._payload: bytearray | None = None
        self._pay_mv: memoryview | None = None
        self._pay_got = 0
        self._trace_id = 0
        self.bytes_in = 0
        self.eof = False
        # Trace id from the most recent frame *returned* by read_frame
        # (0 = untraced). Header metadata, so the (msg_type, payload)
        # return shape is unchanged for the many existing call sites.
        self.last_trace_id = 0

    def _recv_some(self, mv: memoryview, timeout: float | None) -> int | None:
        """One ``recv_into``; None on timeout/would-block, raises
        ``EOFError`` when the peer closed mid-stream."""
        self._sock.settimeout(timeout)
        try:
            n = self._sock.recv_into(mv)
        except (socket.timeout, TimeoutError, BlockingIOError,
                InterruptedError):
            return None
        except OSError:
            n = 0  # peer reset / socket shut down: treat as EOF
        if n == 0:
            self.eof = True
            if self._hdr_got:
                raise EOFError("peer closed mid-frame")
            raise EOFError("peer closed")
        self.bytes_in += n
        return n

    def _parse_header(self) -> None:
        magic, version, msg_type, length, trace_id = _HEADER.unpack_from(
            self._hdr, 0)
        check_header(magic, version, length, self._max_payload)
        self._msg_type = msg_type
        self._length = length
        self._trace_id = trace_id
        self._payload = bytearray(length)
        self._pay_mv = memoryview(self._payload)
        self._pay_got = 0

    def read_frame(self, timeout: float | None = None,
                   ) -> tuple[int, memoryview] | None:
        """Next ``(msg_type, payload)`` or None on timeout. Raises
        ``EOFError`` on a cleanly closed peer, ``WireError`` on garbage."""
        while self._hdr_got < _HEADER.size:
            n = self._recv_some(self._hdr_mv[self._hdr_got:], timeout)
            if n is None:
                return None
            self._hdr_got += n
        if self._length < 0:
            self._parse_header()   # WireError sticks: re-raised every call
        while self._pay_got < self._length:
            n = self._recv_some(self._pay_mv[self._pay_got:], timeout)
            if n is None:
                return None
            self._pay_got += n
        msg_type, payload = self._msg_type, self._payload
        self.last_trace_id = self._trace_id
        self._payload = self._pay_mv = None
        self._hdr_got, self._length = 0, -1
        return msg_type, memoryview(payload)
