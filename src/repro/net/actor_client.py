"""Remote actor process: jitted rollouts streamed to a ``ReplayGateway``.

This is the paper's actor binary (Alg. 1) as a separate OS process — the
piece that makes "hundreds of actors on hundreds of machines" real rather
than thread-simulated. Each process:

1. connects to the gateway (``--transport tcp|shm|auto``: same-host
   processes upgrade to a shared-memory ring, cross-host stays TCP) and
   handshakes (``HELLO``, protocol-versioned);
2. pulls the initial parameter snapshot (Alg. 1 l.1);
3. loops: jitted ``act_phase`` rollout → serialize the ``TransitionBlock``
   (optionally quantizing float observations with the replay codec) →
   ``ADD_BLOCK`` → every ``param_sync_period`` rollouts, ``PARAM_PULL``
   (Alg. 1 l.2, periodic refresh);
4. exits on ``STOP`` from the gateway (learner finished) or a torn-down
   transport, reporting its client-side counters in a final ``BYE``.

Backpressure mirrors the in-process path: at most ``max_inflight``
un-acknowledged blocks may be on the wire. The gateway only ACKs a block
*after* it lands in the fabric's bounded shard queue, so a saturated replay
holds ACKs back and the remote actor blocks exactly where a local actor
thread would block on ``fabric.add`` (waits counted like ``actor_blocked``).

Blocks ship scatter-gather: ``encode_block_iov`` hands the transport a list
of buffer views (tensor leaves are not concatenated host-side), so the TCP
path writes them with one ``sendmsg`` and the shm path copies each leaf
exactly once, straight into the ring arena.

Numerics: the actor's rng/epsilon geometry is derived from ``(seed,
actor_id)`` by the same fold-in scheme ``runtime/runner.py`` uses for actor
threads, so a run with K threads + M processes spans one exploration ladder
over K+M actors, and moving an actor across the process boundary does not
change its stream.

Run standalone against a remote host (the multi-host path)::

    python -m repro.net.actor_client --host <gateway> --port <p> \
        --preset apex-dqn --actor-id 3 --num-actors 8
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.net import transport as transport_lib
from repro.net import wire
from repro.obs import Tracer
from repro.runtime import phases


@dataclasses.dataclass(frozen=True)
class RemoteActorSpec:
    """Everything a remote actor process needs; must pickle (spawn)."""

    cfg: Any                      # apex.ApexConfig with num_shards = total actors
    env: Any
    agent: Any
    host: str
    port: int
    actor_id: int                 # global ladder position (threads first)
    seed: int = 0                 # runner's AsyncConfig.seed
    max_inflight: int = 4         # un-acked ADD_BLOCKs allowed on the wire
    quantize_obs: bool = False    # wire-quantize float obs (replay codec)
    transport: str = "auto"       # tcp | shm | auto (shm iff host is local)
    ring_bytes: int = transport_lib.DEFAULT_RING_BYTES
    param_sync_period: int | None = None  # default: cfg.param_sync_period
    max_rollouts: int | None = None       # None: run until STOP / EOF
    pin_cpu: int | None = None    # pin this process (and its XLA threads)
                                  # to one core — the paper's one-actor-per-
                                  # CPU model; unpinned actors let XLA's
                                  # intra-op pool spread across cores
    target_blocks_per_s: float | None = None  # pace sends to this offered
                                  # rate (load-test mode: benchmarks drive a
                                  # known aggregate load instead of racing
                                  # the machine); None: run flat out
    connect_timeout_s: float = 10.0
    param_timeout_s: float = 120.0  # a backpressured gateway answers pulls
                                    # late (its handler is busy holding our
                                    # ACKs back) — that's congestion, not
                                    # death, so this bound is generous
    reconnect: bool = True        # a severed transport mid-run (anything but
                                  # an explicit STOP) reconnects with capped
                                  # backoff instead of exiting; un-acked
                                  # blocks are dropped (a temporary dip in
                                  # ingest, the paper's tolerated loss)
    reconnect_timeout_s: float = 20.0  # give up (clean exit) when the
                                  # gateway stays away this long
    poll_s: float = 0.05          # wait granularity on a full window
    trace_sample_rate: float = 0.0  # fraction of blocks stamped with a
                                    # pipeline trace id in the ADD_BLOCK
                                    # header (repro.obs); spans are
                                    # recorded on the gateway host, whose
                                    # sink owns the run's JSONL
    policy: str | None = None     # HOST:PORT of a --serve-policy gateway.
                                  # Set -> thin-client mode: rollouts run
                                  # server-side in the shared slot-scheduled
                                  # engine (this process ships its slice per
                                  # ACT_REQUEST and never holds params);
                                  # unset -> classic local jitted act_phase


class _Stop(Exception):
    """Gateway said STOP (or went away): drain and exit cleanly."""


# The exact slice ``runner.run_async`` builds for actor ``actor_id`` — one
# shared derivation, so thread and process actors are interchangeable
# points on one ladder.
initial_slice = phases.initial_actor_slice


class RemoteActorLoop:
    """One remote actor: transport client + jitted rollout loop."""

    def __init__(self, spec: RemoteActorSpec):
        self.spec = spec
        cfg, env, agent = spec.cfg, spec.env, spec.agent
        self._act = jax.jit(lambda p, sl, sid: phases.act_phase(
            cfg, env, agent, p, sl, sid))
        self._sync_period = (spec.param_sync_period
                             if spec.param_sync_period is not None
                             else cfg.param_sync_period)
        self._params: Any = None
        self._param_version = -1
        self._pull_replies = 0    # PARAM + PARAM_UNCHANGED frames seen
        self._in_flight = 0
        # Deterministic block sampling for pipeline tracing: a sampled
        # block carries its id in the ADD_BLOCK header, and the gateway
        # host's tracer records the downstream spans (this process has no
        # sink — it only originates ids).
        self._tracer = Tracer(spec.trace_sample_rate)
        self._conn: transport_lib.Transport | None = None
        self._policy = None  # PolicyClient in thin-client mode
        self.stats = {"rollouts": 0, "pushed": 0, "blocked": 0,
                      "transitions": 0, "param_pulls": 0, "bytes_out": 0,
                      "reconnects": 0, "inflight_dropped": 0,
                      "param_version": -1, "transport": "",
                      "policy_acts": 0}

    # -- frame plumbing -----------------------------------------------------

    def _handle(self, msg_type: int, payload: memoryview) -> None:
        if msg_type == wire.ADD_ACK:
            self._in_flight -= 1
        elif msg_type == wire.PARAM:
            version, params = wire.decode_params(payload)
            # device_put once per refresh, not once per rollout dispatch
            self._params = jax.device_put(params)
            self._param_version = version
            self.stats["param_version"] = version
            self._pull_replies += 1
        elif msg_type == wire.PARAM_UNCHANGED:
            self._pull_replies += 1
        elif msg_type == wire.STOP:
            raise _Stop
        else:
            raise wire.WireError(f"unexpected message {msg_type} from gateway")

    def _pump(self, conn: transport_lib.Transport, timeout: float) -> bool:
        """Process at most one pending frame; False on timeout."""
        got = conn.recv(timeout=timeout)
        if got is None:
            return False
        self._handle(*got)
        return True

    def _pull_params(self, conn: transport_lib.Transport) -> None:
        """Request a snapshot newer than ours and wait for the reply
        (ACKs interleaved on the stream are processed while waiting)."""
        replies_before = self._pull_replies
        conn.send(wire.PARAM_PULL,
                  wire.encode_json({"have": self._param_version}))
        self.stats["param_pulls"] += 1
        deadline = time.monotonic() + self.spec.param_timeout_s
        while self._pull_replies == replies_before:
            if time.monotonic() > deadline:
                raise TimeoutError("gateway never answered PARAM_PULL")
            self._pump(conn, timeout=self.spec.poll_s)

    # -- connection lifecycle -----------------------------------------------

    def _handshake(self) -> None:
        """HELLO + initial parameter pull on the current connection. The
        reconnect count rides the HELLO so the gateway can account client
        comebacks (priorities are idempotent LWW updates — re-sending after
        a reconnect is safe by construction)."""
        self._conn.send(wire.HELLO, wire.encode_json(
            {"actor_id": self.spec.actor_id,
             "protocol": wire.PROTOCOL_VERSION,
             "reconnects": self.stats["reconnects"]}))
        if self.spec.policy is None:
            self._pull_params(self._conn)
        # thin-client mode never pulls: the policy gateway's engine holds
        # (and hot-swaps) the parameters

    def _retire_conn(self) -> None:
        if self._conn is None:
            return
        self.stats["bytes_out"] += self._conn.bytes_out
        try:
            self._conn.close()
        except OSError:
            pass
        self._conn = None

    def _reconnect(self, cause: BaseException) -> None:
        """A severed transport (anything but an explicit STOP): dial the
        gateway again with capped backoff until ``reconnect_timeout_s``,
        re-handshake, and resume acting. Un-acked blocks on the dead
        connection are dropped (counted ``inflight_dropped``) — a temporary
        ingest dip, the loss mode the paper's replay tolerates. Re-raises
        ``cause`` on give-up so the caller's normal exit paths apply."""
        spec = self.spec
        if not spec.reconnect:
            raise cause
        self._retire_conn()
        self.stats["inflight_dropped"] += self._in_flight
        self._in_flight = 0
        deadline = time.monotonic() + spec.reconnect_timeout_s
        backoff = 0.05
        while True:
            if time.monotonic() >= deadline:
                raise cause
            time.sleep(min(backoff, max(0.0, deadline - time.monotonic())))
            backoff = min(backoff * 2, 1.0)
            try:
                self._conn = transport_lib.connect(
                    spec.host, spec.port, spec.transport,
                    timeout=spec.connect_timeout_s,
                    ring_bytes=spec.ring_bytes)
            except (OSError, transport_lib.ShmUnavailable):
                continue
            self.stats["transport"] = self._conn.kind
            self.stats["reconnects"] += 1
            try:
                self._handshake()
            except (EOFError, transport_lib.TransportClosed, OSError,
                    TimeoutError):
                # Gateway flapped again mid-handshake: keep trying until
                # the deadline (a STOP here propagates — clean exit).
                self._retire_conn()
                continue
            return

    # -- rollout dispatch ----------------------------------------------------

    def _rollout(self, sl, sid):
        """One rollout: local jitted act_phase, or — thin-client mode — an
        ACT_REQUEST round trip into the policy gateway's shared engine. The
        two are bit-identical per actor (the wire codec round-trips every
        leaf exactly), so moving an actor behind the policy plane does not
        change its stream."""
        if self._policy is None:
            return self._act(self._params, sl, sid)
        try:
            res = self._policy.act(sl, int(sid))
        except (EOFError, transport_lib.TransportClosed, OSError) as e:
            # The policy plane lives with the runner; it going away IS the
            # end of the run for a thin client (no params to act with).
            raise _Stop from e
        if res is None:
            raise _Stop  # STOP reply: runtime shutting down
        self.stats["policy_acts"] += 1
        return res

    # -- main loop ----------------------------------------------------------

    def run(self) -> dict:
        """Act until the gateway stops us; returns client-side counters."""
        spec = self.spec
        self._conn = transport_lib.connect(
            spec.host, spec.port, spec.transport,
            timeout=spec.connect_timeout_s, ring_bytes=spec.ring_bytes)
        self.stats["transport"] = self._conn.kind
        try:
            self._handshake()
            if spec.policy is not None:
                from repro.net.learner_client import parse_hostport
                from repro.net.policy_client import PolicyClient
                ph, pp = parse_hostport(spec.policy)
                self._policy = PolicyClient(
                    ph, pp,
                    example=initial_slice(spec.cfg, spec.env, spec.seed,
                                          spec.actor_id),
                    transport=spec.transport,
                    connect_timeout_s=spec.connect_timeout_s,
                    act_timeout_s=spec.param_timeout_s)

            sl = initial_slice(spec.cfg, spec.env, spec.seed, spec.actor_id)
            sid = jnp.int32(spec.actor_id)
            next_send = None  # offered-rate pacing schedule
            while (spec.max_rollouts is None
                   or self.stats["rollouts"] < spec.max_rollouts):
                try:
                    if (self._policy is None
                            and self.stats["rollouts"] > 0
                            and self.stats["rollouts"]
                            % self._sync_period == 0):
                        self._pull_params(self._conn)
                    sl, block, _metrics = self._rollout(sl, sid)
                    payload = wire.encode_block_iov(
                        block, quantize_obs=spec.quantize_obs)
                    if spec.target_blocks_per_s:
                        # Pace to the offered rate (no catch-up bursts: the
                        # target is a strict upper bound), draining ACKs
                        # while waiting out the slot. An overrun slot sends
                        # at once.
                        period = 1.0 / spec.target_blocks_per_s
                        now = time.monotonic()
                        next_send = now if next_send is None else max(
                            next_send + period, now)
                        while True:
                            remaining = next_send - time.monotonic()
                            if remaining <= 0:
                                break
                            self._pump(self._conn, timeout=remaining)
                    # Bounded in-flight window: wait for ACKs when full —
                    # this is where gateway/fabric backpressure reaches the
                    # actor.
                    while self._in_flight >= spec.max_inflight:
                        if not self._pump(self._conn, timeout=spec.poll_s):
                            self.stats["blocked"] += 1
                    self._conn.send(wire.ADD_BLOCK, payload,
                                    trace_id=self._tracer.sample())
                    self._in_flight += 1
                    self.stats["rollouts"] += 1
                    self.stats["pushed"] += 1
                    self.stats["transitions"] += int(
                        block.priorities.shape[0])
                    # opportunistically drain ACKs already on the stream
                    while self._pump(self._conn, timeout=0.001):
                        pass
                except (EOFError, transport_lib.TransportClosed,
                        OSError) as e:
                    # Severed transport mid-rollout (TimeoutError is an
                    # OSError: a wedged gateway counts). STOP is _Stop and
                    # never lands here.
                    self._reconnect(e)
        except (_Stop, EOFError, transport_lib.TransportClosed):
            pass
        finally:
            if self._policy is not None:
                self._policy.close()
            if self._conn is not None:
                try:
                    self._conn.send(wire.BYE, wire.encode_json(
                        {"rollouts": self.stats["rollouts"],
                         "blocked": self.stats["blocked"]}))
                except (OSError, wire.WireError):
                    pass
                self._retire_conn()
        return self.stats


def run_remote_actor(spec: RemoteActorSpec) -> dict:
    """Process entry point (importable, so ``multiprocessing`` spawn and
    ``launch/train.py --actor-procs`` can target it). A gateway that is
    already gone — e.g. the learner finished while this process was still
    compiling — is a clean exit, not a crash."""
    if spec.pin_cpu is not None and hasattr(os, "sched_setaffinity"):
        # Before the first jax op: XLA's intra-op threads spawn lazily and
        # inherit this affinity, so the whole process stays on one core.
        os.sched_setaffinity(0, {spec.pin_cpu % os.cpu_count()})
    try:
        return RemoteActorLoop(spec).run()
    except (ConnectionError, TimeoutError, OSError,
            transport_lib.ShmUnavailable) as e:
        # Observable but non-fatal: the runtime tolerates individual actor
        # losses (paper §3 — actors are expendable) and its gateway
        # monitor stops the run only when no experience source remains.
        print(f"actor {spec.actor_id} aborted: {e!r}", file=sys.stderr)
        return {"aborted": str(e)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--preset", choices=("apex-dqn", "apex-dpg"),
                    default="apex-dqn")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale preset geometry")
    ap.add_argument("--actor-id", type=int, default=0,
                    help="this actor's position on the global eps ladder")
    ap.add_argument("--num-actors", type=int, default=1,
                    help="total actors across all hosts (ladder width)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-inflight", type=int, default=4)
    ap.add_argument("--quantize-obs", action="store_true",
                    help="wire-quantize float observations (replay codec)")
    ap.add_argument("--transport", choices=("tcp", "shm", "auto"),
                    default="auto",
                    help="byte path to the gateway: shm = same-host ring "
                         "(requires a local gateway), auto = shm when the "
                         "host is loopback-local, else tcp")
    ap.add_argument("--max-rollouts", type=int, default=None)
    ap.add_argument("--pin-cpu", type=int, default=None,
                    help="pin this actor process to one CPU core "
                         "(one-actor-per-core, paper §3)")
    args = ap.parse_args()

    if args.preset == "apex-dqn":
        from repro.configs import apex_dqn as preset_mod
    else:
        from repro.configs import apex_dpg as preset_mod
    preset = preset_mod.full() if args.full else preset_mod.reduced()
    cfg = dataclasses.replace(preset.apex, num_shards=args.num_actors)
    spec = RemoteActorSpec(
        cfg=cfg, env=preset.env, agent=preset.agent, host=args.host,
        port=args.port, actor_id=args.actor_id, seed=args.seed,
        max_inflight=args.max_inflight, quantize_obs=args.quantize_obs,
        transport=args.transport, max_rollouts=args.max_rollouts,
        pin_cpu=args.pin_cpu)
    stats = run_remote_actor(spec)
    print(f"actor {args.actor_id} done: {stats}")


if __name__ == "__main__":
    main()
