"""Async actor/learner runtime (paper §3: decoupled acting and learning).

Layering:

* ``phases``  — pure, jittable per-phase functions shared with the
  synchronous ``repro.core.apex`` driver.
* ``params``  — versioned lock-free parameter snapshot store (learner
  publishes, actors pull every ``param_sync_period`` rollouts).
* ``service`` — host-side replay service: a single owner thread applying
  adds / priority write-backs to the sharded ``ReplayState`` behind
  double-buffered bounded queues.
* ``runner``  — thread wiring + throughput accounting (``run_async``).
"""

from repro.runtime.params import ParamSnapshot, ParamStore
from repro.runtime.phases import (ActorSlice, LearnerSlice, TransitionBlock,
                                  act_phase, lane_epsilons, learn_phase,
                                  priority_writeback, replay_add)
from repro.runtime.runner import AsyncConfig, RuntimeResult, run_async
from repro.runtime.service import ReplayService, ServiceStats

__all__ = [
    "ActorSlice", "AsyncConfig", "LearnerSlice", "ParamSnapshot", "ParamStore",
    "ReplayService", "RuntimeResult", "ServiceStats", "TransitionBlock",
    "act_phase", "lane_epsilons", "learn_phase", "priority_writeback",
    "replay_add", "run_async",
]
