"""Async actor/learner runtime (paper §3: decoupled acting and learning).

Layering:

* ``phases``    — pure, jittable per-phase functions shared with the
  synchronous ``repro.core.apex`` driver.
* ``params``    — versioned lock-free parameter snapshot store (learner
  publishes, actors pull every ``param_sync_period`` rollouts).
* ``service``   — ``ReplayShard``: a single owner thread applying adds /
  priority write-backs to one device-resident ``ReplayState`` behind
  double-buffered bounded queues (``ReplayService`` is the PR 1 alias).
* ``fabric``    — ``ReplayFabric``: N shards composed into one replay
  memory (topology below).
* ``inference`` — ``InferenceServer``: coalesces actor act-requests into
  one jitted ``vmap(act_phase)`` device dispatch shared by all actor
  threads (the paper's 1/139 FPS-per-actor economics).
* ``sources``   — the *sample plane*: the learner consumes a
  ``SampleSource`` (sample → consume → priority write-back + stats) and
  never touches fabric internals. ``LocalFabricSource`` wraps the
  in-process fabric, ``repro.net.learner_client.RemoteFabricSource``
  speaks the wire format to a fabric on another host, and
  ``StagedSource`` decorates either with device-staged double buffering
  (async ``device_put`` of batch k+1 overlapping the learn step on k).
* ``runner``    — thread wiring + throughput accounting (``run_async``).

Fabric topology and the (shard, slot) key scheme
------------------------------------------------

::

    actor 0 ─┐                       ┌─ ReplayShard 0 (owner thread) ─┐
    actor 1 ─┼── add: round-robin ───┼─ ReplayShard 1                 ├─ merge ── learner
      ...    │   (ticket counter)    │    ...                         │  (concat sub-samples,
    actor K ─┘                       └─ ReplayShard N-1 ──────────────┘   merged IS weights)

Each shard owns exactly ``capacity / N`` slots (so N must split the
power-of-two capacity into power-of-two slices) and prefetches
``batch_size / N``-item sub-batches on its own clock. A sampled transition's
global key is ``global_index = shard_id * shard_capacity + slot`` — the
paper's "keys" for the distributed replay — so learner priority write-backs
are scattered back to the owning shard by decoding ``shard_id = key //
shard_capacity``, ``slot = key % shard_capacity``. Importance weights for
the merged batch are computed against the *global* sampling distribution
``P(i) = leaf_i / (shard_total(i) * N)`` by ``repro.core.sampling`` — the
exact formula the synchronous ``shard_map`` driver evaluates with
``psum``/``pmax`` collectives, evaluated here with host-side reductions.
"""

from repro.runtime.fabric import (FabricBatch, ReplayFabric,
                                  shard_replay_config)
from repro.runtime.inference import InferenceServer, InferenceStats
from repro.runtime.params import ParamSnapshot, ParamStore
from repro.runtime.phases import (ActorSlice, LearnerSlice, TransitionBlock,
                                  act_phase, lane_epsilons, learn_phase,
                                  priority_writeback, replay_add)
from repro.runtime.runner import (AsyncConfig, RuntimeHandles, RuntimeResult,
                                  run_async)
from repro.runtime.service import (ReplayService, ReplayShard, ServiceStats,
                                   ShardFns, make_shard_fns)
from repro.runtime.snapshot import SnapshotService, restore_run
from repro.runtime.sources import (LocalFabricSource, SampleSource,
                                   SourceClosed, SourceStats, StagedSource)

__all__ = [
    "ActorSlice", "AsyncConfig", "FabricBatch", "InferenceServer",
    "InferenceStats", "LearnerSlice", "LocalFabricSource", "ParamSnapshot",
    "ParamStore", "ReplayFabric", "ReplayService", "ReplayShard",
    "RuntimeHandles", "RuntimeResult", "SampleSource", "ServiceStats",
    "ShardFns", "SnapshotService", "SourceClosed", "SourceStats",
    "StagedSource", "TransitionBlock", "act_phase", "lane_epsilons",
    "learn_phase", "make_shard_fns", "priority_writeback", "replay_add",
    "restore_run", "run_async", "shard_replay_config",
]
