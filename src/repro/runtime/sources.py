"""Transport-agnostic learner sampling: the sample plane behind one protocol.

The paper's architecture cuts acting from learning at the replay memory; the
Gorila lineage cuts the learner↔replay link too (learners on different hosts
than the replay shards). This module is that cut expressed as one interface:
the learner loop consumes a :class:`SampleSource` — sample, consume, write
priorities back, snapshot stats — and never touches fabric internals, so the
same loop runs against

* :class:`LocalFabricSource` — the in-process ``ReplayFabric`` (PR 1-4's
  learner path, extracted from ``runtime/runner.py``);
* ``repro.net.learner_client.RemoteFabricSource`` — a fabric on another
  host, over the ``repro.net`` wire format (lives in ``repro.net`` because
  the socket client sits above this layer);
* :class:`StagedSource` — a decorator adding device-staged double
  buffering to *any* of the above: a stager thread prefetches batch k+1
  and starts its async host→device put while the learner computes on
  batch k, so transport latency (socket round trip, frame decode, H2D
  copy) is hidden behind learner compute. This is the replay
  double-buffering item done once at the interface instead of per
  call-site: on TPU ``jax.device_put`` of host (numpy) leaves stages
  through pinned host memory with an async DMA; on CPU it degrades to a
  (possibly zero-copy) alias, keeping numerics bit-identical everywhere.

:class:`BlockStager` is the same double-buffering idea pointed at the
*ingest* plane: replay shard owners use it to overlap block k+1's H2D
transfer with block k's in-place add (``ReplayShard(ingest_staging=True)``).
It lives here because it is ``StagedSource._stage`` as a standalone object.

All sources yield ``repro.core.sampling.LearnerBatch`` — global
``(shard, slot)`` keys, items, globally-corrected IS weights — and accept
write-backs of any subset/order of those keys, which is what makes the
implementations interchangeable (and property-testable against each other
bit for bit).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any

import time

import jax

from repro.core.sampling import LearnerBatch
from repro.obs import Telemetry
from repro.runtime.fabric import ReplayFabric
from repro.runtime.service import ServiceStats


class BlockStager:
    """Ingest-side twin of :class:`StagedSource`'s device staging.

    The sample plane double-buffers D2H-ward transfers; this is the same
    machinery pointed the other way: the shard owner calls :meth:`stage` on
    an incoming ``TransitionBlock`` *before* dispatching the previous
    block's in-place add, so the async ``jax.device_put`` (pinned-host
    staging + DMA on TPU) of block k+1 overlaps the update kernel running
    on block k. ``device_put`` is value-preserving, so a staged pipeline is
    bit-identical to an unstaged one.

    On a CPU "device" host and device memory are one address space and PJRT
    runs transfers on the compute stream — a put would serialize a redundant
    copy — so staging degrades to pass-through there, exactly like
    ``StagedSource`` (``passthrough`` can be forced off in tests to exercise
    the put path anywhere). Leaves already resident on the target device
    (thread-actor blocks) pass through untouched; the put only pays off for
    host-resident blocks, i.e. gateway-decoded numpy arrays.
    """

    def __init__(self, device: Any = None, passthrough: bool | None = None):
        self._device = device if device is not None else jax.devices()[0]
        self.passthrough = (getattr(self._device, "platform", None) == "cpu"
                            if passthrough is None else passthrough)
        self.blocks_staged = 0  # blocks that actually issued a device put

    def stage(self, block: Any) -> Any:
        """Issue the async H2D put for every host-resident leaf of a block."""
        if self.passthrough:
            return block

        def put(x: Any) -> Any:
            if isinstance(x, jax.Array) and x.devices() == {self._device}:
                return x
            return jax.device_put(x, self._device)

        staged = jax.tree.map(put, block)
        self.blocks_staged += 1
        return staged


class SourceClosed(RuntimeError):
    """The upstream end of a sample source went away (e.g. the serving
    gateway sent STOP or closed the socket). Raised from ``get_batch`` so a
    learner that still *needs* batches fails fast; a learner that already
    finished simply never observes it — which is what makes the orderly
    two-host shutdown race-free (either side may win the teardown race)."""


@dataclasses.dataclass
class SourceStats:
    """Client-side (learner-plane) counters, one instance per source."""

    batches: int = 0          # batches handed to the learner
    writebacks: int = 0       # priority write-back rounds accepted
    writeback_frames: int = 0  # coalesced PRIORITY_UPDATE frames actually
                               # sent (remote transports; <= writebacks)
    starved_polls: int = 0    # get_batch calls that returned None
    param_pushes: int = 0     # params shipped upstream (remote transports)
    staged: int = 0           # batches staged ahead (StagedSource)
    stage_idle: int = 0       # stager polls that found the inner source dry
    reconnects: int = 0       # transport reconnects survived (remote
                              # transports; each one is a severed socket the
                              # source recovered from instead of dying)


class SampleSource:
    """Where learner batches come from and where priorities go back.

    The contract mirrors the fabric's learner side:

    * ``get_batch(timeout)`` — next :class:`LearnerBatch`, or None while the
      source is starved (replay below min-fill, prefetch lagging, transport
      idle). Single-consumer: one learner thread. After a batch is
      returned, ``last_trace_id`` holds its pipeline trace id (0 =
      untraced) — the learner passes it back via ``write_back`` so the
      sample → learn → writeback chain stays linked (``repro.obs``).
    * ``write_back(indices, priorities, trace_id=0)`` — asynchronous
      priority write-back for previously sampled keys; any
      subset/ordering is valid.
    * ``publish_params(version, params)`` — hook for transports that must
      ship fresh learner params upstream (a remote fabric's actors pull from
      *its* param store); in-process sources no-op.
    * ``snapshot()`` — ``ServiceStats`` view of the replay behind the
      source; ``stats`` — this source's own ``SourceStats``.
    * ``error`` — a worker/transport failure the consumer must surface.
    """

    stats: SourceStats
    last_trace_id: int = 0

    def start(self) -> "SampleSource":
        return self

    def stop(self) -> None:
        pass

    def get_batch(self, timeout: float | None = None) -> LearnerBatch | None:
        raise NotImplementedError

    def write_back(self, indices: Any, priorities: Any,
                   trace_id: int = 0) -> None:
        raise NotImplementedError

    def publish_params(self, version: int, params: Any) -> None:
        pass

    def snapshot(self) -> ServiceStats:
        raise NotImplementedError

    @property
    def error(self) -> BaseException | None:
        return None

    @property
    def reconnect_count(self) -> int:
        """Transport reconnects survived (0 for in-process sources);
        decorators forward to the transport-owning inner source."""
        return self.stats.reconnects


class LocalFabricSource(SampleSource):
    """The in-process fabric as a sample source.

    This is the learner-thread code that used to live inline in
    ``runtime/runner.py``, inverted: the runner no longer reaches into the
    fabric; it holds a source, and the fabric is one implementation detail
    behind it. Normalizes the single-shard fast path (a raw ``SampleBatch``
    with shard-internal fields) to the ``LearnerBatch`` contract.

    ``own=True`` makes ``start``/``stop`` manage the fabric lifecycle too —
    for callers (tests, benches) where nothing else feeds the fabric; the
    runner keeps ownership because its actors share the same fabric.
    """

    def __init__(self, fabric: ReplayFabric, *, own: bool = False,
                 telemetry: Telemetry | None = None):
        self._fabric = fabric
        self._own = own
        self.stats = SourceStats()
        self._tel = telemetry if telemetry is not None else Telemetry.local()
        self._h_get = self._tel.histogram("source/get_batch_us")
        self._c_starved = self._tel.counter("source/starved_polls")
        self.last_trace_id = 0

    def start(self) -> "LocalFabricSource":
        if self._own:
            self._fabric.start()
        return self

    def stop(self) -> None:
        if self._own:
            self._fabric.stop()

    def get_batch(self, timeout: float | None = None) -> LearnerBatch | None:
        t0 = time.perf_counter()
        b = self._fabric.get_batch(timeout=timeout)
        if b is None:
            self.stats.starved_polls += 1
            self._c_starved.inc()
            return None
        us = 1e6 * (time.perf_counter() - t0)
        self._h_get.record(us)
        # A sampled batch starts a fresh trace here: the consume plane
        # traces *batches* (sample → learn → writeback), independent of
        # the ingest plane's per-block traces.
        tid = self._tel.tracer.sample()
        if tid:
            self._tel.tracer.record("sample", tid, us)
        self.last_trace_id = tid
        self.stats.batches += 1
        return LearnerBatch(b.indices, b.items, b.is_weights)

    def write_back(self, indices: Any, priorities: Any,
                   trace_id: int = 0) -> None:
        self._fabric.write_back(indices, priorities, trace_id=trace_id)
        self.stats.writebacks += 1

    def snapshot(self) -> ServiceStats:
        return self._fabric.snapshot()

    @property
    def error(self) -> BaseException | None:
        return self._fabric.error


class StagedSource(SampleSource):
    """Device-staged double buffering for any inner :class:`SampleSource`.

    A stager thread pulls batches from the inner source and immediately
    issues an asynchronous ``jax.device_put`` toward the learner's device,
    parking the in-flight batch in a bounded queue (depth 1 = classic double
    buffering: one batch being consumed, one being staged). By the time the
    learner pops batch k+1, its host→device copy has been overlapping the
    learn step on batch k — and for remote sources the socket wait and frame
    decode of k+1 overlapped too, since they happen on the stager thread.

    ``device_put`` is value-preserving, so a staged source is bit-identical
    to its inner source; ordering is preserved (single stager thread, FIFO
    queue). Write-backs and param pushes pass straight through.
    """

    def __init__(self, inner: SampleSource, *, device: Any = None,
                 depth: int = 1, poll_s: float = 0.02,
                 telemetry: Telemetry | None = None):
        self._inner = inner
        self._device = device if device is not None else jax.devices()[0]
        # On a CPU "device" host and device memory are one address space and
        # PJRT runs transfers on the same stream as compute — a device_put
        # would not overlap anything, it would serialize a redundant copy
        # behind the in-flight learn step (measured: milliseconds per batch
        # of pure queueing). Staging then degrades to what it can genuinely
        # overlap there: the inner source's fetch/decode. Real accelerators
        # have a separate DMA stream, so the put is asynchronous and the
        # H2D copy of batch k+1 truly overlaps the learn step on batch k.
        self._passthrough = getattr(self._device, "platform", None) == "cpu"
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._poll_s = poll_s
        self._stop_evt = threading.Event()
        self._thread = threading.Thread(target=self._run_guarded, daemon=True,
                                        name="sample-stager")
        self._error: BaseException | None = None
        self._peer_closed = False
        self.stats = SourceStats()
        self._tel = telemetry if telemetry is not None else Telemetry.local()
        self._h_stage = self._tel.histogram("source/stage_us")
        self._c_starved = self._tel.counter("source/staged_starved_polls")
        self.last_trace_id = 0

    def start(self) -> "StagedSource":
        self._inner.start()
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread.is_alive():
            self._thread.join()
        self._inner.stop()

    def _run_guarded(self) -> None:
        try:
            self._run()
        except SourceClosed:
            # Upstream hung up: stop staging quietly. If the consumer still
            # wants batches it hits the re-raise in get_batch once the
            # queue drains; a consumer that already finished never notices
            # — so the serving host may win the teardown race harmlessly.
            self._peer_closed = True
        except BaseException as e:  # noqa: BLE001
            # A transport torn down *after* stop was requested is a normal
            # part of shutdown, not a worker death — only failures during
            # live operation surface.
            if not self._stop_evt.is_set():
                self._error = e

    def _run(self) -> None:
        while not self._stop_evt.is_set():
            b = self._inner.get_batch(timeout=self._poll_s)
            if b is None:
                self.stats.stage_idle += 1
                continue
            # The batch's trace id rides the staging queue with it, so the
            # consumer-side last_trace_id is the staged batch's, not the
            # most recently *fetched* one.
            tid = getattr(self._inner, "last_trace_id", 0)
            t0 = time.perf_counter()
            staged = self._stage(b)
            self._h_stage.record(1e6 * (time.perf_counter() - t0))
            self.stats.staged += 1
            while not self._stop_evt.is_set():
                try:
                    self._q.put((staged, tid), timeout=self._poll_s)
                    break
                except queue.Full:
                    continue

    def _stage(self, b: LearnerBatch) -> LearnerBatch:
        """Start the async device transfer for every host-resident leaf.

        On TPU ``device_put`` of a numpy leaf stages through pinned host
        memory with an async DMA — the H2D copy of batch k+1 then overlaps
        the learn step on batch k; the learner's jit call joins the
        transfer. Leaves already living on the target device (e.g. a local
        fabric's prefetched batches) pass through untouched: re-putting
        them is a redundant copy *and* a redundant dispatch thread touching
        the device queue, which costs real throughput on small hosts.
        """
        if self._passthrough:
            return b
        def put(x: Any) -> Any:
            if isinstance(x, jax.Array) and x.devices() == {self._device}:
                return x
            return jax.device_put(x, self._device)
        return jax.tree.map(put, b)

    def _check_alive(self) -> None:
        if self.error is not None:
            raise RuntimeError("sample stager died") from self.error

    def get_batch(self, timeout: float | None = None) -> LearnerBatch | None:
        self._check_alive()
        try:
            b, tid = self._q.get(timeout=self._poll_s if timeout is None
                                 else timeout)
        except queue.Empty:
            if self._peer_closed:
                raise SourceClosed(
                    "upstream sample source closed and the staging queue "
                    "is drained") from None
            self.stats.starved_polls += 1
            self._c_starved.inc()
            return None
        self.last_trace_id = tid
        self.stats.batches += 1
        return b

    def write_back(self, indices: Any, priorities: Any,
                   trace_id: int = 0) -> None:
        self._inner.write_back(indices, priorities, trace_id=trace_id)
        self.stats.writebacks += 1

    def publish_params(self, version: int, params: Any) -> None:
        self._inner.publish_params(version, params)

    def snapshot(self) -> ServiceStats:
        return self._inner.snapshot()

    @property
    def error(self) -> BaseException | None:
        return self._error if self._error is not None else self._inner.error

    @property
    def reconnect_count(self) -> int:
        return self._inner.reconnect_count
