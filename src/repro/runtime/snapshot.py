"""Snapshot service: periodic atomic run-state checkpoints (Appendix F).

"All stateful parts of the system must periodically save their work and be
able to resume": for this runtime the stateful parts are the replay fabric
(per-shard storage pytree + sum tree + write/eviction clocks + rng streams)
and the learner (params, target params, optimizer state, step counter,
ParamStore version). Actors are deliberately *not* saved — they are pure
functions of ``(seed, actor_id)`` and the latest params, rebuilt on restart
with only a temporary dip in ingest rate.

:class:`SnapshotService` is a thread that every ``every_s`` seconds captures

* every shard, via ``ReplayShard.checkpoint_state`` (the owner thread
  answers between ops, so the capture is consistent even while hot);
* the learner's live slice — the learner loop publishes ``(steps, lslice)``
  into a shared box as one atomic rebind each step, so the pair is never
  torn;
* the ``ParamStore`` version (a resumed learner must keep version numbers
  monotone for the actors comparing them),

and writes them as one ``ckpt_<learner_steps>.npz`` through
``repro.checkpoint.save`` (tmp + rename: the file is atomic; ``latest()``
never sees a half-written checkpoint). ``restore_run`` is the inverse used
by ``run_async(resume=True)``.

Recovery telemetry lands in the shared bundle: ``snapshot/saves`` counter,
``snapshot/last_step`` gauge, ``snapshot/save_us`` latency histogram.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Any

import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.obs import Telemetry
from repro.obs import log as obslog

CKPT_PREFIX = "ckpt_"


def _run_tree(shards: list, steps: int, lslice: Any, version: int) -> dict:
    return {
        "shards": shards,
        "learner": {"params": lslice.params,
                    "target_params": lslice.target_params,
                    "opt_state": lslice.opt_state,
                    "learner_step": lslice.learner_step},
        "steps": np.int64(steps),
        "param_version": np.int64(version),
    }


class SnapshotService:
    """Periodic checkpoints of fabric + learner into one directory."""

    def __init__(self, directory: str, fabric: Any, learner_box: dict,
                 store: Any, *, every_s: float = 30.0,
                 telemetry: Telemetry | None = None):
        if every_s <= 0:
            raise ValueError(f"checkpoint interval must be > 0s, got "
                             f"{every_s}")
        self._dir = directory
        self._fabric = fabric
        self._box = learner_box
        self._store = store
        self._every_s = every_s
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="snapshot-service")
        tel = telemetry if telemetry is not None else Telemetry.local()
        self._c_saves = tel.counter("snapshot/saves")
        self._g_last = tel.gauge("snapshot/last_step")
        self._h_save = tel.histogram("snapshot/save_us")
        self.saves = 0
        self.last_step = -1
        self.error: BaseException | None = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "SnapshotService":
        self._thread.start()
        return self

    def stop(self, final_save: bool = True) -> None:
        """Stop the periodic thread; by default take one last snapshot so a
        clean shutdown resumes from its very end (a crash resumes from the
        last periodic one). Never raises — a failed final save records the
        error for the runner to surface."""
        self._stop.set()
        if self._thread.is_alive():
            self._thread.join()
        if final_save:
            try:
                self.save_now()
            except BaseException as e:  # noqa: BLE001
                if self.error is None:
                    self.error = e

    # -- capture ------------------------------------------------------------

    def save_now(self) -> str:
        """One atomic full-run checkpoint; returns its path. Same-step saves
        overwrite (the rename is atomic, so readers see old or new)."""
        t0 = time.perf_counter()
        steps, lslice = self._box["live"]
        tree = _run_tree(self._fabric.checkpoint_shards(), steps, lslice,
                         self._store.version)
        path = os.path.join(self._dir, f"{CKPT_PREFIX}{steps}.npz")
        ckpt_lib.save(path, tree, step=steps)
        us = 1e6 * (time.perf_counter() - t0)
        self._h_save.record(us)
        self._c_saves.inc()
        self._g_last.set(steps)
        self.saves += 1
        self.last_step = steps
        obslog.emit("snapshot", step=steps, path=path, us=round(us))
        return path

    def _run(self) -> None:
        while not self._stop.wait(timeout=self._every_s):
            try:
                self.save_now()
            except BaseException as e:  # noqa: BLE001
                # A failing snapshot must not kill the run it is meant to
                # protect; record and keep trying (disk may free up).
                self.error = e


def restore_run(directory: str, fabric: Any, lslice: Any) -> dict | None:
    """Load the newest run checkpoint in ``directory`` into the structure of
    a freshly built (same-geometry) fabric + learner slice. Returns the
    restored tree (``shards`` / ``learner`` / ``steps`` / ``param_version``)
    or None when the directory holds no checkpoint yet — a resume against an
    empty directory is a cold start, not an error (first launch of a
    supervised job)."""
    path = ckpt_lib.latest(directory, prefix=CKPT_PREFIX)
    if path is None:
        return None
    example = _run_tree(fabric.checkpoint_shards(), 0, lslice, 0)
    tree = ckpt_lib.restore(path, example)
    tree["path"] = path
    return tree
