"""Host-side replay shard: one slice of the paper's central replay memory.

One owner thread holds a device-resident ``ReplayState`` and is the only
code that ever touches it, so replay mutation needs no locks. Traffic flows
through three queues, mirroring Fig. 1's arrows:

* ``add``       (actors → replay, bounded) — blocks of n-step transitions
  with actor-side initial priorities. A bounded depth gives *backpressure*:
  when the learner + service fall behind, actors block on ``add`` instead of
  overrunning memory.
* ``samples``   (replay → learner, bounded) — prefetched prioritized
  batches. Depth 2 double-buffers the learner: batch k+1 is sampled while
  the learner consumes batch k. Empty queue = *starved learner*.
* ``updates``   (learner → replay) — priority write-backs; applying one
  counts as a learner step for the periodic eviction clock (paper: evict
  every 100 learning steps).

A single ``ReplayShard`` *is* PR 1's ``ReplayService`` (the name is kept as
an alias); ``repro.runtime.fabric.ReplayFabric`` composes N of them into the
sharded replay fabric, routing actor blocks round-robin and merging per-shard
sub-samples on the learner side. When a fabric owns several shards it builds
one set of jitted phase functions (``make_shard_fns``) and passes it to every
shard, so N shards share one compilation cache entry per op.

Known (and intended) relaxation vs the lockstep driver: a prefetched batch
may reference slots that a concurrent add overwrites before the learner's
priorities come back. The paper's distributed system has the same window —
replay content is allowed to be slightly stale relative to the learner.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replay as replay_lib
from repro.obs import Telemetry
from repro.runtime import phases

# Owner-loop ops between refreshes of the host-visible ``replay_size`` (each
# refresh is a device sync; counters stay exact, size is near-real-time).
_SIZE_REFRESH_OPS = 32

# Per-op latency is *sampled*: every Nth op of each kind is synced
# (block_until_ready) and timed, and the measurement recorded into the
# shard's latency histogram (``repro.obs``). Sampling keeps the owner
# loop's async dispatch pipeline intact between measurements; the sync
# makes the sampled number an honest applied latency (it absorbs any
# backlog the op queued behind). Ops carrying a trace id are always
# synced — a traced span must be an honest duration.
_LATENCY_SAMPLE_EVERY = 8


@dataclasses.dataclass
class ServiceStats:
    blocks_added: int = 0          # transition blocks applied to replay
    transitions_added: int = 0     # transitions offered to the replay op (in
                                   # alloc/prioritized mode a full buffer may
                                   # drop overflow lanes device-side; compare
                                   # ReplayState.total_added for stored count)
    batches_sampled: int = 0       # prioritized batches prefetched
    updates_applied: int = 0       # priority write-backs applied by this
                                   # shard (aggregated fabric stats sum these:
                                   # one learner step touches every shard)
    replay_size: int = 0           # live items (refreshed periodically while
                                   # running; exact after stop())
    add_us: float = 0.0            # mean applied-latency per op kind, in
    sample_us: float = 0.0         # microseconds — a derived view of the
    writeback_us: float = 0.0      # shard's obs histograms (0.0 until the
                                   # first sampled measurement; fabric
                                   # aggregation op-count-weights, not sums)
    h2d_us: float = 0.0            # mean *issue* latency of the ingest
                                   # stager's async device_put (the DMA
                                   # itself overlaps the previous add; 0.0
                                   # when staging is off or passes through)
    blocks_staged: int = 0         # blocks whose H2D put was issued ahead
                                   # by the ingest stager (0 on CPU, where
                                   # staging passes through)

    # Which counter weights each latency field when shard snapshots are
    # folded: a shard's mean only counts in proportion to the ops behind it.
    _US_WEIGHTS = {"add_us": "blocks_added", "sample_us": "batches_sampled",
                   "writeback_us": "updates_applied",
                   "h2d_us": "blocks_staged"}

    @classmethod
    def aggregate(cls, snaps: "list[ServiceStats]") -> "ServiceStats":
        """Combine per-shard snapshots into one view: counters sum, the
        per-op latency means (``*_us``) average weighted by each shard's
        op count — an unweighted mean would let a nearly idle shard (one
        measurement) drag the fabric view as hard as a hot one. Lives with
        the dataclass so every holder of shard snapshots (the fabric,
        sample sources, benches) folds them the same way."""
        agg = cls()
        for f in dataclasses.fields(cls):
            vals = [getattr(s, f.name) for s in snaps]
            if f.name.endswith("_us"):
                wfield = cls._US_WEIGHTS[f.name]
                pairs = [(v, getattr(s, wfield))
                         for v, s in zip(vals, snaps) if v > 0.0]
                wsum = sum(w for _, w in pairs)
                if wsum > 0:
                    setattr(agg, f.name,
                            sum(v * w for v, w in pairs) / wsum)
                elif pairs:
                    # measurements exist but op counters are still zero
                    # (snapshot raced the first _bump): plain mean.
                    setattr(agg, f.name,
                            sum(v for v, _ in pairs) / len(pairs))
            else:
                setattr(agg, f.name, sum(vals))
        return agg


class ShardFns(NamedTuple):
    """Jitted phase functions for one shard geometry. Built once per fabric
    (or per standalone shard) and shared, so N identical shards trace and
    compile each op exactly once. The mutating ops (``add``/``writeback``)
    donate the incoming ``ReplayState``, so the storage pytree and sum-tree
    update in place instead of being copied every call — each shard's owner
    thread is the state's only holder, so the donated buffers are never
    observed again."""
    add: Any
    sample: Any
    writeback: Any
    can_sample: Any
    split: Any


def make_shard_fns(cfg, batch_size: int) -> ShardFns:
    rcfg = cfg.replay
    return ShardFns(
        add=jax.jit(lambda st, block: phases.replay_add(cfg, st, block),
                    donate_argnums=(0,)),
        sample=jax.jit(
            lambda st, rng: replay_lib.sample(rcfg, st, rng, batch_size)),
        writeback=jax.jit(
            lambda st, idx, prios, step, rng: phases.priority_writeback(
                cfg, st, idx, prios, step, rng),
            donate_argnums=(0,)),
        can_sample=jax.jit(lambda st: replay_lib.can_sample(rcfg, st)),
        split=jax.jit(lambda k: jax.random.split(k)),
    )


class ReplayShard:
    """Single replay shard behind double-buffered host-side queues."""

    def __init__(self, cfg, replay_state: replay_lib.ReplayState, *,
                 batch_size: int | None = None, add_queue_depth: int = 4,
                 sample_queue_depth: int = 2, seed: int = 0,
                 shard_id: int = 0, fns: ShardFns | None = None,
                 poll_s: float = 0.05, ingest_staging: bool = False,
                 stager: "Any | None" = None,
                 telemetry: Telemetry | None = None):
        self._cfg = cfg
        # Private copy: add/writeback *donate* the state into jit, deleting
        # its buffers. Copying here keeps the caller's reference readable
        # (and lets one template state seed several shards) at a one-time
        # pytree-copy cost.
        self._state = jax.tree.map(jnp.array, replay_state)
        self._rng = jax.random.key(seed)
        self._fns = fns or make_shard_fns(cfg, batch_size or cfg.batch_size)
        self._poll_s = poll_s
        self.shard_id = shard_id
        # Ingest staging (mirror of the sample plane's StagedSource): the
        # owner loop issues block k+1's async device_put before dispatching
        # block k's add, hiding H2D behind the update. An explicit ``stager``
        # (tests) wins over the flag; the default BlockStager passes through
        # on CPU hosts where a put would serialize a redundant copy.
        if stager is None and ingest_staging:
            from repro.runtime.sources import BlockStager
            stager = BlockStager()
        self._stager = stager

        self._ready = False  # sticky min-fill latch (see _can_sample)
        self._add_q: queue.Queue = queue.Queue(maxsize=add_queue_depth)
        self._sample_q: queue.Queue = queue.Queue(maxsize=sample_queue_depth)
        self._update_q: queue.Queue = queue.Queue()
        # Checkpoint requests (boxes awaiting a consistent host-side capture)
        # and the chaos harness's freeze hook: a paused owner loop models a
        # stalled shard (GC pause, wedged device) without killing it.
        self._ckpt_q: queue.Queue = queue.Queue()
        self._paused = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run_guarded, daemon=True,
                                        name=f"replay-shard-{shard_id}")
        self._stats_lock = threading.Lock()
        self._ops_since_size = 0
        self._op_seq = {"add": 0, "sample": 0, "writeback": 0}
        self.stats = ServiceStats()
        self.error: BaseException | None = None

        # Telemetry: instruments live in the (possibly run-shared)
        # registry under a per-shard namespace; the *_us histograms are
        # the source of truth the ServiceStats *_us fields derive from.
        self._tel = telemetry if telemetry is not None else Telemetry.local()
        pre = f"shard{shard_id}"
        self._hists = {k: self._tel.histogram(f"{pre}/{k}_us")
                       for k in ("add", "sample", "writeback", "h2d")}
        self._g_add_q = self._tel.gauge(f"{pre}/add_queue_depth")
        self._g_sample_q = self._tel.gauge(f"{pre}/sample_queue_depth")
        self._g_update_q = self._tel.gauge(f"{pre}/update_queue_depth")
        self._g_size = self._tel.gauge(f"{pre}/replay_size")
        self._c_add_blocked = self._tel.counter(f"{pre}/add_backpressure")
        self._c_starved = self._tel.counter(f"{pre}/get_batch_starved")

    @property
    def learner_steps(self) -> int:
        """Eviction-clock position: one applied write-back == one step."""
        return self.stats.updates_applied

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "ReplayShard":
        self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        """Ask the shard to drain pending work and exit."""
        self._stop.set()
        if join and self._thread.is_alive():
            self._thread.join()

    @property
    def replay_state(self) -> replay_lib.ReplayState:
        """Final replay state; only meaningful after ``stop()``."""
        return self._state

    # -- checkpoint / restore ------------------------------------------------

    # Everything the paper's Appendix F asks a stateful part to save:
    # replay contents + sum tree, the shard's rng stream, and the counters
    # that drive behavior — ``updates_applied`` is the eviction clock
    # (write-backs pass ``updates_applied + 1`` as the step) and
    # ``transitions_added`` feeds the min-fill short-circuit. Restoring
    # all of them makes post-restore sampling math bit-identical to a run
    # that never stopped.
    _CKPT_COUNTERS = ("blocks_added", "transitions_added", "batches_sampled",
                      "updates_applied")

    def _capture(self) -> dict:
        st = jax.device_get(self._state)
        return {
            "replay": {"storage": st.storage, "tree": st.tree,
                       "write_pos": st.write_pos, "size": st.size,
                       "total_added": st.total_added},
            "rng": jax.device_get(jax.random.key_data(self._rng)),
            "counters": {k: np.int64(getattr(self.stats, k))
                         for k in self._CKPT_COUNTERS},
        }

    def checkpoint_state(self, timeout_s: float = 60.0) -> dict:
        """Consistent host-side snapshot of everything needed to rebuild
        this shard (plain numpy pytree, ready for ``checkpoint.save``).

        The mutating ops donate ``ReplayState`` into jit, so only the owner
        thread may observe it: a live shard services the request *between*
        ops at its next loop pass; a stopped (or not yet started) shard is
        captured directly. Safe to call from any thread."""
        self._check_alive()
        if not self._thread.is_alive():
            return self._capture()
        box: queue.Queue = queue.Queue(maxsize=1)
        self._ckpt_q.put(box)
        try:
            return box.get(timeout=timeout_s)
        except queue.Empty:
            self._check_alive()
            if not self._thread.is_alive():
                return self._capture()
            raise RuntimeError(
                f"replay shard {self.shard_id} did not answer a checkpoint "
                f"request within {timeout_s}s") from None

    def restore(self, ckpt: dict) -> None:
        """Adopt a ``checkpoint_state`` capture. Must be called before
        ``start()`` (the owner thread is the state's only holder once it
        runs). Restores the replay pytree, the rng stream, and the
        behavioral counters, so the first op after restore continues the
        interrupted run bit-for-bit."""
        if self._thread.is_alive():
            raise RuntimeError("restore() must run before start()")
        rep = ckpt["replay"]
        self._state = replay_lib.ReplayState(
            storage=jax.tree.map(jnp.asarray, rep["storage"]),
            tree=jnp.asarray(rep["tree"]),
            write_pos=jnp.asarray(rep["write_pos"]),
            size=jnp.asarray(rep["size"]),
            total_added=jnp.asarray(rep["total_added"]))
        self._rng = jax.random.wrap_key_data(jnp.asarray(ckpt["rng"]))
        with self._stats_lock:
            for k in self._CKPT_COUNTERS:
                setattr(self.stats, k, int(ckpt["counters"][k]))
            self.stats.replay_size = int(rep["size"])
        self._ready = False  # re-derived from the restored state on demand

    # -- chaos hooks ---------------------------------------------------------

    def pause(self) -> None:
        """Freeze the owner loop (fault injection: a stalled shard owner).
        Queues keep filling — callers see backpressure/starvation exactly as
        they would behind a wedged thread — until :meth:`unpause`."""
        self._paused.set()

    def unpause(self) -> None:
        self._paused.clear()

    # -- observability ------------------------------------------------------

    def snapshot(self) -> ServiceStats:
        """Consistent copy of the running counters, safe to call from any
        thread at any time. ``replay_size`` is refreshed by the owner loop
        every ~``_SIZE_REFRESH_OPS`` applied ops (exact after ``stop()``);
        the other counters are exact at the moment of the snapshot. The
        ``*_us`` fields are derived views — the running mean of the
        shard's latency histograms — kept on the dataclass so benches and
        progress logs read one object."""
        with self._stats_lock:
            snap = dataclasses.replace(self.stats)
        for kind, hist in self._hists.items():
            setattr(snap, f"{kind}_us", hist.mean)
        return snap

    # -- actor side ---------------------------------------------------------

    def _check_alive(self) -> None:
        if self.error is not None:
            raise RuntimeError(
                f"replay shard {self.shard_id} died") from self.error

    def add(self, block: phases.TransitionBlock,
            timeout: float | None = None, trace_id: int = 0) -> bool:
        """Enqueue a transition block; False when the bounded queue stayed
        full for ``timeout`` seconds (the caller is being backpressured).
        ``timeout=None`` uses the ``poll_s`` configured at construction
        (the runner instead passes ``AsyncConfig.add_poll_s`` explicitly).
        A nonzero ``trace_id`` rides the queue with the block and marks
        its apply as a traced "add" span."""
        self._check_alive()
        try:
            self._add_q.put((block, trace_id),
                            timeout=self._poll_s if timeout is None
                            else timeout)
            return True
        except queue.Full:
            self._c_add_blocked.inc()
            return False

    # -- learner side -------------------------------------------------------

    def get_batch(self, timeout: float | None = None):
        """Next prefetched prioritized batch, or None if starved (replay
        below min-fill, or sampling not keeping up with the learner)."""
        self._check_alive()
        try:
            return self._sample_q.get(timeout=self._poll_s if timeout is None
                                      else timeout)
        except queue.Empty:
            self._c_starved.inc()
            return None

    def write_back(self, indices: jax.Array, priorities: jax.Array,
                   trace_id: int = 0) -> None:
        """Queue a priority write-back (Alg. 2 l.8); applied asynchronously.
        A nonzero ``trace_id`` marks the apply as a traced "writeback"
        span, closing the batch's sample → learn → writeback chain."""
        self._update_q.put((indices, priorities, trace_id))

    # -- owner loop ---------------------------------------------------------

    def _bump(self, **deltas: int) -> None:
        with self._stats_lock:
            for k, d in deltas.items():
                setattr(self.stats, k, getattr(self.stats, k) + d)
            self._ops_since_size += 1
            refresh = self._ops_since_size >= _SIZE_REFRESH_OPS
            if refresh:
                self._ops_since_size = 0
        if refresh:
            # Outside the lock: int() blocks on the device, and readers only
            # need the counters to stay consistent, not the size to be fresh.
            size = int(self._state.size)
            with self._stats_lock:
                self.stats.replay_size = size
            self._g_size.set(size)

    def _timed(self, kind: str, fn, *args, trace_id: int = 0):
        """Dispatch an op; every ``_LATENCY_SAMPLE_EVERY``th call of each
        kind — and every traced call — is synced and timed into the
        shard's ``<kind>_us`` histogram (hot-path regressions surface in
        runner progress logs, bench counters, and the obs report). Traced
        calls additionally record a pipeline span under the op's stage
        name so the block/batch chain stays linked across planes."""
        self._op_seq[kind] += 1
        if self._op_seq[kind] % _LATENCY_SAMPLE_EVERY and not trace_id:
            return fn(*args)
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        us = 1e6 * (time.perf_counter() - t0)
        self._hists[kind].record(us)
        if trace_id:
            self._tel.tracer.record(kind, trace_id, us,
                                    shard=self.shard_id)
        return out

    def _stage_block(self, block: phases.TransitionBlock):
        """Issue the async H2D put for a block (no-op without a stager).

        The put's *issue* time feeds the ``h2d_us`` histogram —
        deliberately not synced: the transfer itself is the thing being
        overlapped, so timing its completion would serialize exactly what
        staging hides."""
        if self._stager is None:
            return block
        before = self._stager.blocks_staged
        t0 = time.perf_counter()
        staged = self._stager.stage(block)
        us = 1e6 * (time.perf_counter() - t0)
        if self._stager.blocks_staged == before:  # passed through
            return staged
        with self._stats_lock:
            self.stats.blocks_staged += 1
        self._hists["h2d"].record(us)
        return staged

    def _apply_add(self, block: phases.TransitionBlock,
                   trace_id: int = 0) -> None:
        self._state = self._timed("add", self._fns.add, self._state, block,
                                  trace_id=trace_id)
        self._bump(blocks_added=1,
                   transitions_added=int(block.priorities.shape[0]))

    def _can_sample(self) -> bool:
        """Min-fill gate with a sticky latch: the device-side check (a host
        sync) runs only until it first passes. Afterwards FIFO adds keep the
        buffer full and eviction trims to ``soft_cap >= min_fill``, so the
        gate can't re-close in any supported config. Before the gate can
        possibly pass, a host-side counter short-circuits the device sync:
        live size never exceeds the transitions offered, so while
        ``transitions_added < min_fill`` the owner loop stays sync-free."""
        if not self._ready:
            if self.stats.transitions_added < self._cfg.replay.min_fill:
                return False
            self._ready = bool(self._fns.can_sample(self._state))
        return self._ready

    def _next_rng(self) -> jax.Array:
        self._rng, sub = self._fns.split(self._rng)
        return sub

    def _run_guarded(self) -> None:
        # A dead shard must not fail silently: record the error so actor /
        # learner calls raise instead of spinning against a stalled queue.
        try:
            self._run()
        except BaseException as e:  # noqa: BLE001
            self.error = e

    def _serve_checkpoints(self) -> None:
        """Answer pending checkpoint requests (owner thread only): between
        ops the state is quiescent, so the capture is consistent."""
        while True:
            try:
                box = self._ckpt_q.get_nowait()
            except queue.Empty:
                return
            box.put(self._capture())

    def _run(self) -> None:
        while True:
            while self._paused.is_set() and not self._stop.is_set():
                time.sleep(0.001)  # frozen by fault injection
            self._serve_checkpoints()
            progressed = False
            # Queue-depth gauges once per loop pass: cheap (three qsize
            # reads), and the interval sink turns them into the queue
            # pressure row of the obs report.
            self._g_add_q.set(self._add_q.qsize())
            self._g_sample_q.set(self._sample_q.qsize())
            self._g_update_q.set(self._update_q.qsize())

            # 1. Priority write-backs first: they advance the eviction clock
            # and keep the sampling distribution fresh (Alg. 2 l.8).
            while True:
                try:
                    idx, prios, tid = self._update_q.get_nowait()
                except queue.Empty:
                    break
                step = self.stats.updates_applied + 1
                self._state = self._timed(
                    "writeback", self._fns.writeback,
                    self._state, idx, prios, step, self._next_rng(),
                    trace_id=tid)
                self._bump(updates_applied=1)
                progressed = True

            # 2. Refill the prefetch buffer (Alg. 2 l.4) before touching the
            # add backlog: the learner is the scarce consumer the paper
            # protects, and a starved learner wastes more than a briefly
            # staler sampling distribution costs.
            while not self._sample_q.full() and self._can_sample():
                batch = self._timed("sample", self._fns.sample,
                                    self._state, self._next_rng())
                try:
                    self._sample_q.put_nowait(batch)
                except queue.Full:
                    break
                self._bump(batches_sampled=1)
                progressed = True

            # 3. Drain actor blocks (Alg. 1 l.10-11) — boundedly: under
            # sustained actor pressure an open-ended drain would never
            # yield back to steps 1-2 and the learner would starve behind
            # a permanently non-empty add queue. One queue's worth per
            # iteration keeps ingest at full rate while the prefetch/
            # write-back steps stay scheduled (an unbounded queue —
            # maxsize 0 — gets a fixed chunk instead). The drain is
            # *pipelined* when an ingest stager is attached: block k+1's
            # async device_put is issued before block k's add dispatches,
            # so the H2D transfer overlaps the in-place update; the last
            # staged block is flushed when the queue runs dry (holding it
            # across iterations would stall min-fill under sparse traffic).
            staged_prev = None
            for _ in range(self._add_q.maxsize or _SIZE_REFRESH_OPS):
                try:
                    block, tid = self._add_q.get_nowait()
                except queue.Empty:
                    break
                staged_next = (self._stage_block(block), tid)
                if staged_prev is not None:
                    self._apply_add(*staged_prev)
                staged_prev = staged_next
                progressed = True
            if staged_prev is not None:
                self._apply_add(*staged_prev)

            if self._stop.is_set():
                if self._add_q.empty() and self._update_q.empty():
                    break
                continue
            if not progressed:
                # Idle: park on the add queue so actors wake us immediately.
                try:
                    block, tid = self._add_q.get(timeout=0.002)
                except queue.Empty:
                    continue
                # A lone block has no overlap partner, but staging it still
                # turns the in-jit transfer into an explicit counted put.
                self._apply_add(self._stage_block(block), tid)

        size = int(self._state.size)
        with self._stats_lock:
            self.stats.replay_size = size
        self._g_size.set(size)
        # A checkpoint request racing the exit would otherwise hang its
        # caller until the timeout; serve it here, the state is final.
        self._serve_checkpoints()


# PR 1 name for the single-shard service; the owner loop is unchanged.
ReplayService = ReplayShard
