"""Host-side replay service: the paper's central replay memory as a thread.

One owner thread holds the device-resident ``ReplayState`` and is the only
code that ever touches it, so replay mutation needs no locks. Traffic flows
through three queues, mirroring Fig. 1's arrows:

* ``add``       (actors → replay, bounded) — blocks of n-step transitions
  with actor-side initial priorities. A bounded depth gives *backpressure*:
  when the learner + service fall behind, actors block on ``add`` instead of
  overrunning memory.
* ``samples``   (replay → learner, bounded) — prefetched prioritized
  batches. Depth 2 double-buffers the learner: batch k+1 is sampled while
  the learner consumes batch k. Empty queue = *starved learner*.
* ``updates``   (learner → replay) — priority write-backs; applying one
  counts as a learner step for the periodic eviction clock (paper: evict
  every 100 learning steps).

Known (and intended) relaxation vs the lockstep driver: a prefetched batch
may reference slots that a concurrent add overwrites before the learner's
priorities come back. The paper's distributed system has the same window —
replay content is allowed to be slightly stale relative to the learner.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any

import jax

from repro.core import replay as replay_lib
from repro.runtime import phases


@dataclasses.dataclass
class ServiceStats:
    blocks_added: int = 0          # transition blocks applied to replay
    transitions_added: int = 0     # individual transitions applied
    batches_sampled: int = 0       # prioritized batches prefetched
    updates_applied: int = 0       # priority write-backs (= learner steps seen)
    replay_size: int = 0           # live items at shutdown


class ReplayService:
    """Single replay shard behind double-buffered host-side queues."""

    def __init__(self, cfg, replay_state: replay_lib.ReplayState, *,
                 batch_size: int | None = None, add_queue_depth: int = 4,
                 sample_queue_depth: int = 2, seed: int = 0):
        self._cfg = cfg
        self._state = replay_state
        self._rng = jax.random.key(seed)
        batch = batch_size or cfg.batch_size
        rcfg = cfg.replay

        self._jit_add = jax.jit(
            lambda st, block: phases.replay_add(cfg, st, block))
        self._jit_sample = jax.jit(
            lambda st, rng: replay_lib.sample(rcfg, st, rng, batch))
        self._jit_writeback = jax.jit(
            lambda st, idx, prios, step, rng: phases.priority_writeback(
                cfg, st, idx, prios, step, rng))
        self._jit_can_sample = jax.jit(
            lambda st: replay_lib.can_sample(rcfg, st))
        self._jit_split = jax.jit(lambda k: jax.random.split(k))

        self._ready = False  # sticky min-fill latch (see _can_sample)
        self._add_q: queue.Queue = queue.Queue(maxsize=add_queue_depth)
        self._sample_q: queue.Queue = queue.Queue(maxsize=sample_queue_depth)
        self._update_q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run_guarded, daemon=True,
                                        name="replay-service")
        self.stats = ServiceStats()
        self.error: BaseException | None = None

    @property
    def learner_steps(self) -> int:
        """Eviction-clock position: one applied write-back == one step."""
        return self.stats.updates_applied

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "ReplayService":
        self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        """Ask the service to drain pending work and exit."""
        self._stop.set()
        if join and self._thread.is_alive():
            self._thread.join()

    @property
    def replay_state(self) -> replay_lib.ReplayState:
        """Final replay state; only meaningful after ``stop()``."""
        return self._state

    # -- actor side ---------------------------------------------------------

    def _check_alive(self) -> None:
        if self.error is not None:
            raise RuntimeError("replay service died") from self.error

    def add(self, block: phases.TransitionBlock, timeout: float = 0.05) -> bool:
        """Enqueue a transition block; False when the bounded queue stayed
        full for ``timeout`` seconds (the caller is being backpressured)."""
        self._check_alive()
        try:
            self._add_q.put(block, timeout=timeout)
            return True
        except queue.Full:
            return False

    # -- learner side -------------------------------------------------------

    def get_batch(self, timeout: float = 0.05):
        """Next prefetched prioritized batch, or None if starved (replay
        below min-fill, or sampling not keeping up with the learner)."""
        self._check_alive()
        try:
            return self._sample_q.get(timeout=timeout)
        except queue.Empty:
            return None

    def write_back(self, indices: jax.Array, priorities: jax.Array) -> None:
        """Queue a priority write-back (Alg. 2 l.8); applied asynchronously."""
        self._update_q.put((indices, priorities))

    # -- owner loop ---------------------------------------------------------

    def _apply_add(self, block: phases.TransitionBlock) -> None:
        self._state = self._jit_add(self._state, block)
        self.stats.blocks_added += 1
        self.stats.transitions_added += int(block.priorities.shape[0])

    def _can_sample(self) -> bool:
        """Min-fill gate with a sticky latch: the device-side check (a host
        sync) runs only until it first passes. Afterwards FIFO adds keep the
        buffer full and eviction trims to ``soft_cap >= min_fill``, so the
        gate can't re-close in any supported config."""
        if not self._ready:
            self._ready = bool(self._jit_can_sample(self._state))
        return self._ready

    def _next_rng(self) -> jax.Array:
        self._rng, sub = self._jit_split(self._rng)
        return sub

    def _run_guarded(self) -> None:
        # A dead service must not fail silently: record the error so actor /
        # learner calls raise instead of spinning against a stalled queue.
        try:
            self._run()
        except BaseException as e:  # noqa: BLE001
            self.error = e

    def _run(self) -> None:
        while True:
            progressed = False

            # 1. Priority write-backs first: they advance the eviction clock
            # and keep the sampling distribution fresh (Alg. 2 l.8).
            while True:
                try:
                    idx, prios = self._update_q.get_nowait()
                except queue.Empty:
                    break
                self.stats.updates_applied += 1
                self._state = self._jit_writeback(
                    self._state, idx, prios, self.stats.updates_applied,
                    self._next_rng())
                progressed = True

            # 2. Refill the prefetch buffer (Alg. 2 l.4) before touching the
            # add backlog: the learner is the scarce consumer the paper
            # protects, and a starved learner wastes more than a briefly
            # staler sampling distribution costs.
            while not self._sample_q.full() and self._can_sample():
                batch = self._jit_sample(self._state, self._next_rng())
                try:
                    self._sample_q.put_nowait(batch)
                except queue.Full:
                    break
                self.stats.batches_sampled += 1
                progressed = True

            # 3. Drain actor blocks (Alg. 1 l.10-11).
            while True:
                try:
                    block = self._add_q.get_nowait()
                except queue.Empty:
                    break
                self._apply_add(block)
                progressed = True

            if self._stop.is_set():
                if self._add_q.empty() and self._update_q.empty():
                    break
                continue
            if not progressed:
                # Idle: park on the add queue so actors wake us immediately.
                try:
                    block = self._add_q.get(timeout=0.002)
                except queue.Empty:
                    continue
                self._apply_add(block)

        self.stats.replay_size = int(self._state.size)
