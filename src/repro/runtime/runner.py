"""Decoupled actor/learner runtime (paper Fig. 1, host-threaded realization).

The synchronous driver (``repro.core.apex``) alternates acting and learning
inside one jitted step, which pins the generate:consume ratio to whatever
``rollout_len``/``learner_steps_per_iter`` dictate. Here the two sides run
free:

* N actor threads each own an ``ActorSlice`` (``lanes_per_shard`` vector
  envs) and loop: jitted ``act_phase`` → push the ``TransitionBlock`` into
  the ``ReplayFabric`` (blocking on a bounded queue = backpressure). With
  ``inference_batching`` the per-thread dispatch is replaced by one batched
  ``vmap(act_phase)`` call shared by all actors (``runtime.inference``) —
  the paper's FPS-per-actor economics.
* ``actor_procs`` more actors run as separate OS *processes* (the paper's
  multi-host regime, §3): a ``ReplayGateway`` TCP thread decodes their
  ``ADD_BLOCK`` frames and routes them into the very same ``ReplayFabric``,
  so the learner is agnostic to whether a block crossed a queue or a
  socket. Thread- and process-actors share one exploration ladder
  (processes take the upper actor ids).
* The ``ReplayFabric`` owns ``replay_shards`` independent ``ReplayShard``
  owner threads; actor blocks route round-robin and the learner batch is
  merged from per-shard sub-samples with globally-corrected IS weights
  (``repro.core.sampling``).
* The learner thread loops: pop a prioritized batch from its
  ``SampleSource`` → jitted ``learn_phase`` → write the fresh priorities
  back through the source → publish params through the versioned lock-free
  ``ParamStore``. The learner never touches fabric internals: the source is
  ``LocalFabricSource`` over the in-process fabric by default,
  ``RemoteFabricSource`` against another host's gateway with
  ``learner_remote`` (this process then runs *only* the learner), and
  either wrapped in ``StagedSource`` with ``sample_staging`` (device-staged
  double buffering: the H2D put of batch k+1 overlaps the learn step on k).
* With ``serve_sampling`` the roles flip: this process runs actors + fabric
  + gateway and *no* local learner — a remote learner attaches through the
  gateway's sample plane, and the run's learner clock is the stream of
  ``PRIORITY_UPDATE`` write-backs it sends back.

Threads overlap because XLA releases the GIL while kernels execute, so actor
rollouts, learner updates, and replay maintenance genuinely run concurrently
on CPU — and the same wiring maps to streams/devices on accelerators.

Throughput accounting matches the paper's §4.1 split: transitions/s
*generated* by actors and transitions/s *consumed* by the learner are
measured independently (theirs: ~12.5K vs ~9.7K, ratio ~1.29).
"""

from __future__ import annotations

import dataclasses
import multiprocessing
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.obs import Telemetry
from repro.obs import log as obslog
from repro.runtime import phases
from repro.runtime import snapshot as snapshot_lib
from repro.runtime.fabric import ReplayFabric
from repro.runtime.inference import InferenceServer, InferenceStats
from repro.runtime.params import ParamStore
from repro.runtime.service import ServiceStats
from repro.runtime.sources import (LocalFabricSource, SampleSource,
                                   SourceStats, StagedSource)

# Supervised actor restarts back off exponentially per slot: base * 2^k,
# capped — a crash-looping actor binary must not busy-spin the spawner.
_RESTART_BACKOFF_BASE_S = 0.25
_RESTART_BACKOFF_CAP_S = 5.0


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Runtime geometry: thread counts, queue depths, stop conditions."""

    actor_threads: int = 1           # each runs cfg.lanes_per_shard lanes
                                     # (0 allowed when actor_procs > 0)
    actor_procs: int = 0             # remote actor *processes* feeding the
                                     # fabric through a ReplayGateway socket
    replay_shards: int = 1           # ReplayShard owner threads in the fabric
    inference_batching: bool = False # one vmapped act dispatch for all actors
    inference_mode: str = "wave"     # scheduling inside the shared engine:
                                     # "wave" coalesces up to coalesce_s and
                                     # pads short waves; "slots" admits
                                     # pending requests into free slots the
                                     # moment the previous dispatch returns
                                     # (continuous batching — no window tax,
                                     # params hot-swap at dispatch bounds)
    serve_policy: str | None = None  # "host:port": ALSO serve the shared
                                     # engine over the transport plane (a
                                     # second, policy-only gateway speaking
                                     # ACT_REQUEST/ACT_RESULT); actor procs
                                     # then run as thin clients that ship
                                     # their slice per rollout instead of
                                     # pulling params (requires
                                     # inference_batching)
    learn_batches_per_step: int = 1  # prefetched batches consumed per jitted
                                     # learner call (lax.scan — amortizes
                                     # dispatch for small batches; the run
                                     # stops at the first multiple >=
                                     # total_learner_steps)
    gateway_port: int = 0            # ReplayGateway TCP port (0: ephemeral)
    gateway_host: str = "127.0.0.1"  # ReplayGateway bind address; the
                                     # loopback default only reaches same-
                                     # machine peers — bind "0.0.0.0" to
                                     # serve actors/learners on other hosts
    ingest_max_inflight: int = 4     # un-acked blocks per remote actor (the
                                     # socket analogue of add_queue_depth)
    transport: str = "auto"          # byte path for every remote hop (actor
                                     # procs and learner_remote): "tcp",
                                     # "shm" (same-host shared-memory ring,
                                     # strict), or "auto" (shm when the peer
                                     # is loopback-local, else tcp)
    transport_ring_bytes: int = 0    # shm ring arena size per direction
                                     # (0: repro.net default)
    wire_quantize_obs: bool = False  # remote actors ship obs via the replay
                                     # codec (uint8 + affine, ~4x less wire)
    wire_quantize_prios: bool = False  # remote learner quantizes priority
                                     # write-backs (lossy, uint8 + affine)
    wire_quantize_params: bool = False  # remote learner quantizes PARAM_PUSH
                                     # snapshots (lossy; actors then act on
                                     # quantized params)
    sample_staging: bool = False     # wrap the learner's SampleSource in a
                                     # StagedSource: a stager thread device-
                                     # puts batch k+1 (pinned-host staging +
                                     # async DMA on TPU) while the learner
                                     # computes on batch k
    ingest_staging: bool = False     # the add-side mirror: shard owners
                                     # issue block k+1's async device_put
                                     # (BlockStager) before dispatching
                                     # block k's in-place add, hiding H2D
                                     # behind the update kernel (pass-
                                     # through on CPU hosts; bit-identical
                                     # everywhere)
    learner_remote: str | None = None  # "host:port" of a serving gateway:
                                     # run ONLY the learner here, sampling a
                                     # remote fabric (requires
                                     # actor_threads=0, actor_procs=0,
                                     # replay_shards=1 — the fabric lives on
                                     # the serving host)
    serve_sampling: bool = False     # run actors + fabric + gateway and NO
                                     # local learner; a remote learner
                                     # drives the run through the gateway's
                                     # sample plane (stops after
                                     # total_learner_steps observed
                                     # PRIORITY_UPDATEs)
    add_queue_depth: int = 4         # actor→replay backpressure bound (per shard)
    sample_queue_depth: int = 2      # replay→learner prefetch (double buffer)
    total_learner_steps: int = 200   # stop once the learner consumed this many
    max_seconds: float | None = None # wall-clock safety stop
    publish_every: int = 1           # learner steps between param publications
    starve_timeout_s: float = 0.02   # learner wait per fabric.get_batch poll
    add_poll_s: float = 0.02         # actor wait per fabric.add poll (these
                                     # two replace the hardcoded add/get_batch
                                     # poll intervals; direct ReplayShard /
                                     # ReplayFabric users tune `poll_s` at
                                     # construction instead)
    coalesce_s: float = 0.002        # inference-server wave-forming window
    progress_every_s: float | None = None  # log a fabric-snapshot line every
                                     # so many seconds (None: no progress log)
    metrics_dir: str | None = None   # telemetry plane: write metrics.jsonl /
                                     # spans.jsonl snapshots here (None: keep
                                     # the registry in-process only). Render
                                     # with `python -m repro.obs.report DIR`.
    trace_sample_rate: float = 0.0   # fraction of transition blocks / learner
                                     # batches that carry a pipeline trace id
                                     # (0: tracing off; 1: every block). Traced
                                     # ops force a device sync for honest
                                     # stage durations — keep this small on
                                     # hot runs.
    checkpoint_dir: str | None = None  # snapshot service: periodically save
                                     # fabric + learner + ParamStore version
                                     # as atomic ckpt_<step>.npz files here
                                     # (None: no periodic checkpoints).
                                     # Requires the fabric AND learner to be
                                     # local (not learner_remote/serve_
                                     # sampling).
    checkpoint_every_s: float = 30.0 # seconds between periodic snapshots
    resume: bool = False             # cold-start from checkpoint.latest() in
                                     # checkpoint_dir: replay contents, sum
                                     # trees, eviction clocks, learner slice
                                     # and param version all continue where
                                     # the snapshot left them (an empty
                                     # directory is a normal cold start)
    supervise_actors: bool = True    # respawn dead actor processes with
                                     # capped exponential backoff (actors are
                                     # pure functions of (seed, actor_id) +
                                     # params, so a respawn rebuilds the same
                                     # ladder slot); False: deaths are only
                                     # detected/logged
    actor_restart_limit: int = 5     # supervised respawns per actor slot
                                     # before the slot is declared dead
    reconnect_timeout_s: float = 20.0  # how long remote actors / the remote
                                     # learner source retry (with backoff)
                                     # after a severed transport before
                                     # giving up
    seed: int = 0


@dataclasses.dataclass
class RuntimeResult:
    learner: phases.LearnerSlice     # final params/target/opt state
    stats: dict[str, float]          # throughput + contention counters
    service_stats: ServiceStats      # fabric aggregate (summed over shards)
    shard_stats: list[ServiceStats]  # per-shard counters
    last_actor_metrics: dict | None  # last act_phase metrics (any actor)
    inference_stats: InferenceStats | None = None  # when inference_batching
    gateway_stats: Any = None        # net.GatewayStats when a gateway ran
    policy_stats: Any = None         # net.GatewayStats of the policy-plane
                                     # gateway (serve_policy)
    source_stats: SourceStats | None = None  # learner-plane SampleSource
                                     # counters (None in serve mode)


@dataclasses.dataclass
class RuntimeHandles:
    """Live internals of a running ``run_async``, handed to its
    ``on_handles`` callback once every plane has started. This is the
    surface the fault-injection harness (``repro.testing.chaos``) reaches
    through to kill processes, sever transports, and freeze shard owners —
    deliberately raw, not a stable public API."""

    stop: threading.Event            # the run's stop event
    fabric: Any                      # ReplayFabric | None (learner_remote)
    gateway: Any                     # net.ReplayGateway | None
    source: Any                      # learner SampleSource | None (serve)
    store: Any                       # ParamStore
    procs: list                      # live actor processes (slot-indexed;
                                     # the supervisor swaps entries in place)
    procs_lock: Any                  # guards ``procs`` slot swaps
    snapshots: Any                   # SnapshotService | None
    learner_box: dict                # {"steps", "lslice", "live"}
    counters: dict                   # the run's shared counters dict


def _actor_geometry(cfg, acfg: AsyncConfig):
    """Each actor (thread t in [0, actor_threads), process j at
    actor_threads + j) takes one ladder shard: actor a plays global lanes
    [a*lanes, (a+1)*lanes), so one exploration ladder spans threads and
    remote processes alike. A remote-learner process runs zero actors; its
    ladder width is pinned to 1 (the acting geometry lives on the serving
    host)."""
    return dataclasses.replace(
        cfg, num_shards=max(acfg.actor_threads + acfg.actor_procs, 1))


def run_async(cfg, acfg: AsyncConfig, env, agent, optimizer,
              rng: jax.Array | None = None,
              on_handles: Any = None) -> RuntimeResult:
    """Run the decoupled runtime until the learner consumed
    ``total_learner_steps`` batches (or ``max_seconds`` elapsed). With
    ``learn_batches_per_step = k > 1`` the learner consumes in chunks of k
    and stops at the first multiple of k >= ``total_learner_steps``.

    ``rng`` seeds parameter init only; actor slices always derive from
    ``AsyncConfig.seed`` via ``phases.initial_actor_slice`` so that remote
    actor processes can reproduce their slice from ``(seed, actor_id)``
    alone.

    ``on_handles``, if given, is called once with a :class:`RuntimeHandles`
    after every plane has started — the fault-injection hook."""
    remote = acfg.learner_remote is not None
    serving = acfg.serve_sampling
    if remote and serving:
        raise ValueError(
            "AsyncConfig.learner_remote and serve_sampling are the two "
            "sides of one topology: a process either samples a remote "
            "fabric or serves its own — not both")
    if acfg.actor_procs < 0:
        raise ValueError("AsyncConfig.actor_procs must be >= 0, got "
                         f"{acfg.actor_procs}")
    if acfg.transport not in ("tcp", "shm", "auto"):
        raise ValueError("AsyncConfig.transport must be 'tcp', 'shm', or "
                         f"'auto', got {acfg.transport!r}")
    if (acfg.wire_quantize_prios or acfg.wire_quantize_params) and not remote:
        raise ValueError(
            "wire_quantize_prios/wire_quantize_params configure the remote "
            "learner's upstream frames and require learner_remote")
    if remote and (acfg.actor_threads or acfg.actor_procs
                   or acfg.inference_batching or acfg.replay_shards != 1
                   or acfg.ingest_staging):
        raise ValueError(
            "AsyncConfig.learner_remote runs a learner-only process: the "
            "actors, replay shards, and inference server live on the "
            "serving host — set actor_threads=0, actor_procs=0, "
            "replay_shards=1, inference_batching=False, "
            "ingest_staging=False (got "
            f"threads={acfg.actor_threads}, procs={acfg.actor_procs}, "
            f"shards={acfg.replay_shards}, "
            f"inference_batching={acfg.inference_batching}, "
            f"ingest_staging={acfg.ingest_staging})")
    if serving and (acfg.sample_staging or acfg.learn_batches_per_step != 1):
        raise ValueError(
            "serve_sampling runs no local learner: sample_staging and "
            "learn_batches_per_step configure the learner's consume path "
            "and belong on the learner_remote host (got "
            f"sample_staging={acfg.sample_staging}, "
            f"learn_batches_per_step={acfg.learn_batches_per_step})")
    if not remote and acfg.actor_threads < (0 if acfg.actor_procs else 1):
        raise ValueError(
            "AsyncConfig needs at least one actor: actor_threads >= 1, or "
            "actor_threads >= 0 with actor_procs >= 1 (got "
            f"threads={acfg.actor_threads}, procs={acfg.actor_procs})")
    if acfg.total_learner_steps < 1:
        raise ValueError("AsyncConfig.total_learner_steps must be >= 1, got "
                         f"{acfg.total_learner_steps}")
    if acfg.replay_shards < 1:
        raise ValueError("AsyncConfig.replay_shards must be >= 1, got "
                         f"{acfg.replay_shards}")
    if acfg.learn_batches_per_step < 1:
        raise ValueError("AsyncConfig.learn_batches_per_step must be >= 1, "
                         f"got {acfg.learn_batches_per_step}")
    if acfg.add_queue_depth < 1 or acfg.sample_queue_depth < 1:
        raise ValueError(
            "AsyncConfig.add_queue_depth and sample_queue_depth must be "
            ">= 1: the runtime relies on bounded queues for actor "
            "backpressure and learner double buffering (got "
            f"add={acfg.add_queue_depth}, sample={acfg.sample_queue_depth})")
    if acfg.inference_mode not in ("wave", "slots"):
        raise ValueError("AsyncConfig.inference_mode must be 'wave' or "
                         f"'slots', got {acfg.inference_mode!r}")
    if acfg.serve_policy is not None and not acfg.inference_batching:
        raise ValueError(
            "AsyncConfig.serve_policy serves the shared inference engine "
            "over the transport plane — it requires inference_batching")
    if (acfg.inference_batching and acfg.actor_threads < 1
            and acfg.serve_policy is None):
        raise ValueError("inference_batching needs in-process actor threads "
                         "(or serve_policy, which feeds the engine from "
                         "remote clients)")
    if not 0.0 <= acfg.trace_sample_rate <= 1.0:
        raise ValueError(
            "AsyncConfig.trace_sample_rate is a sampling fraction in "
            f"[0, 1], got {acfg.trace_sample_rate}")
    if acfg.resume and not acfg.checkpoint_dir:
        raise ValueError(
            "AsyncConfig.resume needs checkpoint_dir: resuming means "
            "loading checkpoint.latest() from somewhere")
    if acfg.checkpoint_dir and (remote or serving):
        raise ValueError(
            "AsyncConfig.checkpoint_dir snapshots the replay fabric AND the "
            "learner slice together, so both must be local — a "
            "learner_remote process has no fabric and a serve_sampling "
            "process has no learner. Run the snapshot service on a "
            "single-process topology (got "
            f"learner_remote={acfg.learner_remote!r}, "
            f"serve_sampling={acfg.serve_sampling})")
    if acfg.checkpoint_dir and acfg.checkpoint_every_s <= 0:
        raise ValueError(
            "AsyncConfig.checkpoint_every_s must be > 0 seconds, got "
            f"{acfg.checkpoint_every_s}")
    if acfg.actor_restart_limit < 0:
        raise ValueError(
            "AsyncConfig.actor_restart_limit must be >= 0, got "
            f"{acfg.actor_restart_limit}")
    cfg = _actor_geometry(cfg, acfg)
    rng = jax.random.key(acfg.seed) if rng is None else rng
    p_rng, _ = jax.random.split(rng)

    # -- state ------------------------------------------------------------
    # With zero actor threads the first slice is still built: it seeds
    # param init and the warm-up rollout (remote actor 0 derives the
    # identical slice from (seed, actor_id=0) on its side).
    slices = [phases.initial_actor_slice(cfg, env, acfg.seed, t)
              for t in range(max(acfg.actor_threads, 1))]
    obs0 = slices[0].obs
    params = agent.init(p_rng, obs0[:1])
    lslice = phases.LearnerSlice(
        params=params, target_params=jax.tree.map(jnp.copy, params),
        opt_state=optimizer.init(params),
        learner_step=jnp.zeros((), jnp.int32))
    item = phases.item_example(env, obs0, cfg.compress_obs)

    # One telemetry bundle for the whole run: every plane (fabric shards,
    # gateway, sample source, inference server, the loops below) records
    # into the same registry/tracer, and one sink thread flushes it.
    tel = Telemetry.for_run(acfg.metrics_dir, acfg.trace_sample_rate)
    fabric = None if remote else ReplayFabric(
        cfg, item, num_shards=acfg.replay_shards,
        add_queue_depth=acfg.add_queue_depth,
        sample_queue_depth=acfg.sample_queue_depth, seed=acfg.seed + 1,
        ingest_staging=acfg.ingest_staging, telemetry=tel)
    # -- resume (Appendix F): cold-start from the newest snapshot ----------
    # The fresh fabric/slice above provide the example structure; restoring
    # swaps their contents for the checkpointed ones before any thread
    # starts, so the first op after resume continues the interrupted run.
    resume_steps = 0
    store_version = 0
    if acfg.resume:
        restored = snapshot_lib.restore_run(acfg.checkpoint_dir, fabric,
                                            lslice)
        if restored is not None:
            fabric.restore_shards(restored["shards"])
            lrn = restored["learner"]
            lslice = phases.LearnerSlice(
                params=jax.tree.map(jnp.asarray, lrn["params"]),
                target_params=jax.tree.map(jnp.asarray,
                                           lrn["target_params"]),
                opt_state=jax.tree.map(jnp.asarray, lrn["opt_state"]),
                learner_step=jnp.asarray(lrn["learner_step"]))
            params = lslice.params
            resume_steps = int(restored["steps"])
            store_version = int(restored["param_version"])
            obslog.emit("resume", path=restored["path"], step=resume_steps,
                        params_v=store_version)
    store = ParamStore(params, version=store_version)
    # With a policy plane, remote actor procs land in the same engine as
    # the in-process threads, so the slot count covers both populations.
    infer_batch = acfg.actor_threads + (
        acfg.actor_procs if acfg.serve_policy is not None else 0)
    server = (InferenceServer(cfg, env, agent, store,
                              max_batch=max(infer_batch, 1),
                              coalesce_s=acfg.coalesce_s,
                              mode=acfg.inference_mode, telemetry=tel)
              if acfg.inference_batching else None)
    policy_gateway = None
    if acfg.serve_policy is not None:
        from repro.net import ReplayGateway
        from repro.net import transport as transport_lib
        from repro.net.learner_client import parse_hostport
        policy_host, policy_port = parse_hostport(acfg.serve_policy,
                                                  allow_ephemeral=True)
        # A second, policy-only gateway (fabric=None): ACT_REQUEST frames
        # from thin clients block in the shared engine and batch with the
        # in-process actors' requests.
        policy_gateway = ReplayGateway(
            None, store, host=policy_host, port=policy_port,
            accept_shm=acfg.transport != "tcp",
            ring_bytes=(acfg.transport_ring_bytes
                        or transport_lib.DEFAULT_RING_BYTES),
            inference=server, act_example=slices[0], telemetry=tel)
    gateway = None
    if acfg.actor_procs > 0 or serving:
        # Deferred import: repro.net sits on top of this module's siblings.
        from repro.net import ReplayGateway
        from repro.net import transport as transport_lib
        gateway = ReplayGateway(
            fabric, store, host=acfg.gateway_host, port=acfg.gateway_port,
            add_timeout_s=acfg.add_poll_s,
            # A tcp-pinned runtime refuses ring upgrades outright; shm/auto
            # let each client negotiate (cross-host peers stay tcp anyway).
            accept_shm=acfg.transport != "tcp",
            ring_bytes=(acfg.transport_ring_bytes
                        or transport_lib.DEFAULT_RING_BYTES),
            telemetry=tel)

    # -- sample plane ------------------------------------------------------
    # The learner consumes a SampleSource and never reaches into fabric
    # internals; every topology is one source construction here.
    source: SampleSource | None = None
    if not serving:
        if remote:
            from repro.net import transport as transport_lib
            from repro.net.learner_client import (RemoteFabricSource,
                                                  parse_hostport)
            host, port = parse_hostport(acfg.learner_remote)
            source = RemoteFabricSource(
                host, port, transport=acfg.transport,
                poll_s=acfg.starve_timeout_s,
                ring_bytes=(acfg.transport_ring_bytes
                            or transport_lib.DEFAULT_RING_BYTES),
                quantize_prios=acfg.wire_quantize_prios,
                quantize_params=acfg.wire_quantize_params,
                telemetry=tel)
        else:
            source = LocalFabricSource(fabric, telemetry=tel)
        if acfg.sample_staging:
            source = StagedSource(source, poll_s=acfg.starve_timeout_s,
                                  telemetry=tel)

    act_fn = (jax.jit(lambda p, sl, sid: phases.act_phase(
                  cfg, env, agent, p, sl, sid))
              if server is None and acfg.actor_threads > 0 else None)
    learn_k = acfg.learn_batches_per_step
    if not serving:
        learn_fn = jax.jit(lambda lsl, items, w: phases.learn_phase(
            cfg, agent, optimizer, lsl, items, w, None))
        if learn_k > 1:
            # Satellite of the prefetch queues: one jitted call consumes k
            # double-buffered batches via lax.scan, amortizing dispatch
            # overhead when per-batch compute is small.
            def _learn_scan(lsl, items_k, w_k):
                def body(l, xw):
                    l, prios, _ = phases.learn_phase(cfg, agent, optimizer,
                                                     l, xw[0], xw[1], None)
                    return l, prios
                return jax.lax.scan(body, lsl, (items_k, w_k))
            learn_many_fn = jax.jit(_learn_scan)

    # Warm the caches before the clock starts: one throwaway rollout (the
    # batched server wave when inference batching is on, the per-actor fn
    # otherwise — only the variant that will actually run) and one throwaway
    # update on storage-shaped garbage (results discarded). The warm rollout
    # also *measures* the block size, so accounting follows whatever
    # act_phase actually emits.
    if server is not None:
        block_transitions = server.warm(slices[0])
    elif act_fn is not None:
        _, block0, _ = jax.block_until_ready(
            act_fn(params, slices[0], jnp.int32(0)))
        block_transitions = int(block0.priorities.shape[0])
    else:
        # No acting on this host (pure actor-procs mode, or a remote-learner
        # process): don't compile a rollout just to measure it — the block
        # size is the formula the error below spells out (remote transitions
        # are counted from actual gateway traffic anyway).
        block_transitions = (cfg.lanes_per_shard * cfg.window
                             * cfg.replicate_k)
    if fabric is not None and block_transitions > fabric.shard_capacity:
        # a block must fit inside one shard or the circular add would alias
        raise ValueError(
            f"transition block ({block_transitions}) larger than per-shard "
            f"replay capacity ({fabric.shard_capacity}): lower "
            f"AsyncConfig.replay_shards (= {acfg.replay_shards}) or shrink "
            f"lanes_per_shard * (rollout_len - n_step + 1) * replicate_k")
    if not serving:
        items_ex, w_ex = phases.learner_batch_example(cfg, item)
        jax.block_until_ready(learn_fn(lslice, items_ex, w_ex))
        if learn_k > 1:
            items_k_ex = jax.tree.map(
                lambda a: jnp.zeros((learn_k,) + a.shape, a.dtype), items_ex)
            jax.block_until_ready(learn_many_fn(
                lslice, items_k_ex,
                jnp.ones((learn_k, cfg.batch_size), jnp.float32)))
    stop = threading.Event()
    counters = {"actor_transitions": 0, "actor_blocked": 0,
                "learner_starved": 0, "rollouts": 0, "actor_restarts": 0,
                "actor_proc_exits": 0}
    counter_lock = threading.Lock()
    last_metrics: list[Any] = [None]
    thread_errors: list[BaseException] = []

    def guarded(fn):
        """A dead worker must stop the whole runtime, not hang or silently
        yield an untrained result: record the error and wake everyone."""
        def run(*args):
            try:
                fn(*args)
            except BaseException as e:  # noqa: BLE001
                thread_errors.append(e)
                stop.set()
        return run

    # -- actor threads ----------------------------------------------------
    def actor_loop(t: int) -> None:
        sl = slices[t]
        sid = jnp.int32(t)
        snap = store.get()
        rollouts = blocked = pushed = 0
        tracer = tel.tracer
        while not stop.is_set():
            # A traced rollout opens the block's pipeline trace: the same id
            # rides the fabric add (and, for remote actors, the wire header)
            # so the report can line stages up per block.
            tid = tracer.sample()
            t_roll = time.perf_counter() if tid else 0.0
            if server is not None:
                # Batched inference: param refresh happens server-side.
                res = server.act(sl, t)
                if res is None:  # server (or runtime) stopping
                    break
                sl, block, metrics = res
            else:
                if rollouts % cfg.param_sync_period == 0:
                    snap = store.get()
                sl, block, metrics = act_fn(snap.params, sl, sid)
            if tid:
                jax.block_until_ready(block)  # honest rollout duration
                tracer.record("actor", tid,
                              1e6 * (time.perf_counter() - t_roll), actor=t)
            while not stop.is_set():
                if fabric.add(block, timeout=acfg.add_poll_s, trace_id=tid):
                    pushed += 1
                    break
                blocked += 1  # bounded queue full: actor is backpressured
            rollouts += 1
            last_metrics[0] = metrics
        with counter_lock:
            counters["actor_transitions"] += pushed * block_transitions
            counters["actor_blocked"] += blocked
            counters["rollouts"] += rollouts

    # -- learner thread ---------------------------------------------------
    # "live" is the snapshot service's view: one atomic (steps, lslice)
    # rebind per learner step, so a periodic checkpoint never captures a
    # torn step-count/params pair.
    learner_box = {"lslice": lslice, "steps": resume_steps,
                   "live": (resume_steps, lslice)}

    def learner_loop() -> None:
        lsl = learner_box["lslice"]
        steps = resume_steps
        starved = 0
        pending: list = []  # gathered batches for one k-sized jitted call
        while steps < acfg.total_learner_steps and not stop.is_set():
            batch = source.get_batch(timeout=acfg.starve_timeout_s)
            if batch is None:
                starved += 1  # replay below min-fill or prefetch lagging
                continue
            if learn_k == 1:
                # The source stamped this batch's consume-plane trace id
                # when it drew it; the learn span and the priority
                # write-back inherit it (k > 1 chunks stay untraced — one
                # jitted call spans k batches, so a per-batch duration
                # would be a lie).
                tid = source.last_trace_id
                t_learn = time.perf_counter() if tid else 0.0
                lsl, new_prios, _ = learn_fn(lsl, batch.items,
                                             batch.is_weights)
                if tid:
                    jax.block_until_ready(new_prios)  # honest learn duration
                    tel.tracer.record(
                        "learn", tid,
                        1e6 * (time.perf_counter() - t_learn), step=steps)
                source.write_back(batch.indices, new_prios, trace_id=tid)
                steps += 1
            else:
                pending.append(batch)
                if len(pending) < learn_k:
                    continue
                items_k = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *[b.items for b in pending])
                w_k = jnp.stack([b.is_weights for b in pending])
                lsl, prios_k = learn_many_fn(lsl, items_k, w_k)
                # One write-back per consumed batch: each application ticks
                # the shard's eviction clock once, so k-batching leaves the
                # paper's evict-every-N-steps pacing unchanged.
                for i, b in enumerate(pending):
                    source.write_back(b.indices, prios_k[i])
                pending = []
                steps += learn_k
            learner_box["live"] = (steps, lsl)
            if steps % acfg.publish_every < learn_k:
                version = store.publish(lsl.params)
                # Remote transports also ship the snapshot upstream, so the
                # actors feeding the (remote) fabric keep pulling
                # learning-current params; local sources no-op.
                source.publish_params(version, lsl.params)
        jax.block_until_ready(lsl.params)
        learner_box["lslice"] = lsl
        learner_box["steps"] = steps
        counters["learner_starved"] = starved

    def serve_loop() -> None:
        """Serve-sampling mode: no local learner. The learner clock is the
        remote learner's PRIORITY_UPDATE stream observed at the gateway;
        the run ends when it reaches ``total_learner_steps`` (or
        ``max_seconds``/a worker death stops it first).

        A learner-marked BYE also ends the run: the remote learner's own
        step clock is authoritative, and a severed-then-reconnected
        transport can swallow priority frames that were in flight when
        the socket died (bounded loss the replay tolerates — priorities
        are idempotent LWW hints), so the observed count may stall just
        short of the target a frame or two forever."""
        while not stop.wait(timeout=0.1):
            snap = gateway.snapshot()
            if snap.priority_updates >= acfg.total_learner_steps:
                break
            if snap.learner_byes > 0 and snap.priority_updates > 0:
                break
        learner_box["steps"] = gateway.snapshot().priority_updates

    # -- actor-process supervision ----------------------------------------
    # In-process workers propagate death through guarded()/_check_alive;
    # the socket path needs its own watchdog. The supervisor tracks every
    # actor-process *slot* independently of local threads (a dead proc is
    # detected even when actor_threads > 0) and — because actors are pure
    # functions of (seed, actor_id) + the latest params — respawns dead
    # processes with capped exponential backoff, the paper's
    # restartable-actor model. A dead gateway, or every experience source
    # permanently gone, still stops the runtime instead of letting the
    # learner starve forever.
    procs: list = []
    proc_specs: list = []
    procs_lock = threading.Lock()
    spawn_actor: Any = None  # bound below once the spawn ctx exists
    c_restarts = tel.counter("supervisor/actor_restarts")
    c_proc_exits = tel.counter("supervisor/actor_proc_exits")

    def supervisor() -> None:
        n = len(procs)
        restarts = [0] * n          # respawns burned per slot
        retry_at = [0.0] * n        # scheduled respawn time (0 = none)
        dead = [False] * n          # slot exhausted / unsupervised death
        while not stop.wait(timeout=0.25):
            if gateway.error is not None:
                thread_errors.append(gateway.error)
                stop.set()
                return
            now = time.monotonic()
            for j in range(n):
                with procs_lock:
                    p = procs[j]
                if p.is_alive() or dead[j]:
                    continue
                if retry_at[j] == 0.0:
                    # First observation of this death.
                    with counter_lock:
                        counters["actor_proc_exits"] += 1
                    c_proc_exits.inc()
                    if (not acfg.supervise_actors
                            or restarts[j] >= acfg.actor_restart_limit):
                        dead[j] = True
                        obslog.emit("actor-proc-down", slot=j,
                                    exitcode=p.exitcode,
                                    restarts=restarts[j],
                                    supervised=acfg.supervise_actors)
                        continue
                    backoff = min(
                        _RESTART_BACKOFF_BASE_S * (2 ** restarts[j]),
                        _RESTART_BACKOFF_CAP_S)
                    retry_at[j] = now + backoff
                    obslog.emit("actor-proc-exited", slot=j,
                                exitcode=p.exitcode,
                                retry_in_s=round(backoff, 2))
                    continue
                if now < retry_at[j]:
                    continue
                restarts[j] += 1
                retry_at[j] = 0.0
                with procs_lock:
                    procs[j] = spawn_actor(j)
                with counter_lock:
                    counters["actor_restarts"] += 1
                c_restarts.inc()
                obslog.emit("actor-restart", slot=j, attempt=restarts[j])
            if acfg.actor_threads == 0 and n and all(dead):
                thread_errors.append(RuntimeError(
                    "every remote actor process exited"
                    + (" and exhausted its restart budget"
                       if acfg.supervise_actors else "")
                    + " before the learner finished; no experience source "
                      "remains"))
                stop.set()
                return

    # -- progress logging (satellite of the fabric: observable while hot) --
    def progress_loop() -> None:
        t_start = time.perf_counter()
        while not stop.wait(timeout=acfg.progress_every_s):
            snap = (fabric.snapshot() if fabric is not None
                    else source.snapshot())
            dt = time.perf_counter() - t_start
            obslog.emit(
                "async", t=round(dt, 1),
                generated=snap.transitions_added,
                sampled_batches=snap.batches_sampled,
                writebacks=snap.updates_applied,
                replay_size=snap.replay_size,
                add_us=round(snap.add_us), sample_us=round(snap.sample_us),
                writeback_us=round(snap.writeback_us),
                h2d_us=round(snap.h2d_us),
                params_v=store.version)

    # -- drive ------------------------------------------------------------
    tel.start()  # sink flush thread (no-op without metrics_dir)
    if fabric is not None:
        fabric.start()
    if server is not None:
        server.start()
    if policy_gateway is not None:
        policy_gateway.start()
        obslog.emit("serve-policy", listening=True,
                    host=policy_gateway.host, port=policy_gateway.port)
    if gateway is not None:
        from repro.net import RemoteActorSpec
        from repro.net.actor_client import run_remote_actor
        gateway.start()
        if serving:
            # The learner host needs this address to attach; ephemeral
            # ports are only discoverable here.
            obslog.emit("serve-sampling", listening=True,
                        host=gateway.host, port=gateway.port)
        ctx = multiprocessing.get_context("spawn")  # never fork a jax parent
        # A wildcard bind serves remote peers; local subprocesses dial
        # loopback rather than the unroutable 0.0.0.0.
        dial_host = ("127.0.0.1" if gateway.host in ("0.0.0.0", "::")
                     else gateway.host)
        policy_dial = None
        if policy_gateway is not None:
            ph = ("127.0.0.1" if policy_gateway.host in ("0.0.0.0", "::")
                  else policy_gateway.host)
            policy_dial = f"{ph}:{policy_gateway.port}"
        for j in range(acfg.actor_procs):
            proc_specs.append(RemoteActorSpec(
                cfg=cfg, env=env, agent=agent,
                host=dial_host, port=gateway.port, policy=policy_dial,
                actor_id=acfg.actor_threads + j, seed=acfg.seed,
                max_inflight=acfg.ingest_max_inflight,
                quantize_obs=acfg.wire_quantize_obs,
                transport=acfg.transport,
                trace_sample_rate=acfg.trace_sample_rate,
                reconnect_timeout_s=acfg.reconnect_timeout_s,
                **({"ring_bytes": acfg.transport_ring_bytes}
                   if acfg.transport_ring_bytes else {})))

        def spawn_actor(j: int):
            p = ctx.Process(target=run_remote_actor, args=(proc_specs[j],),
                            daemon=True, name=f"actor-proc-{j}")
            p.start()
            return p

        for j in range(acfg.actor_procs):
            procs.append(spawn_actor(j))
        threading.Thread(target=supervisor, daemon=True,
                         name="actor-supervisor").start()
    if source is not None:
        # Connect/spin up the sample plane before the clock starts (the
        # remote transport retries while the serving host finishes binding).
        source.start()
    snapshots = None
    if acfg.checkpoint_dir:
        snapshots = snapshot_lib.SnapshotService(
            acfg.checkpoint_dir, fabric, learner_box, store,
            every_s=acfg.checkpoint_every_s, telemetry=tel).start()
    actors = [threading.Thread(target=guarded(actor_loop), args=(t,),
                               daemon=True, name=f"actor-{t}")
              for t in range(acfg.actor_threads)]
    learner = threading.Thread(
        target=guarded(serve_loop if serving else learner_loop),
        daemon=True, name="serve-wait" if serving else "learner")
    progress = (threading.Thread(target=progress_loop, daemon=True,
                                 name="progress")
                if acfg.progress_every_s else None)
    t0 = time.perf_counter()
    for th in actors:
        th.start()
    learner.start()
    if progress is not None:
        progress.start()
    if on_handles is not None:
        on_handles(RuntimeHandles(
            stop=stop, fabric=fabric, gateway=gateway, source=source,
            store=store, procs=procs, procs_lock=procs_lock,
            snapshots=snapshots, learner_box=learner_box,
            counters=counters))
    learner.join(timeout=acfg.max_seconds)
    stop.set()
    if server is not None:
        server.stop(join=False)  # unblock actors parked on act() first
    for th in actors:
        th.join()
    learner.join()
    if progress is not None:
        progress.join()
    dt = time.perf_counter() - t0
    pg_snap = None
    if policy_gateway is not None:
        # Before the ingest gateway joins the actor processes: a thin
        # client parked in an ACT round trip must see its STOP (the engine
        # is already stopping, so pending requests answer STOP immediately).
        policy_gateway.stop()
        if policy_gateway.error is not None:
            thread_errors.append(policy_gateway.error)
        pg_snap = policy_gateway.snapshot()
    if server is not None:
        server.stop()
        if server.error is not None:
            thread_errors.append(server.error)
    gw_snap = None
    if gateway is not None:
        # STOP goes out to every actor process; the drain grace lets their
        # in-flight blocks land and their BYE counters merge, then the
        # processes exit on their own. Stubborn ones are terminated.
        gateway.stop()
        with procs_lock:
            final_procs = list(procs)
        for p in final_procs:
            p.join(timeout=30.0)
        for p in final_procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)
            elif p.exitcode not in (0, None):
                if acfg.supervise_actors:
                    # A supervised run already absorbed (and possibly
                    # replaced) crashing actors mid-run; a crash in the
                    # shutdown window is the same tolerated event, not a
                    # run failure.
                    obslog.emit("actor-proc-down", slot=p.name,
                                exitcode=p.exitcode, at="shutdown")
                else:
                    thread_errors.append(RuntimeError(
                        f"actor process {p.name} exited with {p.exitcode}"))
        if gateway.error is not None:
            thread_errors.append(gateway.error)
        gw_snap = gateway.snapshot()
        with counter_lock:
            # Includes blocks that landed during the shutdown drain grace:
            # they were generated inside the measured window and were
            # sitting in the bounded in-flight window — the remote analogue
            # of in-process blocks parked in shard add queues at stop,
            # which the thread counters include the same way.
            counters["actor_transitions"] += gw_snap.transitions_in
            counters["actor_blocked"] += (gw_snap.add_retries
                                          + gw_snap.client_blocked)
            counters["rollouts"] += gw_snap.blocks_in
    if source is not None:
        # Stop the sample plane before the fabric: a staged source's stager
        # thread is still pulling prefetched batches, and the remote client
        # wants to BYE before its socket dies under it.
        source.stop()
        if source.error is not None:
            thread_errors.append(source.error)
    if fabric is not None:
        fabric.stop()
        if fabric.error is not None:
            # A shard may die after the learner's last call (e.g. during the
            # final drain) — no later add/get_batch would surface it.
            thread_errors.append(fabric.error)
    if snapshots is not None:
        # After fabric.stop(): the shards have drained their queues, so the
        # final snapshot is the complete end-of-run state — a clean
        # shutdown resumes from its very end. Skip it when the run is
        # already failing (a dead shard cannot be captured).
        snapshots.stop(final_save=not thread_errors)
        if snapshots.error is not None:
            thread_errors.append(snapshots.error)
    # Final flush *after* every plane stopped, so the last metrics snapshot
    # and the tail of the span buffer land in the JSONL (even on failure —
    # a run that died is exactly the one worth reading the report of).
    tel.stop()
    if thread_errors:
        raise RuntimeError(
            f"async runtime worker died after {dt:.1f}s") from thread_errors[0]

    steps = learner_box["steps"]
    shard_stats = fabric.shard_snapshots() if fabric is not None else []
    agg = fabric.snapshot() if fabric is not None else source.snapshot()
    stats = {
        "seconds": dt,
        "actor_transitions": float(counters["actor_transitions"]),
        "learner_transitions": float(steps * cfg.batch_size),
        "actor_tps": counters["actor_transitions"] / dt if dt > 0 else 0.0,
        "learner_tps": steps * cfg.batch_size / dt if dt > 0 else 0.0,
        "rollouts": float(counters["rollouts"]),
        "learner_steps": float(steps),
        "actor_blocked": float(counters["actor_blocked"]),
        "learner_starved": float(counters["learner_starved"]),
        "param_version": float(store.version),
        "replay_size": float(agg.replay_size),
        "replay_shards": float(acfg.replay_shards),
        "actor_procs": float(acfg.actor_procs),
        "actor_restarts": float(counters["actor_restarts"]),
        "actor_proc_exits": float(counters["actor_proc_exits"]),
        "resumed_from_step": float(resume_steps),
    }
    if snapshots is not None:
        stats["snapshots"] = float(snapshots.saves)
    if source is not None:
        stats["source_reconnects"] = float(source.reconnect_count)
    if gw_snap is not None:
        stats["gateway_transitions"] = float(gw_snap.transitions_in)
        stats["gateway_param_sends"] = float(gw_snap.param_sends)
        stats["gateway_bytes_in"] = float(gw_snap.bytes_in)
    if pg_snap is not None:
        stats["policy_acts"] = float(pg_snap.act_requests)
        stats["policy_bytes_out"] = float(pg_snap.bytes_out)
    stats["generate_consume_ratio"] = (
        stats["actor_tps"] / stats["learner_tps"]
        if stats["learner_tps"] > 0 else float("inf"))
    m = last_metrics[0]
    return RuntimeResult(
        learner=learner_box["lslice"], stats=stats,
        service_stats=agg, shard_stats=shard_stats,
        last_actor_metrics=(
            {k: float(v) for k, v in m.items()} if m is not None else None),
        inference_stats=server.snapshot() if server is not None else None,
        gateway_stats=gw_snap, policy_stats=pg_snap,
        source_stats=source.stats if source is not None else None)
