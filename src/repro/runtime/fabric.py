"""Sharded replay fabric: N replay shards behind one actor/learner facade.

The paper scales the central replay memory by sharding it (§3: "the replay
memory may be distributed across many machines"); PR 1's single
``ReplayService`` becomes here an N-shard *fabric* with the same interface:

* **Ingest** — ``add`` routes actor ``TransitionBlock``s round-robin across
  shards (a fetch-and-increment ticket, so concurrent actors spread load
  evenly; under backpressure a failed attempt retries on the next shard in
  the rotation). Each shard's owner thread applies its own adds, so ingest
  bandwidth scales with shard count.
* **Sample** — ``get_batch`` assembles one learner batch from per-shard
  sub-samples: every shard continuously prefetches ``batch_size /
  num_shards``-item sub-batches (equal quotas, as in the synchronous
  ``shard_map`` driver), and the fabric concatenates one sub-batch per shard,
  re-weighting with ``repro.core.sampling.merged_is_weights`` — the *same*
  formula the sync path computes with ``psum``/``pmax`` collectives.
* **Write-back** — sampled items carry global ``(shard, slot)`` keys encoded
  as ``global_index = shard_id * shard_capacity + slot``. ``write_back``
  decodes the key and scatters each learner priority to the owning shard's
  update queue.

Global min-fill semantics match the sync driver's ``pmin`` gate: a merged
batch is only produced once *every* shard passes its (scaled) min-fill.

Single-consumer contract: ``get_batch``/``write_back`` are called from one
learner thread (partial sub-batch sets are parked between calls without
locking); ``add`` is safe from any number of actor threads.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replay as replay_lib, sampling
from repro.runtime import phases
from repro.runtime.service import (ReplayShard, ServiceStats, ShardFns,
                                   make_shard_fns)


# A merged learner batch is exactly the learner-plane contract: global
# (shard, slot) keys, items, globally-corrected IS weights. The historical
# fabric-local name is kept as an alias.
FabricBatch = sampling.LearnerBatch


def shard_replay_config(rcfg: replay_lib.ReplayConfig,
                        num_shards: int) -> replay_lib.ReplayConfig:
    """Split one logical replay config across ``num_shards`` equal shards.

    Total capacity is preserved exactly — which requires the per-shard slice
    ``capacity / num_shards`` to itself be a power of two (capacity already
    is one, so in practice: a power-of-two shard count); anything else would
    silently inflate or shrink the configured memory, so it is rejected.
    Soft cap and min-fill are both ceil-rounded: ceil is monotone, so a base
    config with ``soft_cap >= min_fill`` keeps that invariant per shard (the
    sticky min-fill latch in ``ReplayShard._can_sample`` relies on it).
    """
    if num_shards == 1:
        return rcfg
    cap, rem = divmod(rcfg.capacity, num_shards)
    if rem or cap < 2 or cap & (cap - 1):
        raise ValueError(
            f"capacity {rcfg.capacity} cannot be split into {num_shards} "
            f"power-of-two shards — use a power-of-two shard count that "
            f"divides the capacity")
    soft = (None if rcfg.soft_capacity is None
            else max(1, math.ceil(rcfg.soft_capacity / num_shards)))
    return dataclasses.replace(
        rcfg, capacity=cap, soft_capacity=soft,
        min_fill=max(1, math.ceil(rcfg.min_fill / num_shards)))


@functools.lru_cache(maxsize=None)
def _partition_fn(num_shards: int, shard_capacity: int):
    """Jitted write-back partition for one fabric geometry: stable-sort the
    global keys by owning shard and count the per-shard segment lengths, all
    on device. The host then transfers only the tiny count vector and hands
    each shard a lazy slice of the sorted device arrays — one device→host
    sync per write-back instead of materializing the whole index batch with
    ``np.asarray`` every learner step."""
    @jax.jit
    def part(indices, priorities):
        sids = indices // shard_capacity
        order = jnp.argsort(sids, stable=True)
        counts = jnp.sum(sids[:, None] == jnp.arange(num_shards)[None, :],
                         axis=0)
        return (indices - sids * shard_capacity)[order], priorities[order], counts
    return part


@functools.lru_cache(maxsize=None)
def _merge_fn(beta: float, shard_capacity: int):
    """Jitted sub-sample merge for one (beta, per-shard-capacity) geometry,
    cached so same-geometry fabric instances share one compilation (the
    shard count specializes via the traced tuple length)."""
    @jax.jit
    def merge(subs):
        leaf = jnp.stack([b.leaf_mass for b in subs])
        totals = jnp.stack([b.total_mass for b in subs])
        sizes = jnp.stack([b.size for b in subs])
        w = sampling.merged_is_weights(leaf, totals, sizes, beta).reshape(-1)
        idx = jnp.concatenate(
            [b.indices + k * shard_capacity for k, b in enumerate(subs)])
        items = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                             *[b.items for b in subs])
        return idx, items, w
    return merge


class ReplayFabric:
    """N ``ReplayShard``s + round-robin ingest + learner-side batch merge."""

    def __init__(self, cfg, item_example: Any, *, num_shards: int = 1,
                 batch_size: int | None = None, add_queue_depth: int = 4,
                 sample_queue_depth: int = 2, seed: int = 0,
                 poll_s: float = 0.05, fns: ShardFns | None = None,
                 ingest_staging: bool = False, telemetry=None):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        batch = batch_size or cfg.batch_size
        if batch % num_shards:
            raise ValueError(
                f"batch_size {batch} must be divisible by num_shards "
                f"{num_shards} (equal per-shard sample quotas)")
        self.num_shards = num_shards
        self.sub_batch = batch // num_shards
        rcfg = shard_replay_config(cfg.replay, num_shards)
        self._cfg = cfg if num_shards == 1 else dataclasses.replace(
            cfg, replay=rcfg,
            # Prioritized eviction fires on every shard per learner step, so
            # the per-event victim count must shrink with the per-shard
            # buffer or N shards would evict N x the configured amount.
            evict_num=max(1, (cfg.evict_num or batch) // num_shards))
        self.shard_capacity = rcfg.capacity
        # One set of jitted fns for all shards: identical geometry means one
        # trace/compile per op, not one per shard. Callers rebuilding
        # same-geometry fabrics (benches, tests) can pass ``fns`` to reuse
        # compilations across instances too.
        fns = fns or make_shard_fns(self._cfg, self.sub_batch)
        self.fns = fns
        self.shards = [
            ReplayShard(self._cfg, replay_lib.init(rcfg, item_example),
                        batch_size=self.sub_batch,
                        add_queue_depth=add_queue_depth,
                        sample_queue_depth=sample_queue_depth,
                        seed=seed + k, shard_id=k, fns=fns, poll_s=poll_s,
                        ingest_staging=ingest_staging, telemetry=telemetry)
            for k in range(num_shards)]
        self._poll_s = poll_s
        self._ticket = 0
        self._ticket_lock = threading.Lock()
        self._pending: list[replay_lib.SampleBatch | None] = (
            [None] * num_shards)
        # Shared across same-geometry fabric instances (like ShardFns): the
        # merge only depends on beta and the per-shard capacity.
        self._merge = _merge_fn(rcfg.beta, rcfg.capacity)
        self._part = _partition_fn(num_shards, rcfg.capacity)

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "ReplayFabric":
        for sh in self.shards:
            sh.start()
        return self

    def stop(self, join: bool = True) -> None:
        for sh in self.shards:       # signal all first so drains overlap
            sh.stop(join=False)
        if join:
            for sh in self.shards:
                sh.stop(join=True)

    @property
    def error(self) -> BaseException | None:
        for sh in self.shards:
            if sh.error is not None:
                return sh.error
        return None

    def replay_states(self) -> list[replay_lib.ReplayState]:
        """Final per-shard states; only meaningful after ``stop()``."""
        return [sh.replay_state for sh in self.shards]

    # -- checkpoint / restore ------------------------------------------------

    def checkpoint_shards(self) -> list[dict]:
        """Consistent host-side captures of every shard (safe while hot:
        each owner thread answers between ops). The list is the fabric's
        contribution to a run snapshot — restore into a same-geometry
        fabric with :meth:`restore_shards` before ``start()``."""
        return [sh.checkpoint_state() for sh in self.shards]

    def restore_shards(self, ckpts: list) -> None:
        if len(ckpts) != self.num_shards:
            raise ValueError(
                f"checkpoint has {len(ckpts)} shards, fabric has "
                f"{self.num_shards}: resume requires the same "
                f"replay_shards geometry the snapshot was taken with")
        for sh, ckpt in zip(self.shards, ckpts):
            sh.restore(ckpt)

    # -- observability ------------------------------------------------------

    def snapshot(self) -> ServiceStats:
        """Aggregated counters across shards, safe while running. Counters
        sum per-shard values (note ``updates_applied`` counts per-shard
        write-back applications: one learner step touches every shard);
        the per-op latency means (``*_us``) average over the shards that
        have a measurement, weighted by each shard's op count."""
        return ServiceStats.aggregate(self.shard_snapshots())

    def shard_snapshots(self) -> list[ServiceStats]:
        return [sh.snapshot() for sh in self.shards]

    @property
    def stats(self) -> ServiceStats:
        return self.snapshot()

    # -- actor side ---------------------------------------------------------

    def add(self, block: phases.TransitionBlock,
            timeout: float | None = None, trace_id: int = 0) -> bool:
        """Route a block to the next shard in the rotation; False when that
        shard's bounded queue stayed full (backpressure — the rotation has
        already advanced, so a retry lands on the next shard). A nonzero
        ``trace_id`` follows the block to the owning shard's add span."""
        n = int(block.priorities.shape[0])
        if n > self.shard_capacity:
            raise ValueError(
                f"transition block ({n}) larger than per-shard capacity "
                f"({self.shard_capacity}): with {self.num_shards} shards a "
                f"block must fit one shard — lower the shard count or shrink "
                f"lanes_per_shard * (rollout_len - n_step + 1) * replicate_k")
        with self._ticket_lock:
            k = self._ticket % self.num_shards
            self._ticket += 1
        return self.shards[k].add(block, timeout, trace_id=trace_id)

    # -- learner side -------------------------------------------------------

    def get_batch(self, timeout: float | None = None):
        """One merged learner batch, or None while any shard is starved
        (below min-fill or prefetch lagging). Sub-batches already collected
        are parked, so repeated calls make progress shard by shard."""
        t = self._poll_s if timeout is None else timeout
        per_shard = max(t / self.num_shards, 1e-4)
        for k, sh in enumerate(self.shards):
            if self._pending[k] is None:
                self._pending[k] = sh.get_batch(timeout=per_shard)
        if any(p is None for p in self._pending):
            return None
        subs = tuple(self._pending)
        self._pending = [None] * self.num_shards
        if self.num_shards == 1:
            return subs[0]  # plain SampleBatch: key == slot, native weights
        return FabricBatch(*self._merge(subs))

    def write_back(self, indices: jax.Array, priorities: jax.Array,
                   trace_id: int = 0) -> None:
        """Scatter learner priorities back to the owning shards by decoding
        the global ``(shard, slot)`` keys (Alg. 2 l.8). A nonzero
        ``trace_id`` marks every shard's segment apply as part of the same
        batch trace (the batch fans out; the trace follows all of it).

        The keys are self-describing (``shard = key // shard_capacity``), so
        any subset/ordering of keys from batches this fabric assembled is
        valid — callers may filter or reorder before writing back.

        The partition (stable sort by owning shard + segment counts) runs as
        jitted device ops; the host transfers only the per-shard counts and
        passes each shard a lazy slice of the sorted device arrays, so the
        indices never round-trip through ``np.asarray``. An unfiltered
        merged batch always splits into equal ``batch/num_shards`` segments
        (the merge layout guarantees it), so the shards' jitted write-backs
        see stable shapes and compile once.
        """
        if self.num_shards == 1:
            self.shards[0].write_back(indices, priorities,
                                      trace_id=trace_id)
            return
        slots, prios, counts = self._part(indices, priorities)
        off = 0
        for k, n in enumerate(np.asarray(counts).tolist()):
            if n:
                self.shards[k].write_back(slots[off:off + n],
                                          prios[off:off + n],
                                          trace_id=trace_id)
            off += n
