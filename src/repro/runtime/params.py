"""Versioned, lock-free parameter snapshot store.

The paper's actors "periodically request the latest network parameters"
(Alg. 1 l.2) — a one-way publish/subscribe, never a synchronization barrier.
Here the learner publishes an immutable ``(version, params)`` tuple; actors
grab whichever snapshot is current when their ``param_sync_period`` comes up.

Lock-freedom relies on two facts: (a) rebinding a single attribute is atomic
in CPython, so readers always observe a complete snapshot, never a torn one;
(b) snapshots are never mutated after publication — the learner's jitted
update produces fresh arrays each step, so a published pytree is frozen by
construction. Readers therefore need no lock, and a slow actor merely acts
with stale parameters — exactly the staleness the paper measures (Fig. 9).
"""

from __future__ import annotations

from typing import Any, NamedTuple


class ParamSnapshot(NamedTuple):
    version: int
    params: Any


class ParamStore:
    """Single-writer (learner) / many-reader (actors) snapshot store."""

    def __init__(self, params: Any, version: int = 0):
        # ``version`` seeds the counter when a run resumes from a snapshot:
        # actors compare versions monotonically, so a restarted learner must
        # not restart numbering from 0 or every cached pull looks fresh.
        self._snap = ParamSnapshot(version, params)

    def publish(self, params: Any) -> int:
        """Publish a new snapshot; returns its version. Single writer only —
        two concurrent publishers could skip a version number."""
        snap = ParamSnapshot(self._snap.version + 1, params)
        self._snap = snap  # atomic rebind: readers see old or new, never torn
        return snap.version

    def get(self) -> ParamSnapshot:
        """Latest snapshot (wait-free)."""
        return self._snap

    @property
    def version(self) -> int:
        return self._snap.version
