"""Shared actor inference server: K clients, one device dispatch.

The paper's FPS economics (§4.1) rest on actors being nearly free relative to
the learner — Ape-X runs 360 actors at ~1/139th of the learner's FPS each —
which in practice requires *batching* actor policy evaluation so the device
is dispatched once per wave of actors, not once per actor. Clients submit
their ``ActorSlice`` to a server thread that runs **one** jitted
``vmap(act_phase)`` call over the stacked slices (parameters broadcast),
then hands each client its own slice of the stacked results.

Two scheduling modes share the engine:

* ``mode="wave"`` — classic wave coalescing: after the first pending
  request the server waits up to ``coalesce_s`` for the rest of the wave,
  then pads short waves to ``max_batch`` by replicating the last request
  (one compiled shape forever; padding lanes recompute a duplicate rollout
  and are dropped). The padding tax is recorded honestly:
  ``inference/pad_fraction`` gauge plus ``inference/padded_lanes`` /
  ``inference/wave_lanes`` lifetime counters.
* ``mode="slots"`` — slot-scheduled continuous batching: no coalesce
  window. Pending requests are admitted from a deque into the compiled
  step's ``max_batch`` slots the moment the previous dispatch returns, and
  every slot is freed the step its request finishes (actor rollouts are
  one-step requests, so admission latency is the only scheduling variable
  — there is no batch-wide barrier for a straggler to stretch).
  ``inference/slot_occupancy`` gauges how full the step runs.

Semantics vs per-actor dispatch (both modes):

* Numerics are identical per actor — ``act_phase`` is pure and the vmap axis
  is the actor axis, so each actor's rollout uses its own rng/env state and
  its shard's slice of the exploration ladder. A full wave dispatches the
  exact same stacked content in either mode, so per-actor results are
  bit-identical between them (property-tested).
* Parameter staleness: wave mode refreshes its ``ParamStore`` snapshot
  every ``param_sync_period`` *dispatches* (a dispatch is one rollout per
  participating actor). Slot mode refreshes at every dispatch boundary —
  the hot-swap contract: a request finishes on the snapshot current when
  its dispatch was admitted, no request is ever dropped for a version
  change, and ``InferenceStats.hot_swaps`` counts the swaps taken.

Stop/error propagation is event-driven: a parked ``act()`` wakes the
instant ``stop()`` runs or the server thread dies — there is no poll
quantum between a failure and the client seeing it.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.obs import Telemetry
from repro.runtime import phases
from repro.runtime.params import ParamStore


@dataclasses.dataclass
class InferenceStats:
    requests: int = 0        # act() calls served
    dispatches: int = 0      # jitted batched calls issued
    full_waves: int = 0      # dispatches that batched max_batch requests
    param_refreshes: int = 0
    hot_swaps: int = 0       # slot mode: dispatch-boundary param swaps taken
                             # with requests in flight elsewhere (zero drops)


class _Request:
    __slots__ = ("aslice", "shard_id", "event", "result")

    def __init__(self, aslice: phases.ActorSlice, shard_id: int):
        self.aslice = aslice
        self.shard_id = shard_id
        self.event = threading.Event()
        self.result = None


class InferenceServer:
    """Batches ``act_phase`` across clients into one jitted call."""

    def __init__(self, cfg, env, agent, store: ParamStore, *,
                 max_batch: int, param_sync_period: int | None = None,
                 coalesce_s: float = 0.002, mode: str = "wave",
                 telemetry: Telemetry | None = None):
        if mode not in ("wave", "slots"):
            raise ValueError(
                f"InferenceServer mode must be 'wave' or 'slots', got "
                f"{mode!r}")
        self._cfg = cfg
        self._mode = mode
        self._tel = telemetry if telemetry is not None else Telemetry.local()
        # Wave *issue* latency (stack + jit dispatch, not synced — syncing
        # would serialize the pipeline this server exists to keep full)
        # and wave occupancy, for the obs report's inference row.
        self._h_wave = self._tel.histogram("inference/wave_us")
        self._g_wave = self._tel.gauge("inference/wave_size")
        # The padding tax, made visible (wave mode replicates the last
        # request into idle lanes): instantaneous fraction plus lifetime
        # lane counters so the report can state a run-wide pad fraction.
        self._g_pad = self._tel.gauge("inference/pad_fraction")
        self._g_occupancy = self._tel.gauge("inference/slot_occupancy")
        self._c_wave_lanes = self._tel.counter("inference/wave_lanes")
        self._c_padded = self._tel.counter("inference/padded_lanes")
        self._store = store
        self._max_batch = max_batch
        self._sync_period = (param_sync_period if param_sync_period is not None
                             else cfg.param_sync_period)
        self._coalesce_s = coalesce_s
        self._snap = store.get()

        def batched(params, slices, sids):
            return jax.vmap(lambda sl, sid: phases.act_phase(
                cfg, env, agent, params, sl, sid))(slices, sids)

        self._fn = jax.jit(batched)

        self._pending: collections.deque[_Request] = collections.deque()
        self._cond = threading.Condition()
        self._stop = threading.Event()
        self._stats_lock = threading.Lock()
        self.stats = InferenceStats()
        self.error: BaseException | None = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="inference-server")

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "InferenceServer":
        self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        with self._cond:
            # Wake parked clients directly: their requests will never be
            # taken, and act() must not sit out a poll quantum to notice.
            for req in self._pending:
                req.event.set()
            self._pending.clear()
            self._cond.notify_all()
        if join and self._thread.is_alive():
            self._thread.join()

    def warm(self, aslice: phases.ActorSlice) -> int:
        """Compile the full-wave batched call before the clock starts;
        returns the measured per-actor transitions-per-block."""
        slices = jax.tree.map(
            lambda x: jnp.stack([x] * self._max_batch), aslice)
        sids = jnp.arange(self._max_batch, dtype=jnp.int32)
        _, blocks, _ = jax.block_until_ready(
            self._fn(self._snap.params, slices, sids))
        return int(blocks.priorities.shape[1])

    def snapshot(self) -> InferenceStats:
        with self._stats_lock:
            return dataclasses.replace(self.stats)

    # -- client side ---------------------------------------------------------

    def act(self, aslice: phases.ActorSlice, shard_id: int,
            ) -> tuple[phases.ActorSlice, phases.TransitionBlock, dict] | None:
        """Submit one rollout request and wait for its slice of the batched
        result. Returns None when the server (or runtime) is stopping."""
        req = _Request(aslice, shard_id)
        with self._cond:
            # Registration and the stop/error check share the lock, so a
            # request is either appended while the server is live (stop()
            # or the death path will wake it) or refused here — it can
            # never slip into a queue nobody will drain.
            if self.error is not None:
                raise RuntimeError("inference server died") from self.error
            if self._stop.is_set():
                return None
            self._pending.append(req)
            self._cond.notify_all()
        req.event.wait()
        if req.result is None:
            if self.error is not None:
                raise RuntimeError("inference server died") from self.error
            return None  # stopped before (or during) this request's dispatch
        return req.result

    # -- server loop --------------------------------------------------------

    def _take_wave(self) -> list[_Request]:
        with self._cond:
            while not self._pending and not self._stop.is_set():
                self._cond.wait(timeout=0.05)
            if self._stop.is_set():
                return []
            if self._mode == "wave":
                # Coalesce: wait out the window for the rest of the wave.
                deadline = time.monotonic() + self._coalesce_s
                while (len(self._pending) < self._max_batch
                       and not self._stop.is_set()):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
            # Slot admission: whatever is pending right now fills free
            # slots, nothing waits for stragglers.
            wave = [self._pending.popleft()
                    for _ in range(min(len(self._pending), self._max_batch))]
            return wave

    def _run(self) -> None:
        try:
            while not self._stop.is_set():
                wave = self._take_wave()
                if not wave:
                    continue
                self._dispatch(wave)
        except BaseException as e:  # noqa: BLE001
            self.error = e
        finally:
            with self._cond:  # unblock any client still parked on a request
                for req in self._pending:
                    req.event.set()
                self._pending.clear()

    def _refresh_params(self) -> None:
        """Dispatch-boundary snapshot policy (caller holds _stats_lock).
        Wave mode: every ``param_sync_period`` dispatches. Slot mode: every
        dispatch — requests admitted into this dispatch complete on the
        snapshot taken here, so a version change never drops an in-flight
        request; it just bounds staleness at one dispatch."""
        if self._mode == "slots":
            snap = self._store.get()
            if snap.version != self._snap.version:
                self._snap = snap
                self.stats.param_refreshes += 1
                self.stats.hot_swaps += 1
        elif self.stats.dispatches % self._sync_period == 0:
            self._snap = self._store.get()
            self.stats.param_refreshes += 1

    def _dispatch(self, wave: list[_Request]) -> None:
        with self._stats_lock:
            self._refresh_params()
            self.stats.dispatches += 1
            self.stats.requests += len(wave)
            self.stats.full_waves += int(len(wave) == self._max_batch)
        try:
            # Pad short waves to max_batch by replicating the last request:
            # one compiled shape forever instead of one trace per wave size
            # (padding lanes recompute a duplicate rollout and are dropped
            # — counted below so the tax is visible in the obs report).
            pad = self._max_batch - len(wave)
            reqs = wave + [wave[-1]] * pad
            self._g_pad.set(pad / self._max_batch)
            self._g_occupancy.set(len(wave) / self._max_batch)
            self._c_wave_lanes.inc(self._max_batch)
            self._c_padded.inc(pad)
            t0 = time.perf_counter()
            slices = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[r.aslice for r in reqs])
            sids = jnp.asarray([r.shard_id for r in reqs], jnp.int32)
            out = self._fn(self._snap.params, slices, sids)
            self._h_wave.record(1e6 * (time.perf_counter() - t0))
            self._g_wave.set(len(wave))
            for i, req in enumerate(wave):
                req.result = jax.tree.map(lambda x: x[i], out)
        except BaseException as e:  # noqa: BLE001
            self.error = e  # recorded *before* clients wake, so act() raises
            raise
        finally:
            # Whatever failed above, a taken wave must never park its
            # clients forever: wake them (result stays None; act()
            # re-raises).
            for req in wave:
                req.event.set()
