"""Shared actor inference server: K actor threads, one device dispatch.

The paper's FPS economics (§4.1) rest on actors being nearly free relative to
the learner — Ape-X runs 360 actors at ~1/139th of the learner's FPS each —
which in practice requires *batching* actor policy evaluation so the device
is dispatched once per wave of actors, not once per actor. Here actor threads
submit their ``ActorSlice`` to a server thread that coalesces pending
requests and runs **one** jitted ``vmap(act_phase)`` call over the stacked
slices (parameters broadcast), then hands each actor its own slice of the
stacked results.

Semantics vs per-actor dispatch:

* Numerics are identical per actor — ``act_phase`` is pure and the vmap axis
  is the actor axis, so each actor's rollout uses its own rng/env state and
  its shard's slice of the exploration ladder.
* Parameter staleness is unified: the server refreshes its ``ParamStore``
  snapshot every ``param_sync_period`` *dispatches* (a dispatch is one
  rollout per participating actor), replacing the per-actor refresh clock.
* Coalescing waits up to ``coalesce_s`` after the first pending request for
  the rest of the wave; in steady state all actors block on results and
  resubmit together, so full waves form naturally.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.obs import Telemetry
from repro.runtime import phases
from repro.runtime.params import ParamStore


@dataclasses.dataclass
class InferenceStats:
    requests: int = 0        # act() calls served
    dispatches: int = 0      # jitted batched calls issued
    full_waves: int = 0      # dispatches that batched max_batch requests
    param_refreshes: int = 0


class _Request:
    __slots__ = ("aslice", "shard_id", "event", "result")

    def __init__(self, aslice: phases.ActorSlice, shard_id: int):
        self.aslice = aslice
        self.shard_id = shard_id
        self.event = threading.Event()
        self.result = None


class InferenceServer:
    """Batches ``act_phase`` across actor threads into one jitted call."""

    def __init__(self, cfg, env, agent, store: ParamStore, *,
                 max_batch: int, param_sync_period: int | None = None,
                 coalesce_s: float = 0.002,
                 telemetry: Telemetry | None = None):
        self._cfg = cfg
        self._tel = telemetry if telemetry is not None else Telemetry.local()
        # Wave *issue* latency (stack + jit dispatch, not synced — syncing
        # would serialize the pipeline this server exists to keep full)
        # and wave occupancy, for the obs report's inference row.
        self._h_wave = self._tel.histogram("inference/wave_us")
        self._g_wave = self._tel.gauge("inference/wave_size")
        self._store = store
        self._max_batch = max_batch
        self._sync_period = (param_sync_period if param_sync_period is not None
                             else cfg.param_sync_period)
        self._coalesce_s = coalesce_s
        self._snap = store.get()

        def batched(params, slices, sids):
            return jax.vmap(lambda sl, sid: phases.act_phase(
                cfg, env, agent, params, sl, sid))(slices, sids)

        self._fn = jax.jit(batched)

        self._pending: list[_Request] = []
        self._cond = threading.Condition()
        self._stop = threading.Event()
        self._stats_lock = threading.Lock()
        self.stats = InferenceStats()
        self.error: BaseException | None = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="inference-server")

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "InferenceServer":
        self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if join and self._thread.is_alive():
            self._thread.join()

    def warm(self, aslice: phases.ActorSlice) -> int:
        """Compile the full-wave batched call before the clock starts;
        returns the measured per-actor transitions-per-block."""
        slices = jax.tree.map(
            lambda x: jnp.stack([x] * self._max_batch), aslice)
        sids = jnp.arange(self._max_batch, dtype=jnp.int32)
        _, blocks, _ = jax.block_until_ready(
            self._fn(self._snap.params, slices, sids))
        return int(blocks.priorities.shape[1])

    def snapshot(self) -> InferenceStats:
        with self._stats_lock:
            return dataclasses.replace(self.stats)

    # -- actor side ---------------------------------------------------------

    def act(self, aslice: phases.ActorSlice, shard_id: int,
            ) -> tuple[phases.ActorSlice, phases.TransitionBlock, dict] | None:
        """Submit one rollout request and wait for its slice of the batched
        result. Returns None when the server (or runtime) is stopping."""
        if self.error is not None:
            raise RuntimeError("inference server died") from self.error
        req = _Request(aslice, shard_id)
        with self._cond:
            self._pending.append(req)
            self._cond.notify_all()
        while not req.event.wait(timeout=0.05):
            if self.error is not None:
                raise RuntimeError("inference server died") from self.error
            if self._stop.is_set():
                return None
        if req.result is None:
            if self.error is not None:
                raise RuntimeError("inference server died") from self.error
            return None  # stopped mid-dispatch
        return req.result

    # -- server loop --------------------------------------------------------

    def _take_wave(self) -> list[_Request]:
        with self._cond:
            while not self._pending and not self._stop.is_set():
                self._cond.wait(timeout=0.05)
            if self._stop.is_set():
                return []
            deadline = time.monotonic() + self._coalesce_s
            while len(self._pending) < self._max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(timeout=remaining)
            wave = self._pending[:self._max_batch]
            del self._pending[:len(wave)]
            return wave

    def _run(self) -> None:
        try:
            while not self._stop.is_set():
                wave = self._take_wave()
                if not wave:
                    continue
                self._dispatch(wave)
        except BaseException as e:  # noqa: BLE001
            self.error = e
        finally:
            with self._cond:  # unblock any actor still parked on a request
                for req in self._pending:
                    req.event.set()
                self._pending.clear()

    def _dispatch(self, wave: list[_Request]) -> None:
        with self._stats_lock:
            if self.stats.dispatches % self._sync_period == 0:
                self._snap = self._store.get()
                self.stats.param_refreshes += 1
            self.stats.dispatches += 1
            self.stats.requests += len(wave)
            self.stats.full_waves += int(len(wave) == self._max_batch)
        try:
            # Pad short waves to max_batch by replicating the last request:
            # one compiled shape forever instead of one trace per wave size
            # (padding lanes recompute a duplicate rollout and are dropped).
            pad = self._max_batch - len(wave)
            reqs = wave + [wave[-1]] * pad
            t0 = time.perf_counter()
            slices = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[r.aslice for r in reqs])
            sids = jnp.asarray([r.shard_id for r in reqs], jnp.int32)
            out = self._fn(self._snap.params, slices, sids)
            self._h_wave.record(1e6 * (time.perf_counter() - t0))
            self._g_wave.set(len(wave))
            for i, req in enumerate(wave):
                req.result = jax.tree.map(lambda x: x[i], out)
        except BaseException as e:  # noqa: BLE001
            self.error = e  # recorded *before* actors wake, so act() raises
            raise
        finally:
            # Whatever failed above, a taken wave must never park its actors
            # forever: wake them (result stays None; act() re-raises).
            for req in wave:
                req.event.set()
