"""Pure per-phase functions shared by the synchronous driver and the async
runtime.

The paper decouples acting from learning (§3): actors generate experience at
their own pace, the learner consumes prioritized samples at its own pace, and
the replay memory sits between them. To make that decoupling real in code,
the Ape-X iteration is split here into four pure, independently jittable
functions:

* ``act_phase``        — roll out T env steps per lane and emit a
                         ``TransitionBlock`` (items + actor-side initial
                         priorities). Touches no replay state.
* ``replay_add``       — insert a block into a replay shard (FIFO or
                         alloc-into-free-slots, per config).
* ``learn_phase``      — one prioritized update from an already-sampled
                         batch: loss/grads, optimizer step, periodic target
                         sync. Returns fresh priorities; touches no replay
                         state.
* ``priority_writeback`` — write learner priorities back into the replay
                         shard and run the periodic eviction policy.

``repro.core.apex`` composes them bulk-synchronously inside one jitted step;
``repro.runtime.runner`` composes them across actor / replay-service /
learner threads. Both paths share these exact functions, so the async
runtime's numerics per phase match the lockstep driver's.

``cfg`` everywhere is an ``apex.ApexConfig`` (accessed structurally to avoid
an import cycle with ``repro.core.apex``).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import codec, nstep, priority as prio, replay as replay_lib
from repro.envs.synthetic import batch_reset, batch_step
from repro.optim import optimizers as optim


class ActorSlice(NamedTuple):
    """Per-actor mutable state: everything an actor thread owns exclusively."""
    env_state: Any
    obs: jax.Array             # (lanes, ...)
    ep_return: jax.Array       # (lanes,) running episode return
    rng: jax.Array
    frames: jax.Array          # env steps taken by this slice


class TransitionBlock(NamedTuple):
    """A flat block of n-step transitions plus actor-computed priorities —
    the unit of actor → replay traffic (paper Alg. 1 l.10-11, batched)."""
    items: Any                 # pytree of (B, ...) arrays
    priorities: jax.Array      # (B,)


class LearnerSlice(NamedTuple):
    """Learner-owned state: online/target params, optimizer, step count."""
    params: Any
    target_params: Any
    opt_state: Any
    learner_step: jax.Array


def lane_epsilons(cfg, shard_id: jax.Array) -> jax.Array:
    """This shard's slice of the global exploration ladder (paper §3)."""
    if cfg.eps_mode == "ladder":
        table = prio.epsilon_ladder(cfg.num_actors, cfg.eps_base, cfg.eps_alpha)
    elif cfg.eps_mode == "fixed_set":
        table = prio.fixed_epsilon_set(cfg.num_actors)
    else:
        raise ValueError(cfg.eps_mode)
    gids = shard_id * cfg.lanes_per_shard + jnp.arange(cfg.lanes_per_shard)
    return table[gids]


def item_example(env, obs: jax.Array, compress: bool = False) -> dict:
    """Replay item layout: the paper stores both endpoint states per
    transition ("costs more RAM, but simplifies the code" — Appendix F)."""
    ob = obs[0]
    if compress:
        ob = codec.encode(ob[None])._asdict()
        ob = {k: v[0] for k, v in ob.items()}
    if hasattr(env, "num_actions"):
        action = jnp.zeros((), jnp.int32)
    else:
        action = jnp.zeros((env.action_dim,), jnp.float32)
    return {
        "obs": ob, "action": action,
        "returns": jnp.zeros((), jnp.float32),
        "discount_n": jnp.zeros((), jnp.float32),
        "next_obs": ob,
    }


def initial_actor_slice(cfg, env, seed: int, actor_id: int) -> ActorSlice:
    """The canonical starting slice for global actor ``actor_id`` of a run
    seeded with ``seed``. Every actor host derives its slice through this
    one function — runner threads and remote actor processes alike — so the
    exploration ladder cannot fork across the process boundary."""
    _, e_rng = jax.random.split(jax.random.key(seed))
    a_rng = jax.random.fold_in(e_rng, actor_id)
    env_state, obs = batch_reset(env, a_rng, cfg.lanes_per_shard)
    return ActorSlice(
        env_state=env_state, obs=obs,
        ep_return=jnp.zeros((cfg.lanes_per_shard,), jnp.float32),
        rng=jax.random.fold_in(a_rng, 1),
        frames=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Act phase
# ---------------------------------------------------------------------------

def act_phase(cfg, env, agent, actor_params: Any, aslice: ActorSlice,
              shard_id: jax.Array | int = 0,
              ) -> tuple[ActorSlice, TransitionBlock, dict]:
    """Roll out T steps per lane, build n-step transitions from the
    trajectory, and compute initial priorities from the buffered Q-values
    (Alg. 1, vectorized). Pure: emits a ``TransitionBlock`` instead of
    writing to replay, so actors need no access to the replay shard."""
    eps = lane_epsilons(cfg, jnp.asarray(shard_id))
    rng, rollout_rng, last_rng = jax.random.split(aslice.rng, 3)
    step_rngs = jax.random.split(rollout_rng, cfg.rollout_len)

    def step_fn(carry, rng_t):
        env_state, obs, ep_ret = carry
        a, aux = agent.act(actor_params, rng_t, obs, eps)
        env_state, out = batch_step(env, env_state, a)
        done = out.discount == 0.0
        ep_ret_next = jnp.where(done, 0.0, ep_ret + out.reward)
        completed = jnp.where(done, ep_ret + out.reward, jnp.nan)
        emit = dict(obs=obs, action=a, aux=aux, reward=out.reward,
                    discount=out.discount, completed=completed)
        return (env_state, out.obs, ep_ret_next), emit

    (env_state, last_obs, ep_ret), traj = jax.lax.scan(
        step_fn, (aslice.env_state, aslice.obs, aslice.ep_return), step_rngs)
    # time-major (T, lanes, ...) -> lane-major (lanes, T, ...)
    traj = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), traj)

    # Bootstrap aux at the final state S_T (one extra policy eval).
    _, last_aux = agent.act(actor_params, last_rng, last_obs, eps)

    n, W = cfg.n_step, cfg.window
    returns, discount_n = nstep.from_trajectory(traj["reward"], traj["discount"], n)

    full_obs = jnp.concatenate([traj["obs"], last_obs[:, None]], axis=1)  # (lanes, T+1, ...)
    full_aux = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b[:, None]], axis=1), traj["aux"], last_aux)

    first_aux = jax.tree.map(lambda x: x[:, :W], full_aux)
    last_aux_w = jax.tree.map(lambda x: x[:, n:], full_aux)
    action_w = traj["action"][:, :W]
    priorities = agent.initial_priorities(
        *jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                      (first_aux, action_w, returns, discount_n, last_aux_w)))

    flat = lambda x: x.reshape((-1,) + x.shape[2:])
    enc = ((lambda o: dict(codec.encode(o)._asdict())) if cfg.compress_obs
           else (lambda o: o))
    items = {
        "obs": enc(flat(full_obs[:, :W])),
        "action": flat(action_w),
        "returns": flat(returns),
        "discount_n": flat(discount_n),
        "next_obs": enc(flat(full_obs[:, n:])),
    }
    if cfg.replicate_k > 1:  # Fig. 6 recency-vs-diversity ablation
        items = jax.tree.map(
            lambda x: jnp.tile(x, (cfg.replicate_k,) + (1,) * (x.ndim - 1)), items)
        priorities = jnp.tile(priorities, cfg.replicate_k)

    completed = traj["completed"]
    n_done = jnp.sum(~jnp.isnan(completed))
    mean_ep_return = jnp.where(
        n_done > 0, jnp.nansum(completed) / jnp.maximum(n_done, 1), jnp.nan)
    metrics = {"mean_ep_return": mean_ep_return, "episodes": n_done,
               "mean_initial_priority": priorities.mean()}

    aslice = ActorSlice(
        env_state=env_state, obs=last_obs, ep_return=ep_ret, rng=rng,
        frames=aslice.frames + cfg.lanes_per_shard * cfg.rollout_len)
    return aslice, TransitionBlock(items, priorities), metrics


def learner_batch_example(cfg, item: Any) -> tuple[Any, jax.Array]:
    """Storage-shaped garbage ``(items, is_weights)`` at the learner batch
    size — the canonical input for warming ``learn_phase`` jit caches before
    a clock starts (the runner and the sample-plane benches share it so the
    warm-up cannot drift from the real batch layout)."""
    items = jax.tree.map(
        lambda a: jnp.zeros((cfg.batch_size,) + jnp.shape(a),
                            jnp.asarray(a).dtype), item)
    return items, jnp.ones((cfg.batch_size,), jnp.float32)


def replay_add(cfg, replay_state: replay_lib.ReplayState,
               block: TransitionBlock) -> replay_lib.ReplayState:
    """Insert a transition block into a replay shard (the replay side of
    Alg. 1 l.10-11): circular FIFO for the Atari regime, alloc-into-free
    slots for the DPG/prioritized-eviction regime."""
    add = replay_lib.add_fifo if cfg.eviction == "fifo" else replay_lib.add_alloc
    return add(cfg.replay, replay_state, block.items, block.priorities)


# ---------------------------------------------------------------------------
# Learn phase
# ---------------------------------------------------------------------------

def learn_phase(cfg, agent, optimizer, lslice: LearnerSlice, items: Any,
                weights: jax.Array, axis_name: str | None = None,
                ) -> tuple[LearnerSlice, jax.Array, dict]:
    """One prioritized update from an already-sampled batch (Alg. 2 l.5-7):
    decode, loss/grads, optimizer step, periodic target sync. Returns the
    fresh |TD| priorities for write-back; touches no replay state."""
    if cfg.compress_obs:  # decode fuses into the learner forward
        items = dict(items)
        items["obs"] = codec.decode(codec.EncodedObs(**items["obs"]))
        items["next_obs"] = codec.decode(codec.EncodedObs(**items["next_obs"]))
    params, opt_state, new_prios, metrics = agent.update(
        lslice.params, lslice.target_params, lslice.opt_state, optimizer,
        items, weights, axis_name)
    step = lslice.learner_step + 1
    target = optim.periodic_target_update(
        params, lslice.target_params, step, cfg.target_update_period)
    lslice = LearnerSlice(params=params, target_params=target,
                          opt_state=opt_state, learner_step=step)
    return lslice, new_prios, metrics


def priority_writeback(cfg, replay_state: replay_lib.ReplayState,
                       indices: jax.Array, priorities: jax.Array,
                       learner_step: jax.Array, rng: jax.Array,
                       ) -> replay_lib.ReplayState:
    """Write fresh learner priorities back into the shard (Alg. 2 l.8) and
    run the periodic eviction policy (paper: every 100 learning steps).
    ``learner_step`` is the post-update step count."""
    rcfg = cfg.replay
    rep = replay_lib.set_priorities(rcfg, replay_state, indices, priorities)
    if cfg.eviction == "fifo":
        rep = jax.lax.cond(
            learner_step % cfg.evict_interval == 0,
            lambda r: replay_lib.evict_fifo(rcfg, r), lambda r: r, rep)
    else:
        evict_num = cfg.evict_num or cfg.batch_size
        rep = jax.lax.cond(
            (learner_step % cfg.evict_interval == 0) & (rep.size > rcfg.soft_cap),
            lambda r: replay_lib.evict_prioritized(rcfg, r, rng, evict_num),
            lambda r: r, rep)
    return rep
