"""Fault-injection harness for the async runtime (the chaos plane).

The paper's fault model (Appendix F) is concrete: actors are pure
functions of ``(seed, actor_id)`` + the latest parameters and may die and
restart at will; replay and learner state checkpoint periodically;
priority updates are idempotent last-writer-wins, so re-sent frames after
a reconnect are harmless. This module turns each of those claims into an
injectable fault against a *live* ``run_async``:

* :func:`kill_actor_proc` — SIGKILL an actor process mid-stream; the
  runner's supervisor must respawn it (capped exponential backoff).
* :func:`sever_gateway_transports` — hard-shutdown the gateway side of
  every live connection mid-frame; remote actors and the remote learner
  source must reconnect, re-handshake, and resume.
* :func:`sever_source_transport` — the client-side mirror: tear the
  learner's ``RemoteFabricSource`` socket out from under it.
* :func:`freeze_shard` — pause a shard owner thread for a while (a stalled
  worker, not a dead one); backpressure must hold and the run complete.
* :func:`kill_shard_owner` — poison a shard's add queue so the owner
  thread dies; the runtime must *fail loudly* (a dead shard is state loss,
  the one fault the plane does not absorb).

A :class:`ChaosMonkey` schedules a plan of timed faults and plugs into
``run_async(..., on_handles=monkey.on_handles)``; faults fire on their own
thread once every plane is up. Reaching into ``RuntimeHandles`` internals
(process objects, gateway connection registry, raw sockets) is the point:
the harness breaks the runtime the way the world would, below every API.
"""

from __future__ import annotations

import dataclasses
import socket
import threading
import time
from typing import Any, Callable, Sequence

from repro.obs import log as obslog


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: ``apply(handles)`` fires ``at_s`` seconds
    after the runtime hands over its internals."""

    at_s: float
    name: str
    apply: Callable[[Any], None]


# -- fault factories --------------------------------------------------------

def kill_actor_proc(at_s: float, slot: int = 0) -> Fault:
    """SIGKILL actor process ``slot`` (no cleanup, no BYE — the real
    crash). The supervisor owns the respawn."""
    def apply(h: Any) -> None:
        with h.procs_lock:
            p = h.procs[slot]
        p.kill()
        p.join(timeout=10.0)
    return Fault(at_s, f"kill_actor_proc[{slot}]", apply)


def _sever(conn: Any) -> bool:
    """Hard-shutdown a Transport's underlying socket (both directions, no
    FIN handshake semantics the peer could mistake for a clean close — the
    next read/write on either side raises). An shm-upgraded transport is
    severed at its doorbell socket, which its ring protocol treats the
    same as a torn TCP stream."""
    t = getattr(conn, "_shm", None) or conn
    sock = getattr(t, "_sock", None)
    if sock is None:
        return False
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass  # already dead — severed either way
    return True


def sever_gateway_transports(at_s: float) -> Fault:
    """Shutdown the gateway side of every live client connection (actors
    and/or a remote learner) mid-whatever-frame-was-in-flight."""
    def apply(h: Any) -> None:
        with h.gateway._lock:
            conns = list(h.gateway._conns.values())
        severed = sum(_sever(c) for c in conns)
        obslog.emit("chaos-severed", side="gateway", conns=severed)
    return Fault(at_s, "sever_gateway_transports", apply)


def sever_source_transport(at_s: float) -> Fault:
    """Shutdown the learner-side socket of the run's ``SampleSource``
    (``RemoteFabricSource``, possibly wrapped in ``StagedSource``)."""
    def apply(h: Any) -> None:
        src = h.source
        src = getattr(src, "_inner", src)  # unwrap StagedSource
        _sever(getattr(src, "_conn", None))
    return Fault(at_s, "sever_source_transport", apply)


def freeze_shard(at_s: float, shard: int = 0, for_s: float = 0.5) -> Fault:
    """Pause shard ``shard``'s owner thread for ``for_s`` seconds: adds and
    write-backs pile up in its bounded queues (backpressure), then drain.
    The fault thread itself waits out the freeze."""
    def apply(h: Any) -> None:
        sh = h.fabric.shards[shard]
        sh.pause()
        try:
            time.sleep(for_s)
        finally:
            sh.unpause()
    return Fault(at_s, f"freeze_shard[{shard}]", apply)


class _Poison:
    """Not a TransitionBlock: the shard owner's dispatch chokes on it."""

    def __getattr__(self, name: str) -> Any:
        raise RuntimeError("chaos: poisoned shard add queue")


def kill_shard_owner(at_s: float, shard: int = 0) -> Fault:
    """Feed a shard's add queue an object its owner thread cannot digest.
    Replay state is storage — a dead shard must FAIL the run (the runtime
    absorbs actor and transport loss, never silent state loss)."""
    def apply(h: Any) -> None:
        h.fabric.shards[shard]._add_q.put((_Poison(), 0))
    return Fault(at_s, f"kill_shard_owner[{shard}]", apply)


# -- the monkey -------------------------------------------------------------

class ChaosMonkey:
    """Applies a plan of timed :class:`Fault`\\ s to a live runtime.

    Usage::

        monkey = ChaosMonkey([kill_actor_proc(0.5), kill_actor_proc(1.5)])
        result = run_async(cfg, acfg, env, agent, opt,
                           on_handles=monkey.on_handles)
        monkey.join()
        assert monkey.applied and not monkey.errors

    The clock starts when ``run_async`` hands over its handles (every
    plane already up), so ``at_s`` measures into the *steady* run. A fault
    raising is recorded in ``errors``, never propagated into the runtime.
    The plan stops early when the run does.
    """

    def __init__(self, plan: Sequence[Fault]):
        self.plan = sorted(plan, key=lambda f: f.at_s)
        self.applied: list[str] = []
        self.errors: list[tuple[str, BaseException]] = []
        self._handles: Any = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="chaos-monkey")

    def on_handles(self, handles: Any) -> None:
        """The ``run_async(on_handles=...)`` hook: arms the plan."""
        self._handles = handles
        self._thread.start()

    def join(self, timeout: float | None = 30.0) -> None:
        if self._thread.ident is not None:
            self._thread.join(timeout=timeout)

    def _run(self) -> None:
        h = self._handles
        t0 = time.monotonic()
        for fault in self.plan:
            delay = t0 + fault.at_s - time.monotonic()
            if delay > 0 and h.stop.wait(timeout=delay):
                return  # run ended before this fault's time came
            if h.stop.is_set():
                return
            obslog.emit("chaos", fault=fault.name, at_s=fault.at_s)
            try:
                fault.apply(h)
                self.applied.append(fault.name)
            except BaseException as e:  # noqa: BLE001 — never hurt the run
                self.errors.append((fault.name, e))
