"""Test-support plane: fault injection for the async runtime.

``repro.testing.chaos`` drives a live ``run_async`` through failures —
killed actor processes, severed transports, frozen/killed shard owners —
via the ``RuntimeHandles`` hook, so the fault-tolerance claims (supervised
restarts, reconnecting transports, snapshot/resume) are *tested* behavior,
not documentation.
"""

from repro.testing.chaos import (ChaosMonkey, Fault, freeze_shard,
                                 kill_actor_proc, kill_shard_owner,
                                 sever_gateway_transports,
                                 sever_source_transport)

__all__ = [
    "ChaosMonkey", "Fault", "freeze_shard", "kill_actor_proc",
    "kill_shard_owner", "sever_gateway_transports",
    "sever_source_transport",
]
