"""Optimizers, gradient clipping and target-network machinery — from scratch.

The paper uses Centered RMSProp (lr 0.00025/4, decay 0.95, eps 1.5e-7, no
momentum, grad-norm clip 40) for Ape-X DQN (Appendix C) and Adam (lr 1e-4)
for Ape-X DPG (Appendix D). The LLM-scale sequence-replay configs use AdamW.

API mirrors the usual GradientTransformation pair: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; apply with
:func:`apply_updates`. All transforms are pure pytree maps, so they shard
exactly like the parameters (FSDP over ``data``, TP over ``model``).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Any:
    """Paper Appendix C: gradient norms are clipped to 40."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# Centered RMSProp (Appendix C).
# ---------------------------------------------------------------------------

class RMSPropState(NamedTuple):
    mean_sq: Any
    mean: Any


def centered_rmsprop(
    learning_rate: float = 0.00025 / 4,
    decay: float = 0.95,
    eps: float = 1.5e-7,
) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return RMSPropState(mean_sq=z, mean=jax.tree.map(jnp.copy, z))

    def update(grads, state, params=None):
        del params
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mean_sq = jax.tree.map(lambda ms, g: decay * ms + (1 - decay) * g * g,
                               state.mean_sq, g32)
        mean = jax.tree.map(lambda m, g: decay * m + (1 - decay) * g,
                            state.mean, g32)
        updates = jax.tree.map(
            lambda g, ms, m: -learning_rate * g / jnp.sqrt(ms - m * m + eps),
            g32, mean_sq, mean,
        )
        return updates, RMSPropState(mean_sq, mean)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW.
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam(
    learning_rate: float = 1e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=z,
                         nu=jax.tree.map(jnp.copy, z))

    def update(grads, state, params=None):
        step = state.step + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -learning_rate * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - learning_rate * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay and params is not None:
            updates = jax.tree.map(upd, mu, nu, params)
        else:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(learning_rate: float = 3e-4, weight_decay: float = 0.1, **kw) -> Optimizer:
    return adam(learning_rate=learning_rate, weight_decay=weight_decay, **kw)


# ---------------------------------------------------------------------------
# Target networks (slow-moving copies; Appendix C: copy every 2500 batches,
# Appendix D: every 100 batches).
# ---------------------------------------------------------------------------

def periodic_target_update(params: Any, target_params: Any, step: jax.Array,
                           period: int) -> Any:
    """Hard copy every ``period`` learner steps, identity otherwise."""
    do_copy = (step % period) == 0
    return jax.tree.map(
        lambda p, t: jnp.where(do_copy, p.astype(t.dtype), t), params, target_params
    )
