import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent without TPUs.

For every (architecture x input shape) the appropriate step function is
lowered and compiled against the production mesh with ShapeDtypeStruct
stand-ins (no allocation):

  train_4k     -> train_step  (prioritized learner update)
  prefill_32k  -> score_step  (actor-side priority computation)
  decode_*     -> serve_step  (one token vs a seq_len cache)

Per combo it prints/records ``compiled.memory_analysis()`` (fits check),
``compiled.cost_analysis()`` (FLOPs/bytes for the roofline) and the
collective traffic parsed from the partitioned HLO; artifacts land in
``benchmarks/artifacts/`` for ``benchmarks/roofline.py``.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, shape_applicable
from repro.launch import (hlo_analysis, mesh as mesh_lib,
                          sharding as shard_lib, steps as steps_lib)
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, data_axes,
                               make_production_mesh, num_chips)
from repro.models import registry, transformer
from repro.optim import optimizers as optim

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")


def cost_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a one-element list of dicts, newer ones a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def probe_flops_scope(mesh) -> str:
    """Decide whether cost_analysis() reports global or per-device FLOPs by
    compiling a known matmul (2*M*K*N flops) sharded over the mesh."""
    M = K = N = 1024
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    D = data_axes(mesh)
    sa = jax.NamedSharding(mesh, jax.sharding.PartitionSpec(D, None))
    sb = jax.NamedSharding(mesh, jax.sharding.PartitionSpec(None, "model"))
    compiled = jax.jit(lambda a, b: a @ b, in_shardings=(sa, sb)).lower(a, b).compile()
    flops = float(cost_dict(compiled).get("flops", 0.0))
    expected_global = 2.0 * M * K * N
    return "global" if flops > expected_global / 2 else "per_device"


def active_param_count(cfg, param_shapes) -> tuple[int, int]:
    """(total, active) parameter counts; routed-expert tensors scale by
    top_k / num_experts."""
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.moe is not None and len(leaf.shape) == 4:
            active += n * cfg.moe.top_k // cfg.moe.num_experts
        else:
            active += n
    return total, active


def model_flops(cfg, shape, active_params: int) -> float:
    """Analytic 'useful' FLOPs: 6*N*D train, 2*N*D prefill, 2*N*B decode."""
    if shape.kind == "train":
        return 6.0 * active_params * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * active_params * shape.seq_len * shape.global_batch
    return 2.0 * active_params * shape.global_batch  # one token per seq


def probe_layer_counts(cfg) -> tuple[int, int]:
    """(k, 2k) layer counts for the cost-extrapolation probes. k respects the
    arch's layer-group period (Zamba2: one shared-attn call per 6 layers)."""
    k = cfg.shared_attn_every or 2
    k = min(k, cfg.n_layers)
    return k, min(2 * k, cfg.n_layers)


def build_lowered(cfg, shape, mesh, probe_layers: int | None = None,
                  overrides: dict | None = None):
    """Lower the right step for (cfg, shape) against the mesh.

    Two flavors (DESIGN.md dry-run methodology):
    * full (probe_layers=None): the production path — scan-over-layers,
      chunked attention, per-layer remat for training, sharding constraints.
      This is the compile/fits proof; XLA's cost analysis counts while-loop
      bodies once, so its FLOPs/collectives are NOT used for the roofline.
    * probe (probe_layers=k): a k-layer UNROLLED variant with the attention
      KV loop unrolled too — exact instruction-level accounting. Costs are
      linearly extrapolated from the (k, 2k) probes: per-layer = (c2k-ck)/k,
      fixed (embed/head/loss) = ck - k*per-layer.
    """
    D = data_axes(mesh)
    if probe_layers is None:
        cfg = dataclasses.replace(
            cfg, attn_impl="chunked", scan_layers=True,
            remat=(shape.kind == "train"),
            act_sharding=(D, None, "model"))
    else:
        cfg = dataclasses.replace(
            cfg, n_layers=probe_layers,
            attn_impl="chunked", scan_layers=False, attn_unroll=True,
            remat=(shape.kind == "train"),
            act_sharding=(D, None, "model"))
    if overrides:
        ov = dict(overrides)
        if ov.get("act_sharding") == "data_only":
            ov["act_sharding"] = (D, None, None)
        elif ov.get("act_sharding") == "seq":
            ov["act_sharding"] = (D, "model", None)
        if "moe_groups" in ov:
            g = ov.pop("moe_groups")
            if cfg.moe is not None:
                ov["moe"] = dataclasses.replace(cfg.moe, dispatch_groups=g)
        elif ov.get("act_sharding") == "model":
            ov["act_sharding"] = (D, None, "model")
        cfg = dataclasses.replace(cfg, **ov)
    param_shapes = jax.eval_shape(lambda: transformer.init(cfg, jax.random.key(0)))
    p_shard = shard_lib.param_shardings(param_shapes, mesh)
    rep = shard_lib.replicated(mesh)

    if shape.kind == "train":
        optimizer = optim.adamw(3e-4)
        opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
        o_shard = _opt_shardings(optimizer, param_shapes, p_shard, mesh)
        batch = registry.input_specs(cfg, shape)
        b_shard = shard_lib.batch_shardings(batch, mesh)
        step = steps_lib.make_train_step(cfg, optimizer)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard))
        return jitted.lower(param_shapes, opt_shapes, batch), cfg

    if shape.kind == "prefill":
        batch = registry.input_specs(cfg, shape)
        b_shard = shard_lib.batch_shardings(batch, mesh)
        step = steps_lib.make_score_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        return jitted.lower(param_shapes, batch), cfg

    # decode
    batch = registry.input_specs(cfg, shape)
    cache_shapes = registry.cache_specs(cfg, shape)
    c_shard = shard_lib.cache_shardings(cache_shapes, mesh)
    tok_shard = shard_lib.batch_shardings({"token": batch["token"]}, mesh)["token"]
    step = steps_lib.make_serve_step(cfg)
    jitted = jax.jit(step, in_shardings=(p_shard, c_shard, tok_shard, rep))
    return jitted.lower(param_shapes, cache_shapes, batch["token"],
                        batch["pos"]), cfg


def _opt_shardings(optimizer, param_shapes, p_shard, mesh):
    """Adam mu/nu shard exactly like their parameters; counters replicated."""
    rep = shard_lib.replicated(mesh)
    opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
    # AdamState(step, mu, nu): mu/nu mirror params
    return type(opt_shapes)(step=rep,
                            mu=p_shard, nu=p_shard)


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              flops_scope: str | None = None, verbose: bool = True,
              overrides: dict | None = None, tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)
    cfg = registry.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips, "status": "skipped", "reason": why,
           "variant": tag or "baseline"}
    if not ok:
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} @ {mesh_name}: SKIPPED ({why})")
        return rec

    # 1) full production compile — the "it lowers, compiles and fits" proof
    t0 = time.time()
    with mesh_lib.set_mesh(mesh):
        lowered, full_cfg = build_lowered(cfg, shape, mesh,
                                          overrides=overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # 2) (k, 2k)-layer unrolled probes — exact cost/collective accounting,
    #    linearly extrapolated to n_layers
    def probe_costs(layers: int) -> dict:
        with mesh_lib.set_mesh(mesh):
            plow, _ = build_lowered(cfg, shape, mesh, probe_layers=layers,
                                    overrides=overrides)
        pcomp = plow.compile()
        cost = cost_dict(pcomp)
        coll = hlo_analysis.parse_collectives(pcomp.as_text())
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": float(coll.total_bytes),
                "coll_by_op": coll.bytes_by_op,
                "coll_counts": coll.count_by_op}

    k, k2 = probe_layer_counts(cfg)
    t0 = time.time()
    c1 = probe_costs(k)
    c2 = probe_costs(k2) if k2 > k else c1
    t_probe = time.time() - t0
    L = cfg.n_layers

    def extrap(a, b):
        if k2 == k:
            return b * (L / k)
        per_layer = (b - a) / (k2 - k)
        fixed = a - k * per_layer
        return fixed + L * per_layer

    flops = extrap(c1["flops"], c2["flops"])
    hbm_bytes = extrap(c1["bytes"], c2["bytes"])
    coll_bytes = extrap(c1["coll"], c2["coll"])
    coll_by_op = {op: extrap(c1["coll_by_op"].get(op, 0.0),
                             c2["coll_by_op"].get(op, 0.0))
                  for op in set(c1["coll_by_op"]) | set(c2["coll_by_op"])}

    if flops_scope is None:
        flops_scope = probe_flops_scope(mesh)
    terms = hlo_analysis.roofline_terms(
        flops, hbm_bytes, coll_bytes, chips, PEAK_FLOPS_BF16, HBM_BW,
        ICI_BW, flops_are_global=(flops_scope == "global"))

    param_shapes = jax.eval_shape(lambda: transformer.init(cfg, jax.random.key(0)))
    total_p, active_p = active_param_count(cfg, param_shapes)
    mf = model_flops(cfg, shape, active_p)
    global_flops = flops if flops_scope == "global" else flops * chips

    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_fields[f] = int(v)

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "probe_s": round(t_probe, 2), "probe_layers": [k, k2],
        "flops_scope": flops_scope,
        "hlo_flops": flops, "hlo_bytes": hbm_bytes,
        "hlo_flops_global": global_flops,
        "collective_bytes": coll_bytes,
        "collective_by_op": coll_by_op,
        "params_total": total_p, "params_active": active_p,
        "model_flops": mf,
        "useful_flops_ratio": mf / global_flops if global_flops else None,
        "memory_analysis": mem_fields,
        **terms,
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} @ {mesh_name}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s, "
              f"probes {t_probe:.1f}s)")
        print(f"  memory_analysis: {mem_fields}")
        print(f"  cost_analysis (extrapolated from {k}/{k2}-layer probes): "
              f"flops={flops:.3e} bytes={hbm_bytes:.3e} [{flops_scope}]")
        print(f"  collectives: { {o: f'{b:.3e}' for o, b in coll_by_op.items()} }")
        print(f"  roofline: compute={terms['compute_s']:.4f}s "
              f"memory={terms['memory_s']:.4f}s "
              f"collective={terms['collective_s']:.4f}s "
              f"-> bottleneck: {terms['bottleneck']}")
        print(f"  MODEL_FLOPS={mf:.3e} useful-ratio="
              f"{rec['useful_flops_ratio']:.3f}" if rec["useful_flops_ratio"]
              else "")
    return rec


def artifact_path(arch, shape_name, multi_pod, tag: str = ""):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    safe = arch.replace("/", "_").replace(".", "_")
    suffix = f"__{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR,
                        f"dryrun_{safe}_{shape_name}_{mesh_name}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    combos = ([(args.arch, args.shape)] if not args.all else
              [(a, s) for a in registry.ARCH_IDS for s in INPUT_SHAPES])
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    scope = probe_flops_scope(mesh)
    print(f"[dryrun] devices={num_chips(mesh)} flops_scope={scope}")
    failures = []
    for arch, shape_name in combos:
        path = artifact_path(arch, shape_name, args.multi_pod)
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] {arch} x {shape_name}: cached")
            continue
        try:
            rec = run_combo(arch, shape_name, args.multi_pod, flops_scope=scope)
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "error", "error": repr(e)}
            failures.append((arch, shape_name, repr(e)))
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all combos OK")


if __name__ == "__main__":
    main()
