"""Post-SPMD HLO analysis: collective-traffic accounting + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but not collective
traffic, so collective bytes are recovered by parsing the partitioned HLO
text: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` instruction contributes its *output*
buffer size (per-device module => per-device bytes through the ICI).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum buffer sizes in an HLO shape string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan the (post-partitioning) HLO for collective instructions.

    Matches lines of the form ``  %x = <shape> all-gather(...)`` and credits
    the output shape's bytes to that collective type. ``start/done`` pairs
    (async collectives) are counted once via the ``-start`` instruction, and
    plain (sync) forms are counted directly.
    """
    stats = CollectiveStats()
    line_re = re.compile(
        r"=\s+([^=]+?)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\(")
    seen_async = set()
    for line in hlo_text.splitlines():
        m = line_re.search(line)
        if not m:
            continue
        shape_str, op, is_start = m.group(1), m.group(2), m.group(3)
        if is_start:
            seen_async.add(op)
        b = _shape_bytes(shape_str)
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   chips: int, peak_flops: float, hbm_bw: float,
                   ici_bw: float, flops_are_global: bool) -> dict:
    """The three roofline terms in seconds (DESIGN/EXPERIMENTS §Roofline)."""
    div = chips if flops_are_global else 1
    t_compute = flops / div / peak_flops
    t_memory = hbm_bytes / div / hbm_bw
    t_coll = collective_bytes / ici_bw   # collective bytes are per-device
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms
