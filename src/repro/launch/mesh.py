"""Production mesh construction (TPU v5e).

Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2, data=16,
model=16) = 512 chips — the ``pod`` axis carries only data parallelism
(gradient all-reduce), matching the paper's observation that experience/
gradient aggregation tolerates the slower cross-pod links (§3: "possible for
actors and learners to run in different data-centers").

Functions only — importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-compatible mesh construction: jax >= 0.5 wants explicit
    ``axis_types``; on older jax ``Auto`` is implicit and the enum absent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def set_mesh(mesh):
    """Version-compatible ambient-mesh context: ``jax.set_mesh`` on jax >=
    0.6; on older releases the ``Mesh`` object is itself the context
    manager that installs the resource environment."""
    setter = getattr(jax, "set_mesh", None)
    return setter(mesh) if setter is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry batch/FSDP parallelism (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_chips(mesh) -> int:
    return mesh.devices.size


# TPU v5e hardware model (per chip) for the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
