"""Step functions lowered by the dry-run and executed by train/serve drivers.

One builder per shape kind (DESIGN.md §3):

* ``train_step``   — the Ape-X learner update on a prioritized sequence batch
                     (IS-weighted CE + MoE aux, grad clip, AdamW, fresh
                     per-sequence priorities out).
* ``score_step``   — the Ape-X *actor* role at prefill shape: forward the
                     batch under (stale) params and emit initial priorities
                     (Alg. 1 line 10).
* ``serve_step``   — one-token decode against a ``seq_len`` cache (acting /
                     policy evaluation).

All are pure (params, ...) -> (...) functions — GSPMD distributes them from
the in_shardings alone.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.optim import optimizers as optim


def _forward_logits(cfg, params, batch, return_aux=False):
    kwargs = {}
    tokens = batch.get("tokens")
    if "embeddings" in batch:
        kwargs["embeddings"] = batch["embeddings"]
    if "prefix_embeddings" in batch:
        kwargs["prefix_embeddings"] = batch["prefix_embeddings"]
    return transformer.apply(params, tokens, cfg=cfg, return_aux=return_aux,
                             **kwargs)


def _constrain_logits(cfg, logits):
    if cfg.act_sharding is None:
        return logits
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        logits, P(cfg.act_sharding[0], None, "model"))


def _per_sequence_nll(logits, labels):
    """Per-sequence mean NLL, vocab-sharding friendly: the correct-class logit
    is extracted with a masked reduction (partial-sum + all-reduce under
    GSPMD) instead of take_along_axis, which would all-gather the logits."""
    mask = (labels >= 0).astype(jnp.float32)
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    vocab = logits.shape[-1]
    sel = labels[..., None] == jnp.arange(vocab, dtype=labels.dtype)
    correct = jnp.sum(jnp.where(sel, logits32, 0.0), axis=-1)
    nll = (logz - correct) * mask
    return nll.sum(-1) / jnp.maximum(mask.sum(-1), 1.0)


def make_train_step(cfg, optimizer: optim.Optimizer,
                    grad_clip: float = 1.0) -> Callable:
    def train_step(params: Any, opt_state: Any, batch: dict):
        def loss_fn(p):
            logits, aux = _forward_logits(cfg, p, batch, return_aux=True)
            logits = _constrain_logits(cfg, logits)
            per_seq = _per_sequence_nll(logits, batch["labels"])
            loss = jnp.mean(batch["is_weights"] * per_seq) + aux
            return loss, per_seq

        (loss, per_seq), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads = optim.clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        new_priorities = jax.lax.stop_gradient(per_seq)
        return params, opt_state, new_priorities, {"loss": loss}

    return train_step


def make_score_step(cfg) -> Callable:
    def score_step(params: Any, batch: dict) -> jax.Array:
        logits = _constrain_logits(cfg, _forward_logits(cfg, params, batch))
        return _per_sequence_nll(logits, batch["labels"])   # initial priorities

    return score_step


def make_serve_step(cfg) -> Callable:
    def serve_step(params: Any, cache: Any, token: jax.Array, pos: jax.Array):
        logits, cache = transformer.decode_step(
            params, token, pos, cfg=cfg, cache=cache)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], cache

    return serve_step
