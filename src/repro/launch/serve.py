"""Serving launcher: batched greedy decode (prefill + decode-step loop — the
shape lowered by the decode dry-runs) and a slot-scheduled continuous-batching
engine (per-row decode positions: requests are admitted into free slots as
earlier ones finish, no batch-wide synchronization).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
      --batch 4 --prompt-len 16 --new-tokens 24
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --continuous

The ``ContinuousBatcher`` is the decode-side half of the inference plane
(ROADMAP item 4): one compiled decode step over ``slots`` batch rows, each
row carrying its own position, requests admitted from a deque the step a
slot frees. Prompts are consumed by *chunked prefill* — ``prefill_chunk``
writes C prompt tokens per call at a shared start offset, with a per-row
validity mask restoring the cache of non-participating rows — instead of
feeding the prompt one token at a time through the decode step. The tail
(< C tokens plus the last prompt token) still rides the decode path, so a
prompt of length P costs ``(P-1)//C`` chunk calls + ``P - C*((P-1)//C)``
decode steps rather than P decode steps.

Param hot-swap drains: when the versioned ``ParamStore`` publishes, the
batcher stops admitting, finishes every in-flight request on the params it
was admitted under, swaps, and resumes — zero requests dropped, and the
admission/completion version of every request is recorded so tests can
assert the contract under version churn.
"""

from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_lib
from repro.models import registry, transformer


class ContinuousBatcher:
    """Slot-scheduled continuous batching over per-row decode positions.

    Each of ``slots`` batch rows carries its own position; finished rows are
    immediately re-filled with the next queued request. Attention rows mask
    themselves by their own valid length, so rows never see each other's
    cache; recurrent (SSM/WKV) state is zeroed by one batched masked reset
    per step covering every slot admitted that step.

    ``param_store`` (optional) wires hot-swap: a version change drains the
    in-flight slots on their admission-time params before the swap is taken.
    ``on_step(step)`` runs after every decode step — tests use it to publish
    new versions at deterministic points in the schedule.
    """

    def __init__(self, cfg, params, *, slots: int, max_len: int,
                 max_new_tokens: int, param_store=None,
                 prefill_chunk: int = 8, on_step=None):
        if cfg.encoder_only:
            raise ValueError("encoder-only arch has no decode step")
        self.cfg = cfg
        self.slots, self.max_len = slots, max_len
        self.max_new = max_new_tokens
        self.on_step = on_step
        self._store = param_store
        if param_store is not None:
            snap = param_store.get()
            self.params, self._version = snap.params, snap.version
        else:
            self.params, self._version = params, 0
        # The ring cache's S>1 write path cannot exceed the ring, so chunked
        # prefill is only safe on the full-length cache layout.
        self._chunk = (prefill_chunk
                       if prefill_chunk and prefill_chunk > 1
                       and not getattr(cfg, "swa_ring_cache", False) else 0)
        self.swaps = 0                  # drain-and-swap cycles taken
        self.steps = 0                  # decode steps issued
        self.admission_version: dict[int, int] = {}
        self.completion_version: dict[int, int] = {}
        self.step_fn = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(
                p, t, pos, cfg=cfg, cache=c))
        # One masked reset per step for ALL slots admitted that step
        # (attention rows are masked by length anyway, but SSM/WKV recurrent
        # state must not leak across requests). Cache leaves carry batch at
        # axis 1, so the slot mask broadcasts as (1, slots, 1, ...).
        self.reset_fn = jax.jit(lambda c, mask: jax.tree.map(
            lambda a: jnp.where(mask.reshape((1, -1) + (1,) * (a.ndim - 2)),
                                jnp.zeros_like(a), a), c))

        def masked_chunk(p, c, toks, start, mask):
            _, new = transformer.prefill_chunk(p, toks, start, cfg=cfg,
                                               cache=c)
            # Rows not prefilling this chunk keep their old cache verbatim.
            return jax.tree.map(
                lambda old, fresh: jnp.where(
                    mask.reshape((1, -1) + (1,) * (fresh.ndim - 2)),
                    fresh, old), c, new)

        self.chunk_fn = jax.jit(masked_chunk)

    # -- scheduling policy ---------------------------------------------------

    def _admissible(self, active: np.ndarray) -> list[int]:
        """Slots the scheduler may fill this step (continuous: any free
        slot, immediately)."""
        return [s for s in range(self.slots) if not active[s]]

    # -- engine --------------------------------------------------------------

    def run(self, prompts: list[np.ndarray],
            new_tokens: list[int] | None = None) -> dict[int, list[int]]:
        """Serve every prompt to completion; returns request id -> emitted
        greedy tokens. ``new_tokens`` optionally caps each request's budget
        individually (a ragged stream); defaults to ``max_new_tokens``."""
        cfg = self.cfg
        budgets = (list(new_tokens) if new_tokens is not None
                   else [self.max_new] * len(prompts))
        cache = transformer.init_cache(cfg, self.slots, self.max_len)
        queue = collections.deque(enumerate(prompts))
        slot_req = [-1] * self.slots          # request id per slot
        slot_prompt: list[np.ndarray | None] = [None] * self.slots
        pos = np.zeros(self.slots, np.int64)  # next write position per slot
        emitted: dict[int, list[int]] = {}
        next_tok = np.zeros((self.slots, 1), np.int64)
        active = np.zeros(self.slots, bool)
        draining = False

        def admit(cache):
            admitted = []
            for s in self._admissible(active):
                if not queue:
                    break
                rid, prompt = queue.popleft()
                slot_req[s], slot_prompt[s] = rid, prompt
                emitted[rid] = []
                self.admission_version[rid] = self._version
                active[s] = True
                admitted.append(s)
            if not admitted:
                return cache
            mask = np.zeros(self.slots, bool)
            mask[admitted] = True
            cache = self.reset_fn(cache, jnp.asarray(mask))
            C = self._chunk
            nfull = {s: ((len(slot_prompt[s]) - 1) // C if C else 0)
                     for s in admitted}
            for k in range(max(nfull.values(), default=0)):
                rows = [s for s in admitted if nfull[s] > k]
                toks = np.zeros((self.slots, C), np.int64)
                for s in rows:
                    toks[s] = slot_prompt[s][k * C:(k + 1) * C]
                cmask = np.zeros(self.slots, bool)
                cmask[rows] = True
                # Same-step admissions share chunk starts (all begin at 0),
                # so chunk k is ONE batched call at offset k*C.
                cache = self.chunk_fn(self.params, cache,
                                      jnp.asarray(toks, jnp.int32),
                                      jnp.asarray(k * C, jnp.int32),
                                      jnp.asarray(cmask))
            for s in admitted:
                pos[s] = nfull[s] * C
                next_tok[s, 0] = slot_prompt[s][pos[s]]
            return cache

        while queue or active.any():
            if self._store is not None and self._store.version != self._version:
                draining = True     # stop admitting, finish in-flight slots
            if draining and not active.any():
                snap = self._store.get()
                self.params, self._version = snap.params, snap.version
                self.swaps += 1
                draining = False
            if not draining:
                cache = admit(cache)
            if not active.any():
                continue            # drained (or queue raced empty)

            tok = jnp.asarray(next_tok, jnp.int32)
            step_pos = jnp.asarray(pos, jnp.int32)
            logits, cache = self.step_fn(self.params, cache, tok, step_pos)
            greedy = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            self.steps += 1
            for s in range(self.slots):
                if not active[s]:
                    continue
                rid, prompt = slot_req[s], slot_prompt[s]
                pos[s] += 1
                if pos[s] < len(prompt):          # prompt tail as decode
                    next_tok[s, 0] = prompt[pos[s]]
                    continue
                emitted[rid].append(int(greedy[s]))
                done = (len(emitted[rid]) >= budgets[rid]
                        or pos[s] + 1 >= self.max_len)
                if done:
                    self.completion_version[rid] = self._version
                    active[s] = False
                else:
                    next_tok[s, 0] = greedy[s]
            if self.on_step is not None:
                self.on_step(self.steps)
        return emitted


class WaveBatcher(ContinuousBatcher):
    """Wave-coalescing baseline on the same engine: admission waits for the
    batch-wide barrier (every slot free), so each wave quantizes to its
    slowest member. Exists to isolate the *scheduling* difference for
    ``bench_serve_latency`` — chunked prefill, masked resets, and the
    compiled step are identical to :class:`ContinuousBatcher`."""

    def _admissible(self, active: np.ndarray) -> list[int]:
        if active.any():
            return []               # the barrier: no refills mid-wave
        return list(range(self.slots))


def serve(arch: str, batch: int, prompt_len: int, new_tokens: int,
          reduced: bool = True):
    cfg = registry.get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{arch} is encoder-only: no decode step (DESIGN.md)")
    rng = jax.random.key(0)
    params = transformer.init(cfg, rng)
    max_len = prompt_len + new_tokens
    cache = transformer.init_cache(cfg, batch, max_len)
    prompt = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab_size)

    prefill = jax.jit(lambda p, t, c: transformer.prefill(p, t, cfg=cfg, cache=c))
    serve_step = jax.jit(steps_lib.make_serve_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompt, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(new_tokens - 1):
        tok, cache = serve_step(params, cache, tok, jnp.asarray(prompt_len + i))
        out.append(tok)
    tokens = jnp.concatenate(out, axis=1)
    jax.block_until_ready(tokens)
    dt = time.time() - t0
    tps = batch * new_tokens / dt
    print(f"[serve] {arch}: {batch} seqs x {new_tokens} tokens "
          f"in {dt:.2f}s ({tps:.1f} tok/s)")
    print(f"[serve] first sequence: {tokens[0].tolist()}")
    return tokens


def serve_continuous(arch: str, requests: int = 8, slots: int = 4,
                     new_tokens: int = 8, reduced: bool = True):
    cfg = registry.get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = transformer.init(cfg, jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=rng.randint(4, 12))
               for _ in range(requests)]
    batcher = ContinuousBatcher(cfg, params, slots=slots, max_len=64,
                                max_new_tokens=new_tokens)
    t0 = time.time()
    out = batcher.run(prompts)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"[serve-cb] {arch}: {requests} ragged requests on {slots} slots "
          f"-> {total} tokens in {dt:.2f}s ({batcher.steps} steps)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS, required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching scheduler demo")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.continuous:
        serve_continuous(args.arch, new_tokens=args.new_tokens,
                         reduced=not args.full)
    else:
        serve(args.arch, args.batch, args.prompt_len, args.new_tokens,
              reduced=not args.full)


if __name__ == "__main__":
    main()
