"""Serving launcher: batched greedy decode (prefill + decode-step loop — the
shape lowered by the decode dry-runs) and a continuous-batching scheduler
(per-row decode positions: requests are admitted into free slots as earlier
ones finish, no batch-wide synchronization).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
      --batch 4 --prompt-len 16 --new-tokens 24
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --continuous
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_lib
from repro.models import registry, transformer


class ContinuousBatcher:
    """Slot-based continuous batching over per-row decode positions.

    Each of ``slots`` batch rows carries its own position; finished rows are
    immediately re-filled with the next queued request (its prompt is fed
    token-by-token through the same decode path — "prefill as decode", which
    keeps a single compiled step). Attention rows mask themselves by their
    own valid length, so rows never see each other's cache.
    """

    def __init__(self, cfg, params, *, slots: int, max_len: int,
                 max_new_tokens: int):
        if cfg.encoder_only:
            raise ValueError("encoder-only arch has no decode step")
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.max_new = max_new_tokens
        self.step_fn = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(
                p, t, pos, cfg=cfg, cache=c))

    def run(self, prompts: list[np.ndarray]) -> dict[int, list[int]]:
        cfg = self.cfg
        cache = transformer.init_cache(cfg, self.slots, self.max_len)
        queue = list(enumerate(prompts))
        slot_req = [-1] * self.slots          # request id per slot
        slot_prompt: list[np.ndarray | None] = [None] * self.slots
        pos = np.zeros(self.slots, np.int64)  # next write position per slot
        emitted: dict[int, list[int]] = {}
        next_tok = np.zeros((self.slots, 1), np.int64)
        active = np.zeros(self.slots, bool)

        reset_slot = jax.jit(lambda c, s: jax.tree.map(
            lambda a: a.at[:, s].set(jnp.zeros_like(a[:, s])), c))

        def admit(s, cache):
            if not queue:
                active[s] = False
                return cache
            rid, prompt = queue.pop(0)
            slot_req[s], slot_prompt[s] = rid, prompt
            pos[s] = 0
            next_tok[s, 0] = prompt[0]
            emitted[rid] = []
            active[s] = True
            # zero the slot's cache rows: attention rows are masked anyway,
            # but SSM/WKV recurrent state must not leak across requests
            return reset_slot(cache, s)

        for s in range(self.slots):
            cache = admit(s, cache)

        while any(active):
            tok = jnp.asarray(next_tok, jnp.int32)
            step_pos = jnp.asarray(pos, jnp.int32)
            logits, cache = self.step_fn(self.params, cache, tok, step_pos)
            greedy = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for s in range(self.slots):
                if not active[s]:
                    continue
                rid, prompt = slot_req[s], slot_prompt[s]
                pos[s] += 1
                if pos[s] < len(prompt):          # still prefilling
                    next_tok[s, 0] = prompt[pos[s]]
                    continue
                emitted[rid].append(int(greedy[s]))
                done = (len(emitted[rid]) >= self.max_new
                        or pos[s] + 1 >= self.max_len)
                if done:
                    cache = admit(s, cache)
                else:
                    next_tok[s, 0] = greedy[s]
        return emitted


def serve(arch: str, batch: int, prompt_len: int, new_tokens: int,
          reduced: bool = True):
    cfg = registry.get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{arch} is encoder-only: no decode step (DESIGN.md)")
    rng = jax.random.key(0)
    params = transformer.init(cfg, rng)
    max_len = prompt_len + new_tokens
    cache = transformer.init_cache(cfg, batch, max_len)
    prompt = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab_size)

    prefill = jax.jit(lambda p, t, c: transformer.prefill(p, t, cfg=cfg, cache=c))
    serve_step = jax.jit(steps_lib.make_serve_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompt, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(new_tokens - 1):
        tok, cache = serve_step(params, cache, tok, jnp.asarray(prompt_len + i))
        out.append(tok)
    tokens = jnp.concatenate(out, axis=1)
    jax.block_until_ready(tokens)
    dt = time.time() - t0
    tps = batch * new_tokens / dt
    print(f"[serve] {arch}: {batch} seqs x {new_tokens} tokens "
          f"in {dt:.2f}s ({tps:.1f} tok/s)")
    print(f"[serve] first sequence: {tokens[0].tolist()}")
    return tokens


def serve_continuous(arch: str, requests: int = 8, slots: int = 4,
                     new_tokens: int = 8, reduced: bool = True):
    cfg = registry.get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = transformer.init(cfg, jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=rng.randint(4, 12))
               for _ in range(requests)]
    batcher = ContinuousBatcher(cfg, params, slots=slots, max_len=64,
                                max_new_tokens=new_tokens)
    t0 = time.time()
    out = batcher.run(prompts)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"[serve-cb] {arch}: {requests} ragged requests on {slots} slots "
          f"-> {total} tokens in {dt:.2f}s")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS, required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching scheduler demo")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.continuous:
        serve_continuous(args.arch, new_tokens=args.new_tokens,
                         reduced=not args.full)
    else:
        serve(args.arch, args.batch, args.prompt_len, args.new_tokens,
              reduced=not args.full)


if __name__ == "__main__":
    main()
