"""Parameter / input / cache sharding rules (GSPMD via jit in_shardings).

Strategy (DESIGN.md §5): 2-D parameter sharding — tensor-parallel over
``model`` (attention heads, FF hidden, experts, vocab) and FSDP over the data
axes (``pod`` × ``data``) on a complementary dimension. Activations shard
batch over the data axes; for single-sequence long-context decode the KV
cache shards its *sequence* dimension over ``data`` instead (context
parallelism — softmax partial reductions become collectives).

Every rule passes through a divisibility guard: an axis that does not divide
the dimension is dropped (e.g. granite's 49155 vocab is not 16-divisible, so
its embedding shards on d_model only). This keeps one rule set valid across
all ten architectures.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


def _axis_size(mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name]


def _fit(spec: tuple, shape: tuple[int, ...], mesh) -> P:
    """Drop axes that don't divide their dimension; pad spec to rank."""
    spec = (None,) * (len(shape) - len(spec)) + tuple(spec)
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
        else:
            size = _axis_size(mesh, ax)
            out.append(ax if dim % size == 0 else None)
    return P(*out)


def _param_spec(path: str, shape: tuple[int, ...], mesh) -> P:
    """Rule table keyed on path suffix/context; 'D' = FSDP axes, 'M' = model."""
    D = data_axes(mesh)
    name = path.split("/")[-1]
    ndim = len(shape)

    if path.endswith("embed/w"):
        return _fit(("model", D), shape, mesh)
    if path.endswith("head/w"):
        return _fit((D, "model"), shape, mesh)

    mixer_ctx = "/mixer/" in path or "/attn/" in path
    mlp_ctx = "/mlp/" in path or "/shared/" in path

    if mixer_ctx:
        if name in ("wq", "wk", "wv", "wg", "wr", "in_proj",
                    "wq_a", "wq_b", "wkv_a", "wkv_b"):
            return _fit((D, "model"), shape, mesh)
        if name in ("wo", "out_proj"):
            return _fit(("model", D), shape, mesh)
        if name in ("A_log", "dt_bias", "D") and ndim >= 1:
            return _fit(("model",), shape, mesh)
        return P()  # norms, conv, lora, mixes, bonus — replicated

    if mlp_ctx:
        if name == "router":
            return _fit((D, None), shape, mesh)
        if name in ("w_gate", "w_up", "wk"):
            if ndim == 4:   # MoE experts (L, E, d, ff): experts over model
                return _fit(("model", D, None), shape, mesh)
            return _fit((D, "model"), shape, mesh)
        if name in ("w_down", "wv"):
            if ndim == 4:
                return _fit(("model", None, D), shape, mesh)
            return _fit(("model", D), shape, mesh)
        if name == "wr":    # rwkv channel-mix receptance (d, d)
            return _fit((D, "model"), shape, mesh)
        return P()

    return P()  # final_ln etc.


def param_shardings(param_shapes: Any, mesh) -> Any:
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStructs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec = _param_spec(key, tuple(leaf.shape), mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_shapes: Any, mesh) -> Any:
    """Training/prefill inputs: batch over the data axes, rest replicated."""
    D = data_axes(mesh)

    def one(leaf):
        spec = _fit((D,) + (None,) * (len(leaf.shape) - 1), tuple(leaf.shape), mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch_shapes)


def cache_shardings(cache_shapes: Any, mesh) -> Any:
    """Decode caches (leading layer dim). Batch over data axes when it
    divides; otherwise (single-sequence long-context) the *sequence* dim of
    attention caches shards over ``data`` — context parallelism. Head/state
    dims shard over ``model`` when divisible."""
    D = data_axes(mesh)
    dsize = _axis_size(mesh, D)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        name = str(getattr(path[-1], "key", path[-1]))
        batch_ok = len(shape) >= 2 and shape[1] % dsize == 0
        if name in ("k", "v", "shared_k", "shared_v"):   # (L,B,S,H,Dh)
            if batch_ok:
                return NamedSharding(mesh, _fit((None, D, None, "model", None), shape, mesh))
            return NamedSharding(mesh, _fit((None, None, D, "model", None), shape, mesh))
        if name == "latent":                             # (L,B,S,lora+rope)
            if batch_ok:
                return NamedSharding(mesh, _fit((None, D, None, "model"), shape, mesh))
            return NamedSharding(mesh, _fit((None, None, D, "model"), shape, mesh))
        if name in ("ssm", "wkv"):                       # (L,B,H,...)
            spec = (None, D if batch_ok else None, "model") + (None,) * (len(shape) - 3)
            return NamedSharding(mesh, _fit(spec, shape, mesh))
        if name in ("conv", "tm_prev", "cm_prev"):       # (L,B,...,C)
            spec = (None, D if batch_ok else None) + (None,) * (len(shape) - 3) + ("model",)
            return NamedSharding(mesh, _fit(spec, shape, mesh))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(treedef, [one(p, l) for p, l in flat])


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
