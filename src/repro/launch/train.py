"""Training launcher.

Two modes, matching the two integrations of the paper's technique:

* ``--mode apex-dqn`` / ``--mode apex-dpg`` — the paper's own agents on the
  pure-JAX envs (reduced presets run on CPU; full presets target the mesh).
* ``--mode llm --arch <id>`` — prioritized *sequence* replay training of an
  assigned architecture on the synthetic pipeline (reduced config on CPU).

For the apex modes ``--runtime async`` swaps the lockstep driver for the
decoupled actor/learner runtime (``repro.runtime``): ``--iterations`` then
counts learner steps and generate/consume transitions-per-second are
reported separately (paper §4.1).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode apex-dqn --iterations 200
  PYTHONPATH=src python -m repro.launch.train --mode apex-dqn \
      --runtime async --actor-threads 2 --iterations 200
  PYTHONPATH=src python -m repro.launch.train --mode apex-dqn \
      --runtime async --actor-threads 0 --actor-procs 2 --iterations 200
  PYTHONPATH=src python -m repro.launch.train --mode llm --arch llama3.2-1b \
      --iterations 50 --ckpt-dir /tmp/ckpts
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import apex, replay as replay_lib, sequence_replay as seqrep
from repro.data import pipeline as data_lib
from repro.models import registry, transformer
from repro.optim import optimizers as optim
from repro.runtime import AsyncConfig, run_async


def run_apex(preset, iterations: int, log_every: int, ckpt_dir: str | None):
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(
        preset.apex, preset.env, preset.agent, optimizer)
    state = init_fn(jax.random.key(0))
    t0 = time.time()
    for it in range(iterations):
        state, metrics = step_fn(state)
        if (it + 1) % log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            fps = float(state.frames) / (time.time() - t0)
            print(f"iter {it+1:5d} frames={int(m['frames'])} "
                  f"size={int(m['replay_size'])} fps={fps:8.0f} "
                  f"return={m.get('mean_ep_return', float('nan')):8.3f} "
                  f"loss={m.get('loss', m.get('critic_loss', 0)):.4f}")
        if ckpt_dir and (it + 1) % (log_every * 10) == 0:
            ckpt_lib.save(f"{ckpt_dir}/ckpt_{it+1}.npz",
                          {"params": state.params,
                           "opt_state": state.opt_state,
                           "learner_step": state.learner_step}, step=it + 1)
    return state


def run_apex_async(preset, learner_steps: int, actor_threads: int,
                   ckpt_dir: str | None, replay_shards: int = 1,
                   inference_batching: bool = False, actor_procs: int = 0,
                   learn_batches: int = 1, wire_quantize_obs: bool = False):
    """Decoupled runtime: actors, replay fabric shards, and learner on their
    own clocks; reports generate/consume transitions-per-second separately.
    ``actor_procs`` actors run as separate OS processes streaming blocks
    through the replay gateway (single-machine proof of the multi-host
    path); ``learn_batches`` batches are consumed per jitted learner call."""
    acfg = AsyncConfig(actor_threads=actor_threads,
                       actor_procs=actor_procs,
                       replay_shards=replay_shards,
                       inference_batching=inference_batching,
                       learn_batches_per_step=learn_batches,
                       wire_quantize_obs=wire_quantize_obs,
                       total_learner_steps=learner_steps)
    t0 = time.time()
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    s = res.stats
    print(f"async done in {time.time() - t0:6.1f}s  "
          f"learner_steps={int(s['learner_steps'])} "
          f"param_version={int(s['param_version'])}")
    print(f"  generate={s['actor_tps']:8.0f} t/s  "
          f"consume={s['learner_tps']:8.0f} t/s  "
          f"ratio={s['generate_consume_ratio']:.2f} "
          f"(paper §4.1: ~12.5K:9.7K ~ 1.29)")
    print(f"  actor_blocked={int(s['actor_blocked'])} "
          f"learner_starved={int(s['learner_starved'])} "
          f"replay_size={int(s['replay_size'])} "
          f"shards={int(s['replay_shards'])}")
    if res.gateway_stats is not None:
        g = res.gateway_stats
        print(f"  gateway: {int(s['actor_procs'])} actor procs, "
              f"{g.blocks_in} blocks / {g.transitions_in} transitions in, "
              f"{g.param_sends} param snapshots out, "
              f"{g.bytes_in / 1e6:.1f} MB ingested")
    if res.inference_stats is not None:
        i = res.inference_stats
        print(f"  inference: {i.requests} act-requests in {i.dispatches} "
              f"device dispatches ({i.full_waves} full waves)")
    if res.last_actor_metrics:
        print(f"  last mean_ep_return="
              f"{res.last_actor_metrics['mean_ep_return']:.3f}")
    if ckpt_dir:
        ckpt_lib.save(f"{ckpt_dir}/ckpt_async_final.npz",
                      {"params": res.learner.params,
                       "opt_state": res.learner.opt_state,
                       "learner_step": res.learner.learner_step},
                      step=int(s["learner_steps"]))
    return res


def run_llm(arch: str, iterations: int, log_every: int, ckpt_dir: str | None,
            seq_len: int = 128, batch: int = 8):
    cfg = registry.get_config(arch).reduced()
    params = transformer.init(cfg, jax.random.key(0))
    optimizer = optim.adamw(1e-3)
    scfg = seqrep.SeqReplayConfig(
        replay=replay_lib.ReplayConfig(capacity=1024, min_fill=batch),
        seq_len=seq_len, batch_size=batch, ingest_batch=batch,
        param_sync_period=4, learner_steps_per_round=2)
    pcfg = data_lib.PipelineConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                   batch_size=batch)
    apply_fn = lambda p, tokens: transformer.apply(p, tokens, cfg=cfg)
    state = seqrep.init_state(scfg, params, optimizer, jax.random.key(1))

    @jax.jit
    def round_step(state, step):
        b = data_lib.make_batch(pcfg, jax.random.key(7), step)
        return seqrep.round_step(scfg, apply_fn, optimizer, state,
                                 b["tokens"], b["labels"])

    for it in range(iterations):
        state, metrics = round_step(state, it)
        if (it + 1) % log_every == 0:
            print(f"round {it+1:4d} loss={float(metrics['loss']):.4f} "
                  f"mean_prio={float(metrics['mean_priority']):.4f} "
                  f"replay={int(state.replay.size)}")
        if ckpt_dir and (it + 1) % (log_every * 10) == 0:
            ckpt_lib.save(f"{ckpt_dir}/ckpt_{it+1}.npz",
                          {"params": state.params}, step=it + 1)
    return state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("apex-dqn", "apex-dpg", "llm"),
                    default="apex-dqn")
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--iterations", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale preset (mesh required)")
    ap.add_argument("--runtime", choices=("sync", "async"), default="sync",
                    help="sync: lockstep act/learn alternation; async: "
                         "decoupled actor threads + replay service + learner "
                         "(apex modes only)")
    ap.add_argument("--actor-threads", type=int, default=1,
                    help="actor threads for --runtime async")
    ap.add_argument("--replay-shards", type=int, default=1,
                    help="replay fabric shards for --runtime async (actor "
                         "blocks route round-robin; learner batches merge "
                         "per-shard sub-samples)")
    ap.add_argument("--inference-batching", action="store_true",
                    help="share one batched act dispatch across all actor "
                         "threads (--runtime async)")
    ap.add_argument("--actor-procs", type=int, default=0,
                    help="spawn this many actor OS processes streaming "
                         "experience through the replay gateway socket "
                         "(--runtime async; combine with --actor-threads 0 "
                         "for a pure multi-process run)")
    ap.add_argument("--learn-batches", type=int, default=1,
                    help="prefetched batches consumed per jitted learner "
                         "call via lax.scan (--runtime async)")
    ap.add_argument("--wire-quantize-obs", action="store_true",
                    help="actor processes ship observations via the replay "
                         "codec (uint8 + affine, ~4x less wire traffic)")
    args = ap.parse_args()

    def run_preset(preset):
        if args.runtime == "async":
            run_apex_async(preset, args.iterations, args.actor_threads,
                           args.ckpt_dir, args.replay_shards,
                           args.inference_batching, args.actor_procs,
                           args.learn_batches, args.wire_quantize_obs)
        else:
            run_apex(preset, args.iterations, args.log_every, args.ckpt_dir)

    if args.mode == "apex-dqn":
        from repro.configs import apex_dqn
        preset = apex_dqn.full() if args.full else apex_dqn.reduced()
        run_preset(preset)
    elif args.mode == "apex-dpg":
        from repro.configs import apex_dpg
        preset = apex_dpg.full() if args.full else apex_dpg.reduced()
        run_preset(preset)
    else:
        if not args.arch:
            ap.error("--mode llm requires --arch")
        run_llm(args.arch, args.iterations, args.log_every, args.ckpt_dir)


if __name__ == "__main__":
    main()
