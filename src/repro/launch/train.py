"""Training launcher.

Two modes, matching the two integrations of the paper's technique:

* ``--mode apex-dqn`` / ``--mode apex-dpg`` — the paper's own agents on the
  pure-JAX envs (reduced presets run on CPU; full presets target the mesh).
* ``--mode llm --arch <id>`` — prioritized *sequence* replay training of an
  assigned architecture on the synthetic pipeline (reduced config on CPU).

For the apex modes ``--runtime async`` swaps the lockstep driver for the
decoupled actor/learner runtime (``repro.runtime``): ``--iterations`` then
counts learner steps and generate/consume transitions-per-second are
reported separately (paper §4.1).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode apex-dqn --iterations 200
  PYTHONPATH=src python -m repro.launch.train --mode apex-dqn \
      --runtime async --actor-threads 2 --iterations 200
  PYTHONPATH=src python -m repro.launch.train --mode apex-dqn \
      --runtime async --actor-threads 0 --actor-procs 2 --iterations 200
  PYTHONPATH=src python -m repro.launch.train --mode llm --arch llama3.2-1b \
      --iterations 50 --ckpt-dir /tmp/ckpts
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import apex, replay as replay_lib, sequence_replay as seqrep
from repro.data import pipeline as data_lib
from repro.models import registry, transformer
from repro.obs import log as obslog
from repro.optim import optimizers as optim
from repro.runtime import AsyncConfig, run_async


def run_apex(preset, iterations: int, log_every: int, ckpt_dir: str | None):
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(
        preset.apex, preset.env, preset.agent, optimizer)
    state = init_fn(jax.random.key(0))
    t0 = time.time()
    for it in range(iterations):
        state, metrics = step_fn(state)
        if (it + 1) % log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            fps = float(state.frames) / (time.time() - t0)
            obslog.emit(
                "iter", n=it + 1, frames=int(m["frames"]),
                size=int(m["replay_size"]), fps=round(fps),
                ret=f"{m.get('mean_ep_return', float('nan')):.3f}",
                loss=f"{m.get('loss', m.get('critic_loss', 0)):.4f}")
        if ckpt_dir and (it + 1) % (log_every * 10) == 0:
            ckpt_lib.save(f"{ckpt_dir}/ckpt_{it+1}.npz",
                          {"params": state.params,
                           "opt_state": state.opt_state,
                           "learner_step": state.learner_step}, step=it + 1)
    return state


def run_apex_async(preset, learner_steps: int, actor_threads: int,
                   ckpt_dir: str | None, replay_shards: int = 1,
                   inference_batching: bool = False, actor_procs: int = 0,
                   learn_batches: int = 1, wire_quantize_obs: bool = False,
                   sample_staging: bool = False,
                   learner_remote: str | None = None,
                   serve_sampling: bool = False, gateway_port: int = 0,
                   gateway_host: str = "127.0.0.1", transport: str = "auto",
                   wire_quantize_prios: bool = False,
                   wire_quantize_params: bool = False,
                   ingest_staging: bool = False,
                   add_queue_depth: int = 4, sample_queue_depth: int = 2,
                   metrics_dir: str | None = None,
                   trace_sample_rate: float = 0.0,
                   checkpoint_dir: str | None = None,
                   checkpoint_every_s: float = 30.0,
                   resume: bool = False,
                   inference_mode: str = "wave",
                   serve_policy: str | None = None):
    """Decoupled runtime: actors, replay fabric shards, and learner on their
    own clocks; reports generate/consume transitions-per-second separately.
    ``actor_procs`` actors run as separate OS processes streaming blocks
    through the replay gateway (single-machine proof of the multi-host
    path); ``learn_batches`` batches are consumed per jitted learner call.
    ``learner_remote`` turns this process into a pure learner sampling a
    remote fabric; ``serve_sampling`` turns it into the serving side
    (actors + fabric + gateway, no local learner); ``sample_staging``
    double-buffers the learner's sample path through async device puts and
    ``ingest_staging`` mirrors it on the add side (shard owners overlap
    block k+1's H2D with block k's in-place update)."""
    acfg = AsyncConfig(actor_threads=actor_threads,
                       actor_procs=actor_procs,
                       replay_shards=replay_shards,
                       inference_batching=inference_batching,
                       learn_batches_per_step=learn_batches,
                       wire_quantize_obs=wire_quantize_obs,
                       sample_staging=sample_staging,
                       learner_remote=learner_remote,
                       serve_sampling=serve_sampling,
                       gateway_port=gateway_port,
                       gateway_host=gateway_host,
                       transport=transport,
                       wire_quantize_prios=wire_quantize_prios,
                       wire_quantize_params=wire_quantize_params,
                       ingest_staging=ingest_staging,
                       add_queue_depth=add_queue_depth,
                       sample_queue_depth=sample_queue_depth,
                       metrics_dir=metrics_dir,
                       trace_sample_rate=trace_sample_rate,
                       checkpoint_dir=checkpoint_dir,
                       checkpoint_every_s=checkpoint_every_s,
                       resume=resume,
                       inference_mode=inference_mode,
                       serve_policy=serve_policy,
                       total_learner_steps=learner_steps)
    t0 = time.time()
    res = run_async(preset.apex, acfg, preset.env, preset.agent,
                    preset.make_optimizer())
    s = res.stats
    obslog.emit("async-done", seconds=round(time.time() - t0, 1),
                learner_steps=int(s["learner_steps"]),
                param_version=int(s["param_version"]))
    obslog.emit("async-throughput",
                generate_tps=round(s["actor_tps"]),
                consume_tps=round(s["learner_tps"]),
                ratio=f"{s['generate_consume_ratio']:.2f}",
                paper_ratio="1.29")
    obslog.emit("async-contention",
                actor_blocked=int(s["actor_blocked"]),
                learner_starved=int(s["learner_starved"]),
                replay_size=int(s["replay_size"]),
                shards=int(s["replay_shards"]))
    if res.gateway_stats is not None:
        g = res.gateway_stats
        obslog.emit("gateway", actor_procs=int(s["actor_procs"]),
                    conns=g.connections, shm_conns=g.shm_connections,
                    blocks_in=g.blocks_in, transitions_in=g.transitions_in,
                    param_sends=g.param_sends,
                    mb_in=round(g.bytes_in / 1e6, 1))
        if g.sample_requests:
            obslog.emit("sample-plane", batches_served=g.sample_sends,
                        starved_polls=g.sample_starved,
                        priority_updates=g.priority_updates,
                        param_pushes=g.param_pushes)
    if res.service_stats is not None and res.service_stats.blocks_staged:
        obslog.emit("ingest-staging",
                    blocks_staged=res.service_stats.blocks_staged,
                    h2d_issue_us=round(res.service_stats.h2d_us))
    if res.source_stats is not None and res.source_stats.staged:
        ss = res.source_stats
        obslog.emit("sample-staging", batches_staged=ss.staged,
                    idle_polls=ss.stage_idle)
    if res.inference_stats is not None:
        i = res.inference_stats
        obslog.emit("inference", mode=inference_mode, requests=i.requests,
                    dispatches=i.dispatches, full_waves=i.full_waves,
                    hot_swaps=i.hot_swaps)
    if res.policy_stats is not None:
        p = res.policy_stats
        obslog.emit("policy-plane", conns=p.connections,
                    acts=p.act_requests,
                    mb_out=round(p.bytes_out / 1e6, 1))
    if checkpoint_dir or s.get("actor_restarts") or s.get("source_reconnects"):
        obslog.emit("fault-tolerance",
                    resumed_from_step=int(s.get("resumed_from_step", 0)),
                    snapshots=int(s.get("snapshots", 0)),
                    actor_restarts=int(s.get("actor_restarts", 0)),
                    actor_proc_exits=int(s.get("actor_proc_exits", 0)),
                    source_reconnects=int(s.get("source_reconnects", 0)))
    if res.last_actor_metrics:
        obslog.emit(
            "actor-metrics",
            mean_ep_return=f"{res.last_actor_metrics['mean_ep_return']:.3f}")
    if ckpt_dir and not serve_sampling:
        # In serve mode the trained params live on the remote learner host;
        # res.learner here is the untouched init state.
        ckpt_lib.save(f"{ckpt_dir}/ckpt_async_final.npz",
                      {"params": res.learner.params,
                       "opt_state": res.learner.opt_state,
                       "learner_step": res.learner.learner_step},
                      step=int(s["learner_steps"]))
    return res


def run_llm(arch: str, iterations: int, log_every: int, ckpt_dir: str | None,
            seq_len: int = 128, batch: int = 8):
    cfg = registry.get_config(arch).reduced()
    params = transformer.init(cfg, jax.random.key(0))
    optimizer = optim.adamw(1e-3)
    scfg = seqrep.SeqReplayConfig(
        replay=replay_lib.ReplayConfig(capacity=1024, min_fill=batch),
        seq_len=seq_len, batch_size=batch, ingest_batch=batch,
        param_sync_period=4, learner_steps_per_round=2)
    pcfg = data_lib.PipelineConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                   batch_size=batch)
    apply_fn = lambda p, tokens: transformer.apply(p, tokens, cfg=cfg)
    state = seqrep.init_state(scfg, params, optimizer, jax.random.key(1))

    @jax.jit
    def round_step(state, step):
        b = data_lib.make_batch(pcfg, jax.random.key(7), step)
        return seqrep.round_step(scfg, apply_fn, optimizer, state,
                                 b["tokens"], b["labels"])

    for it in range(iterations):
        state, metrics = round_step(state, it)
        if (it + 1) % log_every == 0:
            obslog.emit("round", n=it + 1,
                        loss=f"{float(metrics['loss']):.4f}",
                        mean_prio=f"{float(metrics['mean_priority']):.4f}",
                        replay=int(state.replay.size))
        if ckpt_dir and (it + 1) % (log_every * 10) == 0:
            ckpt_lib.save(f"{ckpt_dir}/ckpt_{it+1}.npz",
                          {"params": state.params}, step=it + 1)
    return state


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("apex-dqn", "apex-dpg", "llm"),
                    default="apex-dqn")
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--iterations", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale preset (mesh required)")
    ap.add_argument("--runtime", choices=("sync", "async"), default="sync",
                    help="sync: lockstep act/learn alternation; async: "
                         "decoupled actor threads + replay service + learner "
                         "(apex modes only)")
    ap.add_argument("--actor-threads", type=int, default=None,
                    help="actor threads for --runtime async (default 1; "
                         "0 is implied by --learner-remote and allowed with "
                         "--actor-procs)")
    ap.add_argument("--replay-shards", type=int, default=1,
                    help="replay fabric shards for --runtime async (actor "
                         "blocks route round-robin; learner batches merge "
                         "per-shard sub-samples)")
    ap.add_argument("--inference-batching", action="store_true",
                    help="share one batched act dispatch across all actor "
                         "threads (--runtime async)")
    ap.add_argument("--inference-mode", choices=("wave", "slots"),
                    default="wave",
                    help="scheduling inside the shared inference engine: "
                         "wave = coalesce up to 2 ms and pad short waves; "
                         "slots = continuous batching — pending requests "
                         "are admitted into free slots the moment the "
                         "previous dispatch returns, params hot-swap at "
                         "dispatch boundaries (requires "
                         "--inference-batching)")
    ap.add_argument("--serve-policy", metavar="HOST:PORT", default=None,
                    help="also serve the shared inference engine over the "
                         "transport plane: a policy-only gateway at "
                         "HOST:PORT answers ACT_REQUEST frames, actor "
                         "processes become thin clients that ship their "
                         "slice per rollout instead of pulling params, and "
                         "external open-loop clients may attach (requires "
                         "--inference-batching)")
    ap.add_argument("--actor-procs", type=int, default=0,
                    help="spawn this many actor OS processes streaming "
                         "experience through the replay gateway socket "
                         "(--runtime async; combine with --actor-threads 0 "
                         "for a pure multi-process run)")
    ap.add_argument("--learn-batches", type=int, default=1,
                    help="prefetched batches consumed per jitted learner "
                         "call via lax.scan (--runtime async)")
    ap.add_argument("--wire-quantize-obs", action="store_true",
                    help="actor processes ship observations via the replay "
                         "codec (uint8 + affine, ~4x less wire traffic)")
    ap.add_argument("--sample-staging", action="store_true",
                    help="double-buffer the learner's sample path: a stager "
                         "thread device-puts batch k+1 while the learner "
                         "computes on batch k (--runtime async)")
    ap.add_argument("--ingest-staging", action="store_true",
                    help="double-buffer the replay shards' add path: each "
                         "owner thread issues block k+1's async device put "
                         "before dispatching block k's in-place update "
                         "(--runtime async; pass-through on CPU hosts)")
    ap.add_argument("--add-queue-depth", type=int, default=4,
                    help="bounded actor->replay queue depth per shard "
                         "(--runtime async); full queues backpressure "
                         "actors")
    ap.add_argument("--sample-queue-depth", type=int, default=2,
                    help="replay->learner prefetch depth per shard "
                         "(--runtime async); 2 = classic double buffering")
    ap.add_argument("--learner-remote", metavar="HOST:PORT", default=None,
                    help="run ONLY the learner here, sampling the replay "
                         "fabric served by a --serve-sampling run at "
                         "HOST:PORT (--runtime async)")
    ap.add_argument("--serve-sampling", action="store_true",
                    help="run actors + replay fabric + gateway and no local "
                         "learner; a --learner-remote process drives the "
                         "run through the gateway (--runtime async)")
    ap.add_argument("--gateway-port", type=int, default=0,
                    help="replay gateway TCP port (0: ephemeral; set a "
                         "fixed port for --serve-sampling so the learner "
                         "host knows where to connect)")
    ap.add_argument("--gateway-host", default="127.0.0.1",
                    help="replay gateway bind address; the loopback "
                         "default only reaches same-machine peers — pass "
                         "0.0.0.0 to serve actors/learners on other hosts")
    ap.add_argument("--transport", choices=("tcp", "shm", "auto"),
                    default="auto",
                    help="byte path for remote hops (--actor-procs and "
                         "--learner-remote): tcp = sockets, shm = same-host "
                         "shared-memory rings (strict), auto = shm when the "
                         "peer is loopback-local, else tcp")
    ap.add_argument("--wire-quantize-prios", action="store_true",
                    help="the remote learner ships priority write-backs "
                         "quantized (uint8 + affine; lossy) — requires "
                         "--learner-remote")
    ap.add_argument("--wire-quantize-params", action="store_true",
                    help="the remote learner ships param snapshots "
                         "quantized (uint8 + affine per tensor; lossy) — "
                         "requires --learner-remote")
    ap.add_argument("--metrics-dir", default=None,
                    help="write telemetry (metrics.jsonl + spans.jsonl) "
                         "into this directory during the run; render with "
                         "`python -m repro.obs.report DIR` "
                         "(--runtime async)")
    ap.add_argument("--trace-sample-rate", type=float, default=0.0,
                    help="fraction of transition blocks / learner batches "
                         "carrying an end-to-end pipeline trace id, in "
                         "[0, 1] (requires --metrics-dir; traced ops force "
                         "a device sync — keep small on hot runs)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="periodically snapshot the whole run — replay "
                         "fabric contents + sum trees + clocks, learner "
                         "slice, param version — as atomic ckpt_<step>.npz "
                         "files in this directory (--runtime async; "
                         "distinct from --ckpt-dir, which saves final "
                         "params only)")
    ap.add_argument("--checkpoint-every-s", type=float, default=30.0,
                    help="seconds between periodic snapshots (requires "
                         "--checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="cold-start from the newest snapshot in "
                         "--checkpoint-dir and continue the interrupted "
                         "run (an empty directory is a normal cold start)")
    return ap


def validate_args(ap: argparse.ArgumentParser,
                  args: argparse.Namespace) -> argparse.Namespace:
    """Reject incoherent flag combinations up front with actionable
    messages, instead of letting them fail deep inside the runtime (or
    silently do something other than what was asked). Resolves the
    ``--actor-threads`` default (1, or 0 when ``--learner-remote`` implies a
    learner-only process). Returns the resolved namespace."""
    is_async = args.runtime == "async"
    async_only = [("--actor-procs", args.actor_procs != 0),
                  ("--replay-shards", args.replay_shards != 1),
                  ("--inference-batching", args.inference_batching),
                  ("--inference-mode", args.inference_mode != "wave"),
                  ("--serve-policy", args.serve_policy is not None),
                  ("--learn-batches", args.learn_batches != 1),
                  ("--wire-quantize-obs", args.wire_quantize_obs),
                  ("--sample-staging", args.sample_staging),
                  ("--ingest-staging", args.ingest_staging),
                  ("--add-queue-depth", args.add_queue_depth != 4),
                  ("--sample-queue-depth", args.sample_queue_depth != 2),
                  ("--learner-remote", args.learner_remote is not None),
                  ("--serve-sampling", args.serve_sampling),
                  ("--gateway-port", args.gateway_port != 0),
                  ("--gateway-host", args.gateway_host != "127.0.0.1"),
                  ("--transport", args.transport != "auto"),
                  ("--wire-quantize-prios", args.wire_quantize_prios),
                  ("--wire-quantize-params", args.wire_quantize_params),
                  ("--metrics-dir", args.metrics_dir is not None),
                  ("--trace-sample-rate", args.trace_sample_rate != 0.0),
                  ("--checkpoint-dir", args.checkpoint_dir is not None),
                  ("--checkpoint-every-s", args.checkpoint_every_s != 30.0),
                  ("--resume", args.resume),
                  ("--actor-threads", args.actor_threads is not None)]
    if not is_async:
        used = [name for name, on in async_only if on]
        if used:
            ap.error(f"{', '.join(used)} require(s) --runtime async "
                     "(the sync lockstep driver has no actor/replay/learner "
                     "threads to configure)")
    if args.mode == "llm":
        if not args.arch:
            ap.error("--mode llm requires --arch")
        if is_async:
            ap.error("--runtime async applies to the apex modes only; "
                     "--mode llm always runs the sequence-replay round loop")
    if args.iterations < 1:
        ap.error(f"--iterations must be >= 1, got {args.iterations}")
    if args.learn_batches < 1:
        ap.error(f"--learn-batches must be >= 1, got {args.learn_batches}")
    if args.actor_procs < 0:
        ap.error(f"--actor-procs must be >= 0, got {args.actor_procs}")
    if args.replay_shards < 1:
        ap.error(f"--replay-shards must be >= 1, got {args.replay_shards}")
    if args.add_queue_depth < 1:
        ap.error("--add-queue-depth must be >= 1 (a bounded queue is what "
                 f"backpressures actors), got {args.add_queue_depth}")
    if args.sample_queue_depth < 1:
        ap.error("--sample-queue-depth must be >= 1 (the learner prefetch "
                 f"buffer), got {args.sample_queue_depth}")
    if not 0.0 <= args.trace_sample_rate <= 1.0:
        ap.error("--trace-sample-rate is a sampling fraction in [0, 1] "
                 f"(0 = tracing off, 1 = every block), got "
                 f"{args.trace_sample_rate}")
    if args.trace_sample_rate > 0 and args.metrics_dir is None:
        ap.error("--trace-sample-rate records pipeline spans, which only "
                 "persist through the JSONL sink — add --metrics-dir DIR "
                 "(without it the spans would fill a ring buffer nobody "
                 "drains)")
    if args.resume and args.checkpoint_dir is None:
        ap.error("--resume loads checkpoint.latest() from --checkpoint-dir; "
                 "there is nothing to resume from without it")
    if args.checkpoint_every_s <= 0:
        ap.error("--checkpoint-every-s must be > 0 seconds, got "
                 f"{args.checkpoint_every_s}")
    if args.checkpoint_dir is not None and (
            args.learner_remote is not None or args.serve_sampling):
        ap.error("--checkpoint-dir snapshots the replay fabric AND the "
                 "learner together, so both must be local — a "
                 "--learner-remote process has no fabric and a "
                 "--serve-sampling process has no learner; run the "
                 "snapshot service on a single-process topology")

    if args.learner_remote is not None:
        from repro.net.learner_client import parse_hostport
        try:
            parse_hostport(args.learner_remote)
        except ValueError as e:
            ap.error(f"--learner-remote: {e}")
        if args.serve_sampling:
            ap.error("--learner-remote and --serve-sampling are the two "
                     "sides of one topology: this process either samples a "
                     "remote fabric or serves its own, not both")
        conflicts = [("--actor-threads", args.actor_threads not in (None, 0)),
                     ("--actor-procs", args.actor_procs != 0),
                     ("--replay-shards", args.replay_shards != 1),
                     ("--inference-batching", args.inference_batching),
                     ("--inference-mode", args.inference_mode != "wave"),
                     ("--serve-policy", args.serve_policy is not None),
                     ("--wire-quantize-obs", args.wire_quantize_obs),
                     ("--ingest-staging", args.ingest_staging),
                     ("--add-queue-depth", args.add_queue_depth != 4),
                     ("--sample-queue-depth", args.sample_queue_depth != 2),
                     ("--gateway-port", args.gateway_port != 0),
                     ("--gateway-host", args.gateway_host != "127.0.0.1")]
        used = [name for name, on in conflicts if on]
        if used:
            ap.error(f"--learner-remote runs a learner-only process; "
                     f"{', '.join(used)} configure(s) the acting/replay "
                     "side, which lives on the --serve-sampling host — "
                     "drop the flag(s) here and pass them there")
        args.actor_threads = 0
    elif args.actor_threads is None:
        args.actor_threads = 1

    if args.serve_sampling:
        serve_conflicts = [("--sample-staging", args.sample_staging),
                           ("--learn-batches", args.learn_batches != 1)]
        used = [name for name, on in serve_conflicts if on]
        if used:
            ap.error(f"--serve-sampling runs no local learner; "
                     f"{', '.join(used)} configure(s) the learner's "
                     "consume path — pass them to the --learner-remote "
                     "process instead")

    if not 0 <= args.gateway_port <= 65535:
        ap.error(f"--gateway-port must be in [0, 65535] (0 = ephemeral), "
                 f"got {args.gateway_port}")
    gateway_flags = [("--gateway-port", args.gateway_port != 0),
                     ("--gateway-host", args.gateway_host != "127.0.0.1")]
    used = [name for name, on in gateway_flags if on]
    if used and not (args.serve_sampling or args.actor_procs > 0):
        ap.error(f"{', '.join(used)} configure(s) the replay gateway, but "
                 "no gateway will run — add --serve-sampling (serve a "
                 "remote learner) or --actor-procs N (serve actor "
                 "processes)")
    if (args.transport != "auto" and args.actor_procs == 0
            and args.learner_remote is None and not args.serve_sampling):
        ap.error("--transport configures remote hops, but none exist — add "
                 "--actor-procs N, --learner-remote HOST:PORT, or "
                 "--serve-sampling (in-process actor threads and the local "
                 "fabric never touch a transport)")
    if ((args.wire_quantize_prios or args.wire_quantize_params)
            and args.learner_remote is None):
        flags = [n for n, on in
                 [("--wire-quantize-prios", args.wire_quantize_prios),
                  ("--wire-quantize-params", args.wire_quantize_params)]
                 if on]
        ap.error(f"{', '.join(flags)} quantize(s) the remote learner's "
                 "upstream frames and require(s) --learner-remote (a local "
                 "learner writes priorities/params back in-process, no "
                 "wire to quantize)")

    if args.actor_threads < 0:
        ap.error(f"--actor-threads must be >= 0, got {args.actor_threads}")
    if (is_async and args.actor_threads == 0 and args.actor_procs == 0
            and args.learner_remote is None):
        ap.error("--actor-threads 0 leaves the run with no experience "
                 "source: add --actor-procs N (actors as OS processes) or "
                 "run actor threads (the learner would starve forever)")
    if args.serve_policy is not None:
        from repro.net.learner_client import parse_hostport
        try:
            # port 0 = ephemeral bind (logged at startup), like --gateway-port
            parse_hostport(args.serve_policy, allow_ephemeral=True)
        except ValueError as e:
            ap.error(f"--serve-policy: {e}")
        if not args.inference_batching:
            ap.error("--serve-policy serves the shared inference engine; "
                     "there is no engine without --inference-batching")
    if args.inference_mode != "wave" and not args.inference_batching:
        ap.error("--inference-mode selects the shared engine's scheduler; "
                 "it requires --inference-batching")
    if (args.inference_batching and args.actor_threads == 0
            and args.serve_policy is None):
        ap.error("--inference-batching batches *in-process* actor threads; "
                 "with --actor-threads 0 there is nothing to batch (actor "
                 "processes run their own jitted rollouts) — unless "
                 "--serve-policy feeds the engine from remote clients")
    if args.serve_sampling and args.gateway_port == 0:
        obslog.emit("note", serve_sampling=True, gateway_port="ephemeral",
                    hint="the learner host needs the port logged at "
                         "startup; pass --gateway-port to pin it")
    return args


def main():
    ap = build_parser()
    args = validate_args(ap, ap.parse_args())

    def run_preset(preset):
        if args.runtime == "async":
            if preset.apex.batch_size % args.replay_shards:
                ap.error(f"--replay-shards {args.replay_shards} must divide "
                         f"the preset batch size {preset.apex.batch_size} "
                         "(equal per-shard sample quotas)")
            run_apex_async(preset, args.iterations, args.actor_threads,
                           args.ckpt_dir, args.replay_shards,
                           args.inference_batching, args.actor_procs,
                           args.learn_batches, args.wire_quantize_obs,
                           args.sample_staging, args.learner_remote,
                           args.serve_sampling, args.gateway_port,
                           args.gateway_host, args.transport,
                           args.wire_quantize_prios,
                           args.wire_quantize_params,
                           args.ingest_staging,
                           args.add_queue_depth, args.sample_queue_depth,
                           args.metrics_dir, args.trace_sample_rate,
                           args.checkpoint_dir, args.checkpoint_every_s,
                           args.resume, args.inference_mode,
                           args.serve_policy)
        else:
            run_apex(preset, args.iterations, args.log_every, args.ckpt_dir)

    if args.mode == "apex-dqn":
        from repro.configs import apex_dqn
        preset = apex_dqn.full() if args.full else apex_dqn.reduced()
        run_preset(preset)
    elif args.mode == "apex-dpg":
        from repro.configs import apex_dpg
        preset = apex_dpg.full() if args.full else apex_dpg.reduced()
        run_preset(preset)
    else:
        run_llm(args.arch, args.iterations, args.log_every, args.ckpt_dir)


if __name__ == "__main__":
    main()
