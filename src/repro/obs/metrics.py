"""Process-local metrics registry: counters, gauges, latency histograms.

The paper's pipeline lives or dies on balance — actors must out-generate
the learner, write-backs must keep eviction honest — and the four stats
dataclasses (``ServiceStats``/``SourceStats``/``GatewayStats``/
``InferenceStats``) only expose *counts* plus lossy 1-in-8-sampled latency
EMAs. This module is the measurement substrate under all of them: every
plane records into one shared :class:`MetricsRegistry`, and the dataclass
fields become derived views (see ``ServiceStats``'s ``*_us``), so nothing
downstream breaks while percentiles become available.

Design constraints, in order:

* **Lock-cheap on the hot path.** Counters and gauges take one
  uncontended per-instrument lock (tens of ns in CPython — far below the
  cost of the queue ops they sit next to); histograms additionally touch
  one bucket slot. Nothing allocates per record.
* **Fixed-bucket histograms.** Geometric buckets (factor ``2**0.25`` ≈
  1.19) spanning 1µs .. ~70min cover every latency this system produces
  with ≤ ~19% relative quantization error — percentiles interpolate
  inside the bucket, so p50/p95/p99 are honest to within one bucket
  ratio (property-tested against ``numpy.quantile``).
* **Create-or-get instruments.** ``registry.counter(name)`` etc. return
  the existing instrument for a name, so independent components (shards,
  connection handlers) share instruments by naming convention
  (``shard0/add_us``, ``gateway/blocks_in``) without passing handles.

``snapshot()`` is the export surface: a plain-dict view of every
instrument, cheap enough for an interval flush thread
(:mod:`repro.obs.sink`) to call once a second.
"""

from __future__ import annotations

import math
import threading

# Geometric bucket ladder: factor 2**0.25 from 1µs up. 128 buckets reach
# 2**(128/4) µs ≈ 4.3e9 µs ≈ 72 minutes — beyond any latency this system
# can produce while the run is still alive.
_BUCKET_FACTOR = 2.0 ** 0.25
_NUM_BUCKETS = 128
_LOG_FACTOR = math.log(_BUCKET_FACTOR)

# Bucket i spans [_FACTOR**i, _FACTOR**(i+1)); values below 1.0 clamp into
# bucket 0, values beyond the ladder clamp into the last bucket.
_BUCKET_EDGES = [_BUCKET_FACTOR ** i for i in range(_NUM_BUCKETS + 1)]


def bucket_index(value: float) -> int:
    """Bucket for ``value``; clamped to the fixed ladder."""
    if value < _BUCKET_FACTOR:
        return 0
    i = int(math.log(value) / _LOG_FACTOR)
    if i >= _NUM_BUCKETS:  # beyond the ladder: clamp before indexing edges
        return _NUM_BUCKETS - 1
    # float log can land one off the true bucket at edges; nudge.
    if value >= _BUCKET_EDGES[i + 1]:
        i += 1
    elif value < _BUCKET_EDGES[i]:
        i -= 1
    return min(max(i, 0), _NUM_BUCKETS - 1)


class Counter:
    """Monotone event count (blocks routed, starved polls, retries)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-write-wins level (queue depth, replay size, param version)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed geometric-bucket latency histogram with interpolated
    percentiles. Values are microseconds by convention (any positive unit
    works — the ladder is relative)."""

    __slots__ = ("name", "_lock", "_counts", "_count", "_sum", "_min", "_max")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._counts = [0] * _NUM_BUCKETS
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = 0.0

    def record(self, value: float) -> None:
        i = bucket_index(value)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Interpolated q-th percentile (q in [0, 100]). Exact to within
        one bucket ratio (~19%): the true quantile lives in the bucket the
        cumulative count selects, and we interpolate the value linearly by
        rank position inside that bucket, clamped to the observed
        min/max so single-bucket histograms return honest values."""
        with self._lock:
            count = self._count
            if count == 0:
                return 0.0
            counts = list(self._counts)
            lo_seen, hi_seen = self._min, self._max
        rank = (q / 100.0) * (count - 1)  # numpy 'linear' convention
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c > rank:
                # rank falls inside bucket i: interpolate by position.
                frac = (rank - cum) / c
                lo = max(_BUCKET_EDGES[i], lo_seen)
                hi = min(_BUCKET_EDGES[i + 1], hi_seen)
                if hi < lo:
                    lo = hi = max(min(_BUCKET_EDGES[i + 1], hi_seen),
                                  min(_BUCKET_EDGES[i], lo_seen))
                return lo + frac * (hi - lo)
            cum += c
        return hi_seen  # q == 100 (or float dust): the observed max

    def summary(self) -> dict:
        """Plain-dict export: count/sum/mean plus p50/p95/p99."""
        return {"count": self._count, "sum": self._sum, "mean": self.mean,
                "p50": self.percentile(50.0), "p95": self.percentile(95.0),
                "p99": self.percentile(99.0)}


class MetricsRegistry:
    """Create-or-get instrument store; one per process (or per test)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            inst = self._counters.get(name)
            if inst is None:
                inst = self._counters[name] = Counter(name)
            return inst

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            inst = self._gauges.get(name)
            if inst is None:
                inst = self._gauges[name] = Gauge(name)
            return inst

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            inst = self._histograms.get(name)
            if inst is None:
                inst = self._histograms[name] = Histogram(name)
            return inst

    def snapshot(self) -> dict:
        """One consistent-enough view of every instrument (instruments are
        individually locked; cross-instrument skew is bounded by the walk)."""
        with self._lock:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = list(self._histograms.values())
        return {
            "counters": {c.name: c.value for c in counters},
            "gauges": {g.name: g.value for g in gauges},
            "histograms": {h.name: h.summary() for h in hists},
        }
