"""Pipeline tracing: follow one transition block across the planes.

A traced block picks up a compact 64-bit id at the actor, and every
stage that touches it — gateway decode/route, shard add, sample refill,
learner step, priority write-back — records a *span* (stage name, id,
duration, wall time, a few fields) into a bounded in-process buffer.
Between processes the id rides a dedicated header field in the v3 wire
frame (:mod:`repro.net.wire`), so a block that crosses the gateway keeps
its identity without payload changes; ``trace_id == 0`` means untraced
and costs one integer compare on the hot path.

Sampling is deterministic, not random: the id source keeps a sequence
counter and traces every ``round(1/rate)``-th call. Determinism matters
here — tests can set rate 1.0 and assert exact propagation, and two runs
at the same rate trace the same block positions, making run-to-run span
diffs meaningful.

Span semantics per plane:

* **ingest**: actor → gateway → add share one id (the block's), so
  inter-stage gaps in :mod:`repro.obs.report` measure queue time between
  planes.
* **consume**: each sampled batch draws a fresh id at the sample stage;
  learn and write-back inherit it via ``SampleSource.last_trace_id``, so
  the sample → learn → writeback chain is linked per batch.

Durations for jitted stages are honest only under a device sync; traced
ops force ``block_until_ready`` (see ``ReplayShard._timed``), which is
why the sample rate default is 0 and the overhead bench gates the
enabled path at >= 0.98x disabled.
"""

from __future__ import annotations

import os
import threading
import time
from collections import deque

# Bounded span buffer: at the default 1s sink flush interval even a
# rate-1.0 smoke run produces a few thousand spans/s; 64k absorbs sink
# stalls without unbounded growth. Overflow drops oldest (deque maxlen).
_DEFAULT_BUFFER_CAP = 65536


class Tracer:
    """Deterministic-sampled trace-id source plus a bounded span buffer."""

    def __init__(self, sample_rate: float = 0.0,
                 buffer_cap: int = _DEFAULT_BUFFER_CAP):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(
                f"trace sample rate must be in [0, 1], got {sample_rate}")
        self.sample_rate = float(sample_rate)
        # every N-th sample() call draws a real id; rate 0 disables.
        self._period = 0 if sample_rate <= 0.0 else max(
            1, round(1.0 / sample_rate))
        self._lock = threading.Lock()
        self._seq = 0
        self._next_id = 1
        # pid in the top bits keeps ids unique across actor processes
        # without coordination; 48 bits of counter is inexhaustible.
        self._id_prefix = (os.getpid() & 0xFFFF) << 48
        self._spans: deque[dict] = deque(maxlen=buffer_cap)

    @property
    def enabled(self) -> bool:
        return self._period > 0

    def new_id(self) -> int:
        """A fresh nonzero trace id, unconditionally (no sampling)."""
        with self._lock:
            tid = self._id_prefix | self._next_id
            self._next_id = (self._next_id + 1) & ((1 << 48) - 1) or 1
        return tid

    def sample(self) -> int:
        """A trace id for this event if it is sampled, else 0."""
        if self._period == 0:
            return 0
        with self._lock:
            seq = self._seq
            self._seq += 1
        if seq % self._period:
            return 0
        return self.new_id()

    def record(self, stage: str, trace_id: int, dur_us: float,
               **fields) -> None:
        """Append one span. No-op for trace_id 0 so call sites can pass
        the id through unconditionally."""
        if not trace_id:
            return
        span = {"stage": stage, "trace_id": trace_id,
                "dur_us": float(dur_us), "ts": time.time()}
        if fields:
            span.update(fields)
        self._spans.append(span)  # deque.append is atomic under the GIL

    def drain(self) -> list[dict]:
        """Remove and return all buffered spans (sink flush path)."""
        out = []
        while True:
            try:
                out.append(self._spans.popleft())
            except IndexError:
                return out

    def peek(self) -> list[dict]:
        """Non-destructive copy of the buffer (test assertions)."""
        return list(self._spans)
