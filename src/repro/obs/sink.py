"""JSONL export: interval-flushed metrics snapshots and trace spans.

One :class:`JsonlSink` owns a directory and two append-only files:

* ``metrics.jsonl`` — one registry snapshot per flush interval, each
  line ``{"ts": ..., "counters": {...}, "gauges": {...},
  "histograms": {name: {count, sum, mean, p50, p95, p99}}}``.
* ``spans.jsonl`` — every drained trace span, one JSON object per line
  (``stage``, ``trace_id``, ``dur_us``, ``ts``, extra fields).

The flush thread is a daemon on a short interval; ``stop()`` performs a
final flush so short runs (tests, bench smokes) never lose the tail.
Files are line-buffered appends — a crashed run leaves valid JSONL up to
the last flush, which is exactly what ``repro.obs.report`` consumes.
"""

from __future__ import annotations

import json
import os
import threading
import time

from .metrics import MetricsRegistry
from .trace import Tracer

METRICS_FILE = "metrics.jsonl"
SPANS_FILE = "spans.jsonl"


class JsonlSink:
    """Interval flusher for one registry + tracer pair into a directory."""

    def __init__(self, directory: str, registry: MetricsRegistry,
                 tracer: Tracer | None = None, flush_s: float = 1.0):
        self.directory = directory
        self._registry = registry
        self._tracer = tracer
        self._flush_s = flush_s
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()  # serialize flush() vs stop()-flush
        os.makedirs(directory, exist_ok=True)
        self._metrics_path = os.path.join(directory, METRICS_FILE)
        self._spans_path = os.path.join(directory, SPANS_FILE)

    def start(self) -> "JsonlSink":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="obs-sink", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)
            self._thread = None
        self.flush()  # final flush catches everything after the last tick

    def flush(self) -> None:
        with self._lock:
            snap = self._registry.snapshot()
            snap["ts"] = time.time()
            with open(self._metrics_path, "a", encoding="utf-8") as f:
                f.write(json.dumps(snap) + "\n")
            if self._tracer is not None:
                spans = self._tracer.drain()
                if spans:
                    with open(self._spans_path, "a", encoding="utf-8") as f:
                        for span in spans:
                            f.write(json.dumps(span) + "\n")

    def _run(self) -> None:
        while not self._stop.wait(self._flush_s):
            try:
                self.flush()
            except OSError:
                # a full/vanished disk should degrade telemetry, not
                # kill the run; the next tick retries.
                pass
