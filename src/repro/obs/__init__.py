"""One telemetry plane for the distributed runtime.

``Telemetry`` bundles the three pieces every component needs — a
:class:`~repro.obs.metrics.MetricsRegistry`, a
:class:`~repro.obs.trace.Tracer`, and (optionally) a
:class:`~repro.obs.sink.JsonlSink` — behind a single handle that is
threaded through the fabric, gateway, sources, and inference server.

The contract components follow:

* accept ``telemetry=None`` and fall back to ``Telemetry.local()`` — a
  private registry with tracing disabled and no sink. Instruments still
  record (tests can assert on them); nothing is exported.
* the *runner* builds exactly one ``Telemetry`` per run (with a sink
  when ``--metrics-dir`` is set) and hands the same instance to every
  plane, so the sink's snapshots see the whole pipeline.
* instrument names are namespaced by plane (``shard0/add_us``,
  ``gateway/blocks_in``, ``source/staged``) because the registry is
  shared.
"""

from __future__ import annotations

from . import log
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .sink import JsonlSink
from .trace import Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "JsonlSink", "Tracer", "Telemetry", "log",
]


class Telemetry:
    """Registry + tracer + optional sink, as one pass-around handle."""

    def __init__(self, registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 sink: JsonlSink | None = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(0.0)
        self.sink = sink

    @classmethod
    def local(cls) -> "Telemetry":
        """Private no-export telemetry — the default for components
        constructed outside a run (unit tests, ad-hoc scripts)."""
        return cls()

    @classmethod
    def for_run(cls, metrics_dir: str | None,
                trace_sample_rate: float = 0.0,
                flush_s: float = 1.0) -> "Telemetry":
        """The runner's constructor: sink iff ``metrics_dir`` is set."""
        registry = MetricsRegistry()
        tracer = Tracer(trace_sample_rate)
        sink = None
        if metrics_dir:
            sink = JsonlSink(metrics_dir, registry, tracer, flush_s=flush_s)
        return cls(registry, tracer, sink)

    # conveniences so call sites read `tel.counter("x")`, not
    # `tel.registry.counter("x")`
    def counter(self, name: str) -> Counter:
        return self.registry.counter(name)

    def gauge(self, name: str) -> Gauge:
        return self.registry.gauge(name)

    def histogram(self, name: str) -> Histogram:
        return self.registry.histogram(name)

    def start(self) -> "Telemetry":
        if self.sink is not None:
            self.sink.start()
        return self

    def stop(self) -> None:
        if self.sink is not None:
            self.sink.stop()
