"""Structured one-line log emitter: human-readable, machine-parseable.

Replaces the bare ``print`` progress/summary lines in
``runtime/runner.py`` and ``launch/train.py``. Every line keeps the
shape the prints had — ``[event] key=value key=value`` on stdout — but
goes through the stdlib ``logging`` machinery (so operators can redirect
or silence it) and every field is a bare ``key=value`` token, so a
``dict(tok.split("=", 1) for tok in line.split()[1:])`` recovers the
record without a regex.

Usage::

    from repro.obs import log
    log.emit("async-progress", t=f"+{dt:.1f}s", generated=n, ...)
    # -> [async-progress] t=+12.3s generated=4096 ...

Values render compactly: floats to 1 decimal (latencies are µs — finer
is noise), everything else via ``str``. Spaces inside values are
replaced with ``_`` to keep the line splittable.
"""

from __future__ import annotations

import logging
import sys

_LOGGER_NAME = "repro"
_configured = False


def get_logger() -> logging.Logger:
    """The shared "repro" logger: stdout handler, message-only format,
    no propagation (pytest and app root handlers stay clean)."""
    global _configured
    logger = logging.getLogger(_LOGGER_NAME)
    if not _configured:
        if not logger.handlers:
            handler = logging.StreamHandler(sys.stdout)
            handler.setFormatter(logging.Formatter("%(message)s"))
            logger.addHandler(handler)
        logger.setLevel(logging.INFO)
        logger.propagate = False
        _configured = True
    return logger


def _render(value) -> str:
    if isinstance(value, float):
        text = f"{value:.1f}"
    else:
        text = str(value)
    return text.replace(" ", "_")


def format_line(event: str, **fields) -> str:
    """``[event] k=v k=v`` — exposed separately so tests can assert the
    exact line without capturing log output."""
    parts = [f"[{event}]"]
    parts.extend(f"{k}={_render(v)}" for k, v in fields.items())
    return " ".join(parts)


def emit(event: str, **fields) -> None:
    get_logger().info(format_line(event, **fields))
