"""Run-report surface: ``python -m repro.obs.report <metrics-dir>``.

Renders the JSONL a :class:`repro.obs.sink.JsonlSink` wrote during a run
into a per-stage bottleneck table:

* **stage table** — for each pipeline stage (actor, gateway, add,
  sample, learn, writeback): span count, sustained rate over the
  observed window, and p50/p95/p99 of the stage's own duration.
* **inter-stage gaps** — wall-time between consecutive stages of the
  same trace id (actor→gateway, gateway→add, sample→learn,
  learn→writeback): this is where a bottleneck shows up as queue time
  that no single stage's duration explains.
* **queue depths** — last-seen gauge values (shard add/sample queues,
  staged prefetch depth, replay size).
* **stall counters** — starvation and backpressure totals (learner
  starved polls, actor add-blocked, gateway add retries).
* **recovery events** — the fault-tolerance plane's counters (actor
  restarts, transport reconnects, snapshots saved): a run that survived
  faults shows its scars here.

The tool reads only what the sink wrote — run it offline, long after
the run, on a copied directory.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .sink import METRICS_FILE, SPANS_FILE

# Canonical pipeline order; report rows render in this order with any
# unknown stages appended (future planes report in without edits here).
STAGE_ORDER = ["actor", "gateway", "add", "sample", "learn", "writeback"]

# Consecutive same-trace-id stage pairs whose wall-time gap is queue
# time between planes. (add → sample is NOT a pair: a block's add id and
# a batch's sample id are different traces by design.)
GAP_PAIRS = [("actor", "gateway"), ("gateway", "add"),
             ("sample", "learn"), ("learn", "writeback")]

_STALL_TOKENS = ("starved", "backpressure", "blocked", "retries", "dropped")

# Counters whose names carry these tokens are recovery events: the
# fault-tolerance plane reporting restarts, reconnects, and snapshots.
_RECOVERY_TOKENS = ("restart", "reconnect", "snapshot", "proc_exits")


def _read_jsonl(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue  # torn tail line from a crashed run
    return out


def _percentile(values: list[float], q: float) -> float:
    """numpy-style 'linear' percentile on a raw sample, stdlib only."""
    if not values:
        return 0.0
    vals = sorted(values)
    rank = (q / 100.0) * (len(vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(vals) - 1)
    frac = rank - lo
    return vals[lo] + frac * (vals[hi] - vals[lo])


def load_report(directory: str) -> dict:
    """Aggregate a metrics dir into the report's data model."""
    metrics = _read_jsonl(os.path.join(directory, METRICS_FILE))
    spans = _read_jsonl(os.path.join(directory, SPANS_FILE))

    # --- stage table -----------------------------------------------------
    by_stage: dict[str, list[dict]] = {}
    for span in spans:
        by_stage.setdefault(span.get("stage", "?"), []).append(span)
    ts_all = [s["ts"] for s in spans if "ts" in s]
    window_s = max(max(ts_all) - min(ts_all), 1e-9) if ts_all else 0.0
    stages = {}
    for stage, group in by_stage.items():
        durs = [s["dur_us"] for s in group if "dur_us" in s]
        stages[stage] = {
            "count": len(group),
            "rate_hz": len(group) / window_s if window_s else 0.0,
            "p50_us": _percentile(durs, 50.0),
            "p95_us": _percentile(durs, 95.0),
            "p99_us": _percentile(durs, 99.0),
        }

    # --- inter-stage gaps ------------------------------------------------
    by_tid: dict[int, dict[str, float]] = {}
    for span in spans:
        tid = span.get("trace_id")
        if tid:
            # first occurrence wins: the gap measures when the stage
            # first touched this trace.
            by_tid.setdefault(tid, {}).setdefault(
                span.get("stage", "?"), span.get("ts", 0.0))
    gaps = {}
    for src, dst in GAP_PAIRS:
        deltas = [(st[dst] - st[src]) * 1e6 for st in by_tid.values()
                  if src in st and dst in st and st[dst] >= st[src]]
        if deltas:
            gaps[f"{src}->{dst}"] = {
                "count": len(deltas),
                "p50_us": _percentile(deltas, 50.0),
                "p95_us": _percentile(deltas, 95.0),
                "p99_us": _percentile(deltas, 99.0),
            }

    # --- last-seen gauges / stall counters -------------------------------
    last = metrics[-1] if metrics else {}
    gauges = dict(last.get("gauges", {}))
    counters = dict(last.get("counters", {}))
    stalls = {k: v for k, v in counters.items()
              if any(tok in k for tok in _STALL_TOKENS)}
    recovery = {k: v for k, v in counters.items()
                if any(tok in k for tok in _RECOVERY_TOKENS)}
    # snapshot/last_step is a gauge, but it belongs with the recovery
    # story (what a resume would continue from).
    for name, val in gauges.items():
        if any(tok in name for tok in _RECOVERY_TOKENS):
            recovery[name] = val

    # --- inference plane -------------------------------------------------
    # The wave scheduler's padding tax, made honest: lifetime fraction of
    # dispatched lanes that were replicated padding (recomputed duplicate
    # rollouts, dropped on return). A slot-scheduled run pads nothing.
    inference = {}
    lanes = counters.get("inference/wave_lanes", 0)
    if lanes:
        inference["wave_lanes"] = lanes
        inference["padded_lanes"] = counters.get("inference/padded_lanes", 0)
        inference["pad_fraction"] = inference["padded_lanes"] / lanes
        if "inference/slot_occupancy" in gauges:
            inference["slot_occupancy"] = gauges["inference/slot_occupancy"]

    return {"directory": directory, "window_s": window_s,
            "num_spans": len(spans), "num_snapshots": len(metrics),
            "stages": stages, "gaps": gaps, "gauges": gauges,
            "counters": counters, "stalls": stalls, "recovery": recovery,
            "inference": inference,
            "histograms": dict(last.get("histograms", {}))}


def _fmt_row(cols, widths):
    return "  ".join(str(c).rjust(w) for c, w in zip(cols, widths))


def render(report: dict) -> str:
    lines = []
    lines.append(f"run report: {report['directory']}")
    lines.append(f"  spans={report['num_spans']}"
                 f" snapshots={report['num_snapshots']}"
                 f" window={report['window_s']:.2f}s")

    stages = report["stages"]
    if stages:
        order = [s for s in STAGE_ORDER if s in stages]
        order += sorted(s for s in stages if s not in STAGE_ORDER)
        widths = (10, 8, 10, 10, 10, 10)
        lines.append("")
        lines.append("stage durations (traced spans)")
        lines.append(_fmt_row(
            ("stage", "count", "rate/s", "p50_us", "p95_us", "p99_us"),
            widths))
        for stage in order:
            row = stages[stage]
            lines.append(_fmt_row(
                (stage, row["count"], f"{row['rate_hz']:.1f}",
                 f"{row['p50_us']:.1f}", f"{row['p95_us']:.1f}",
                 f"{row['p99_us']:.1f}"), widths))

    gaps = report["gaps"]
    if gaps:
        widths = (18, 8, 12, 12, 12)
        lines.append("")
        lines.append("inter-stage gaps (same trace id, wall time)")
        lines.append(_fmt_row(
            ("edge", "count", "p50_us", "p95_us", "p99_us"), widths))
        for edge, row in gaps.items():
            lines.append(_fmt_row(
                (edge, row["count"], f"{row['p50_us']:.1f}",
                 f"{row['p95_us']:.1f}", f"{row['p99_us']:.1f}"), widths))

    if report["gauges"]:
        lines.append("")
        lines.append("queue depths / levels (last snapshot)")
        for name in sorted(report["gauges"]):
            lines.append(f"  {name} = {report['gauges'][name]:g}")

    if report["stalls"]:
        lines.append("")
        lines.append("starvation / backpressure counters")
        for name in sorted(report["stalls"]):
            lines.append(f"  {name} = {report['stalls'][name]}")

    if report.get("inference"):
        inf = report["inference"]
        lines.append("")
        lines.append("inference plane (shared batched engine)")
        lines.append(f"  dispatched lanes = {inf['wave_lanes']:g}")
        lines.append(f"  padded lanes     = {inf['padded_lanes']:g}  "
                     f"(pad fraction {inf['pad_fraction']:.3f} — wasted "
                     "duplicate rollouts under wave coalescing)")
        if "slot_occupancy" in inf:
            lines.append(f"  slot occupancy   = {inf['slot_occupancy']:.3f}"
                         "  (last dispatch, live/max slots)")

    if report.get("recovery"):
        lines.append("")
        lines.append("recovery events (restarts / reconnects / snapshots)")
        for name in sorted(report["recovery"]):
            lines.append(f"  {name} = {report['recovery'][name]:g}")

    hists = report["histograms"]
    if hists:
        widths = (28, 8, 10, 10, 10, 10)
        lines.append("")
        lines.append("latency histograms (full run)")
        lines.append(_fmt_row(
            ("name", "count", "mean_us", "p50_us", "p95_us", "p99_us"),
            widths))
        for name in sorted(hists):
            h = hists[name]
            if not h.get("count"):
                continue
            lines.append(_fmt_row(
                (name, h["count"], f"{h['mean']:.1f}", f"{h['p50']:.1f}",
                 f"{h['p95']:.1f}", f"{h['p99']:.1f}"), widths))

    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a run's metrics/span JSONL into a per-stage "
                    "bottleneck table.")
    ap.add_argument("metrics_dir",
                    help="directory passed as --metrics-dir to the run")
    ap.add_argument("--json", action="store_true",
                    help="emit the raw aggregated report as JSON")
    args = ap.parse_args(argv)
    if not os.path.isdir(args.metrics_dir):
        print(f"error: {args.metrics_dir} is not a directory",
              file=sys.stderr)
        return 2
    report = load_report(args.metrics_dir)
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True))
    else:
        print(render(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
