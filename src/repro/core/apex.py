"""The Ape-X loop on a TPU mesh: SPMD actor/learner alternation.

Paper architecture (Fig. 1): many actors feed a shared prioritized replay; a
single learner samples, updates, and writes back priorities; actors refresh
parameters periodically. TPU-native realization (DESIGN.md §2):

* Actor lanes — every ``data``-axis shard steps a vector of environments with
  its slice of the global eps-ladder; the *whole* global lane vector plays the
  role of the paper's N actors (eps_i = eps^(1 + i/(N-1)*alpha) over global
  lane ids).
* Sharded replay — each shard owns ``capacity/num_shards`` slots. Experience
  never crosses shards; the learner's gradient psum and two scalars per
  sampling round (global size, global max-IS-weight) are the only collectives.
* Staleness — actors act with a parameter copy refreshed every
  ``param_sync_period`` iterations (paper: every 400 frames), making the
  off-policy gap explicit and testable.
* Alternation — acting and learning run bulk-synchronously;
  ``learner_steps_per_iter`` and ``rollout_len`` set the paper's generate :
  consume ratio (~12.5K : 9.7K transitions/s in §4.1).

Everything below is per-shard pure functions plus a ``shard_map`` wrapper.
The phase bodies themselves (rollout, update, priority write-back) live in
``repro.runtime.phases`` and are shared with the decoupled async runtime
(``repro.runtime.runner``); this module composes them bulk-synchronously.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import priority as prio, replay as replay_lib, sampling
from repro.envs.synthetic import batch_reset
from repro.runtime import phases


@dataclasses.dataclass(frozen=True)
class ApexConfig:
    replay: replay_lib.ReplayConfig
    lanes_per_shard: int = 32          # vectorized envs per shard
    num_shards: int = 1                # data-axis size (for the global ladder)
    rollout_len: int = 16              # T env steps per actor phase
    n_step: int = 3                    # paper: n = 3
    batch_size: int = 64               # learner batch per shard
    learner_steps_per_iter: int = 1
    param_sync_period: int = 1         # iterations between actor param refresh
    target_update_period: int = 100    # learner steps (paper Atari: 2500)
    evict_interval: int = 100          # learner steps between evictions (paper: 100)
    evict_num: int = 0                 # victims per prioritized eviction (DPG mode)
    eviction: str = "fifo"             # "fifo" | "prioritized"
    replicate_k: int = 1               # Fig. 6 ablation: add each transition k times
    eps_mode: str = "ladder"           # "ladder" | "fixed_set" (Fig. 7 ablation)
    eps_base: float = prio.EPSILON_BASE
    eps_alpha: float = prio.EPSILON_ALPHA
    compress_obs: bool = False         # store obs via the uint8 codec (the
                                       # paper's PNG-compression analogue)

    @property
    def num_actors(self) -> int:
        return self.lanes_per_shard * self.num_shards

    @property
    def window(self) -> int:
        return self.rollout_len - self.n_step + 1


class ApexState(NamedTuple):
    # replicated across shards
    params: Any
    target_params: Any
    opt_state: Any
    actor_params: Any          # the stale copy actors act with
    iteration: jax.Array
    learner_step: jax.Array
    # per-shard
    replay: replay_lib.ReplayState
    env_state: Any             # (lanes, ...)
    obs: jax.Array             # (lanes, ...)
    ep_return: jax.Array       # (lanes,) running episode return
    rng: jax.Array
    frames: jax.Array          # env steps on this shard


REPLICATED_FIELDS = ("params", "target_params", "opt_state", "actor_params",
                     "iteration", "learner_step")


def lane_epsilons(cfg: ApexConfig, shard_id: jax.Array) -> jax.Array:
    """This shard's slice of the global exploration ladder."""
    return phases.lane_epsilons(cfg, shard_id)


def init_state(cfg: ApexConfig, env, agent, optimizer, rng: jax.Array,
               shard_id: jax.Array | int = 0) -> ApexState:
    rng = jax.random.fold_in(rng, jnp.asarray(shard_id))
    p_rng, e_rng, s_rng = jax.random.split(rng, 3)
    env_state, obs = batch_reset(env, e_rng, cfg.lanes_per_shard)
    params = agent.init(p_rng, obs[:1])
    item = _item_example(env, obs, cfg.compress_obs)
    return ApexState(
        params=params,
        target_params=jax.tree.map(jnp.copy, params),
        opt_state=optimizer.init(params),
        actor_params=jax.tree.map(jnp.copy, params),
        iteration=jnp.zeros((), jnp.int32),
        learner_step=jnp.zeros((), jnp.int32),
        replay=replay_lib.init(cfg.replay, item),
        env_state=env_state,
        obs=obs,
        ep_return=jnp.zeros((cfg.lanes_per_shard,), jnp.float32),
        rng=s_rng,
        frames=jnp.zeros((), jnp.int32),
    )


def _item_example(env, obs: jax.Array, compress: bool = False) -> dict:
    return phases.item_example(env, obs, compress)


# ---------------------------------------------------------------------------
# Actor phase
# ---------------------------------------------------------------------------

def actor_phase(cfg: ApexConfig, env, agent, state: ApexState,
                shard_id: jax.Array | int = 0) -> tuple[ApexState, dict]:
    """Roll out T steps per lane, build n-step transitions from the trajectory,
    compute initial priorities from the buffered Q-values, bulk-add to the
    shard's replay slots (Alg. 1, vectorized). Thin wrapper over the shared
    ``runtime.phases.act_phase`` + ``replay_add`` pair."""
    aslice = phases.ActorSlice(
        env_state=state.env_state, obs=state.obs, ep_return=state.ep_return,
        rng=state.rng, frames=state.frames)
    aslice, block, metrics = phases.act_phase(
        cfg, env, agent, state.actor_params, aslice, shard_id)
    new_replay = phases.replay_add(cfg, state.replay, block)
    state = state._replace(
        replay=new_replay, env_state=aslice.env_state, obs=aslice.obs,
        ep_return=aslice.ep_return, rng=aslice.rng, frames=aslice.frames)
    return state, metrics


# ---------------------------------------------------------------------------
# Learner phase
# ---------------------------------------------------------------------------

def _global_is_weights(cfg: ApexConfig, batch: replay_lib.SampleBatch,
                       size: jax.Array, axis_name: str | None) -> jax.Array:
    """IS weights for the *actual* global sampling distribution.

    With equal per-shard quotas, P(i) = leaf_i / (shard_total * num_shards);
    correcting with the global N and global max keeps the estimate unbiased
    even when shard masses drift apart. Two scalar collectives total. The
    formula itself lives in ``repro.core.sampling`` and is shared with the
    async fabric's host-side merge (``sampling.merged_is_weights``).
    """
    if axis_name is None:
        return batch.is_weights
    return sampling.collective_is_weights(
        batch.leaf_mass, batch.total_mass, size, cfg.num_shards,
        cfg.replay.beta, axis_name)


def learner_phase(cfg: ApexConfig, agent, optimizer, state: ApexState,
                  axis_name: str | None = None) -> tuple[ApexState, dict]:
    """Sample prioritized batches, apply the off-policy update, write back
    fresh priorities, periodically update the target net and evict (Alg. 2)."""
    rcfg = cfg.replay

    def one_step(st: ApexState, rng: jax.Array) -> tuple[ApexState, dict]:
        ready = replay_lib.can_sample(rcfg, st.replay)
        if axis_name is not None:
            # learner starts only when every shard passed min-fill (paper: a
            # single global threshold of 50000 transitions).
            ready = jax.lax.pmin(ready.astype(jnp.int32), axis_name) > 0

        def do_update(st: ApexState) -> tuple[ApexState, dict]:
            s_rng, e_rng = jax.random.split(rng)
            batch = replay_lib.sample(rcfg, st.replay, s_rng, cfg.batch_size)
            weights = _global_is_weights(cfg, batch, st.replay.size, axis_name)
            lslice = phases.LearnerSlice(
                params=st.params, target_params=st.target_params,
                opt_state=st.opt_state, learner_step=st.learner_step)
            lslice, new_prios, metrics = phases.learn_phase(
                cfg, agent, optimizer, lslice, batch.items, weights, axis_name)
            rep = phases.priority_writeback(
                cfg, st.replay, batch.indices, new_prios,
                lslice.learner_step, e_rng)
            st = st._replace(params=lslice.params, opt_state=lslice.opt_state,
                             target_params=lslice.target_params, replay=rep,
                             learner_step=lslice.learner_step)
            return st, {**metrics, "updated": jnp.ones((), jnp.float32)}

        def skip(st: ApexState) -> tuple[ApexState, dict]:
            zero = {k: jnp.zeros((), jnp.float32) for k in _metric_keys(agent)}
            return st, {**zero, "updated": jnp.zeros((), jnp.float32)}

        return jax.lax.cond(ready, do_update, skip, st)

    if cfg.learner_steps_per_iter == 0:   # actor-only mode (ablations)
        zero = {k: jnp.zeros((), jnp.float32) for k in _metric_keys(agent)}
        return state, {**zero, "updated": jnp.zeros((), jnp.float32)}
    rng, sub = jax.random.split(state.rng)
    step_rngs = jax.random.split(sub, cfg.learner_steps_per_iter)
    state = state._replace(rng=rng)
    state, metrics = jax.lax.scan(
        lambda st, r: one_step(st, r), state, step_rngs)
    return state, jax.tree.map(lambda m: m[-1], metrics)


def _metric_keys(agent) -> tuple[str, ...]:
    from repro.core.agents import DPGAgent
    if isinstance(agent, DPGAgent):
        return ("critic_loss", "policy_loss", "mean_q")
    return ("loss", "mean_q", "mean_abs_td")


# ---------------------------------------------------------------------------
# Full iteration + distribution wrappers
# ---------------------------------------------------------------------------

def train_iteration(cfg: ApexConfig, env, agent, optimizer, state: ApexState,
                    shard_id: jax.Array | int = 0,
                    axis_name: str | None = None) -> tuple[ApexState, dict]:
    # Periodic actor parameter refresh (paper: every 400 frames).
    sync = (state.iteration % cfg.param_sync_period) == 0
    actor_params = jax.tree.map(
        lambda p, a: jnp.where(sync, p, a), state.params, state.actor_params)
    state = state._replace(actor_params=actor_params)

    state, actor_metrics = actor_phase(cfg, env, agent, state, shard_id)
    state, learner_metrics = learner_phase(cfg, agent, optimizer, state, axis_name)
    state = state._replace(iteration=state.iteration + 1)
    return state, {**actor_metrics, **learner_metrics,
                   "replay_size": state.replay.size.astype(jnp.float32),
                   "frames": state.frames.astype(jnp.float32)}


def make_train_fn(cfg: ApexConfig, env, agent, optimizer, mesh=None,
                  data_axis: str = "data"):
    """Build (init_fn, step_fn).

    Without a mesh: single-shard jitted loop (tests/examples). With a mesh:
    ``shard_map`` over the data axis — replicated learner state, per-shard
    replay/envs; collectives are the gradient pmean + the IS/min-fill scalars.
    """
    if mesh is None:
        init_fn = jax.jit(
            lambda rng: init_state(cfg, env, agent, optimizer, rng, 0))
        step_fn = jax.jit(
            lambda st: train_iteration(cfg, env, agent, optimizer, st, 0, None))
        return init_fn, step_fn

    if hasattr(jax, "shard_map"):
        shard_map = functools.partial(jax.shard_map, check_vma=False)
    else:  # jax < 0.5: the API lived in jax.experimental with check_rep
        from jax.experimental.shard_map import shard_map as _shard_map
        shard_map = functools.partial(_shard_map, check_rep=False)

    def per_shard_init(rng):
        sid = jax.lax.axis_index(data_axis)
        st = init_state(cfg, env, agent, optimizer, rng, sid)
        return _add_leading(st)

    def per_shard_step(st):
        sid = jax.lax.axis_index(data_axis)
        st = _strip_leading(st)
        st, metrics = train_iteration(cfg, env, agent, optimizer, st, sid, data_axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, data_axis), metrics)
        return _add_leading(st), metrics

    def state_specs():
        def spec_for(field, leaf_spec):
            return leaf_spec
        reps = {f: P() for f in REPLICATED_FIELDS}
        return ApexState(**reps, **{
            f: P(data_axis) for f in ApexState._fields if f not in reps})

    specs = state_specs()
    init_fn = jax.jit(shard_map(
        per_shard_init, mesh=mesh, in_specs=P(), out_specs=specs))
    step_fn = jax.jit(shard_map(
        per_shard_step, mesh=mesh, in_specs=(specs,),
        out_specs=(specs, P())))
    return init_fn, step_fn


def _add_leading(st: ApexState) -> ApexState:
    """Re-attach the per-shard leading axis expected by shard_map out_specs."""
    return ApexState(**{
        f: (getattr(st, f) if f in REPLICATED_FIELDS
            else jax.tree.map(lambda x: x[None], getattr(st, f)))
        for f in ApexState._fields})


def _strip_leading(st: ApexState) -> ApexState:
    return ApexState(**{
        f: (getattr(st, f) if f in REPLICATED_FIELDS
            else jax.tree.map(lambda x: x[0], getattr(st, f)))
        for f in ApexState._fields})
